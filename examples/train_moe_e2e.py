"""End-to-end driver: pre-train a ~100M-parameter BIP-routed MoE LM for a few
hundred steps with checkpointing, eval, and per-layer balance reporting.

    PYTHONPATH=src python examples/train_moe_e2e.py [--steps 300] [--method bip]

This is the paper's experiment at ~1/3 scale of its 0.3B model: same routing
(m=16, k=4, softmax gate), same per-layer AvgMaxVio accounting as Tables 4/5.
~100M params: 8 layers x 16 experts x (3·256·704) + attention + embeddings.
"""
import argparse
import dataclasses
import os

import jax
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import make_batches
from repro.models import build_model
from repro.training import train_loop
from repro.training.loop import evaluate_ppl


def build_cfg(method: str):
    base = configs.get("minimind_moe_16e")
    routing = dataclasses.replace(
        base.routing,
        strategy={"bip": "bip", "lossfree": "lossfree", "aux_loss": "aux_loss"}[method],
        bip_iters=4,
    )
    return dataclasses.replace(
        base,
        d_model=256,
        n_heads=8,
        n_kv_heads=8,
        head_dim=32,
        d_ff=704,
        moe_d_ff=704,
        vocab_size=4096,
        max_seq_len=256,
        attn_chunk=128,
        routing=routing,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--method", default="bip", choices=["bip", "lossfree", "aux_loss"])
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = build_cfg(args.method)
    model = build_model(cfg)
    n_params = sum(
        int(np.prod(p.shape))
        for p in jax.tree.leaves(jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0))))
    )
    print(f"model: {n_params/1e6:.1f}M params, method={args.method}")

    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    batches = make_batches(cfg, args.batch, args.seq_len, args.steps)

    # chunked training so we can checkpoint between chunks
    state = None
    log_all = None
    done = 0
    for start in range(0, args.steps, args.ckpt_every):
        n = min(args.ckpt_every, args.steps - start)
        chunk = [next(batches) for _ in range(n)]
        state, log = train_loop(
            model, chunk, lr=1e-3, warmup_steps=20, total_steps=args.steps,
            state=state, log_every=25,
        )
        done += n
        mgr.save(done, {"params": state.params, "router": state.router_states})
        if log_all is None:
            log_all = log
        else:
            log_all.losses += log.losses
            log_all.max_vio_steps += log.max_vio_steps
            for t_all, t in zip(log_all.per_layer, log.per_layer):
                t_all.max_vios += t.max_vios
            log_all.model_tracker.max_vios += log.model_tracker.max_vios
        print(f"[{done}/{args.steps}] ckpt saved; loss={log.losses[-1]:.4f}")

    test = make_batches(cfg, args.batch, args.seq_len, 4, split="test")
    ppl = evaluate_ppl(model, state, test)
    s = log_all.summary()
    print("\n==== results ====")
    print(f"test perplexity : {ppl:.3f}")
    print(f"AvgMaxVio       : {s['AvgMaxVio']:.4f}")
    print(f"SupMaxVio       : {s['SupMaxVio']:.4f}")
    print("per-layer AvgMaxVio (paper Table 4 analogue):")
    for i, v in enumerate(s["AvgMaxVio_per_layer"]):
        print(f"  layer {i+1}: {v:.4f}")


if __name__ == "__main__":
    main()
