"""Quickstart: build a small BIP-routed MoE, train 30 steps, watch balance.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax

from repro import configs
from repro.data import make_batches
from repro.models import build_model
from repro.training import train_loop


def main():
    # the paper's 16-expert model at toy scale (same m=16, k=4 routing)
    cfg = configs.reduced_for_smoke("minimind_moe_16e", vocab_size=512)
    print(f"arch={cfg.name} m={cfg.routing.n_experts} k={cfg.routing.top_k} "
          f"strategy={cfg.routing.strategy} T={cfg.routing.bip_iters}")

    model = build_model(cfg)
    batches = make_batches(cfg, batch_size=8, seq_len=64, n_batches=30)
    state, log = train_loop(model, batches, lr=1e-3, total_steps=30, log_every=5)

    s = log.summary()
    print("\nBalance over the whole run (the paper's metrics):")
    print(f"  AvgMaxVio = {s['AvgMaxVio']:.4f}   (paper BIP: ~0.05)")
    print(f"  SupMaxVio = {s['SupMaxVio']:.4f}   (paper BIP: <0.21)")
    print(f"  first-batch MaxVio = {log.max_vio_steps[0].max():.4f} "
          f"<- balanced from step 1, the headline claim")
    print(f"  final ppl = {s['final_ppl']:.2f}")

    # swap in the Loss-Controlled baseline to see the difference
    cfg_lc = dataclasses.replace(
        cfg, routing=dataclasses.replace(cfg.routing, strategy="aux_loss")
    )
    model_lc = build_model(cfg_lc)
    batches = make_batches(cfg_lc, batch_size=8, seq_len=64, n_batches=30)
    _, log_lc = train_loop(model_lc, batches, lr=1e-3, total_steps=30)
    print(f"\nLoss-Controlled for comparison: AvgMaxVio = "
          f"{log_lc.summary()['AvgMaxVio']:.4f}, first batch "
          f"{log_lc.max_vio_steps[0].max():.4f}")


if __name__ == "__main__":
    main()
