"""Batched serving with a KV cache: prefill 8 prompts, decode 32 tokens each.

    PYTHONPATH=src python examples/serve_batched.py [--arch minimind_moe_16e]

Routing stays active at decode time — with expert parallelism, serving
utilization also depends on balanced expert loads, and the BIP gate keeps
balancing per decode batch (its dual vector q warm-starts from training).
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import build_model
from repro.serving import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minimind_moe_16e")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = configs.reduced_for_smoke(args.arch, vocab_size=512)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.frontend_tokens, cfg.frontend_dim)),
            jnp.float32,
        )
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.enc_seq_len, cfg.frontend_dim)),
            jnp.float32,
        )

    eng = ServeEngine(model, params, max_seq_len=args.prompt_len + args.gen + 1)
    cache, states = eng.start(batch)
    logits, cache, states = eng.prefill(prompts, cache, states)
    toks, cache, states = eng.decode(
        logits, cache, states, args.gen, temperature=0.8, key=jax.random.PRNGKey(1)
    )
    print(f"arch={cfg.name} ({cfg.family}), batch={args.batch}")
    for i in range(min(4, args.batch)):
        print(f"  seq {i}: prompt={np.asarray(prompts[i])[:8]}... "
              f"generated={np.asarray(toks[i])[:16]}...")
    print(f"generated {toks.shape[0] * toks.shape[1]} tokens total")


if __name__ == "__main__":
    main()
