"""Continuous batching demo: more requests than slots, variable prompt
lengths, requests arriving mid-flight.

    PYTHONPATH=src python examples/serve_batched.py [--arch minimind_moe_16e]

Routing stays active at serve time — prefill chunks and decode tokens share
each MoE layer's router invocation, and the BIP gate's dual vector q (warm
from training if a checkpoint is loaded) keeps expert loads balanced per
fused step, which is what keeps expert-parallel serving utilization high.
"""
import argparse

import jax
import numpy as np

from repro import configs
from repro.models import build_model
from repro.serving import ContinuousBatchingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minimind_moe_16e")
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = configs.reduced_for_smoke(args.arch, vocab_size=512)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ContinuousBatchingEngine(
        model,
        params,
        n_slots=args.n_slots,
        chunk_size=args.chunk,
        max_seq_len=128,
        temperature=0.8,
    )

    rng = np.random.default_rng(0)
    # submit an initial wave, then trickle the rest in while the pool works
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 40))
        prompt = rng.integers(0, cfg.vocab_size, (plen,))
        if i < args.n_slots:
            reqs.append(eng.submit(prompt, args.gen, ignore_eos=True))
        else:
            reqs.append((prompt, args.gen))

    late = [r for r in reqs if isinstance(r, tuple)]
    reqs = [r for r in reqs if not isinstance(r, tuple)]
    while eng.scheduler.has_work or late:
        if late:  # a request shows up every other step, mid-flight
            prompt, gen = late.pop(0)
            reqs.append(eng.submit(prompt, gen, ignore_eos=True))
        eng.step()
        eng.step()

    print(f"arch={cfg.name} ({cfg.family}), slots={args.n_slots}, "
          f"requests={len(reqs)}, steps={eng.n_steps}")
    for r in reqs[:4]:
        print(f"  req {r.req_id}: prompt[{len(r.prompt)}] "
              f"generated={r.output[:10]}... ({r.finish_reason})")
    total = eng.prefill_tokens + eng.decode_tokens
    print(f"processed {total} tokens ({eng.prefill_tokens} prefill, "
          f"{eng.decode_tokens} decode)")
    if cfg.is_moe:
        load = eng.expert_load
        print(f"per-expert load {load.astype(int).tolist()} "
              f"(MaxVio {load.max() / max(load.mean(), 1e-9) - 1.0:.3f})")


if __name__ == "__main__":
    main()
