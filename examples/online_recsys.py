"""Online ad-slot allocation with Algorithm 3/4 (paper §5 application).

A stream of page views arrives; each must be matched to k=2 of m=8 ad slots,
maximizing total CTR while capping any slot's share (the (BIP) program with
experts = slots). Compares greedy CTR-max routing vs the online BIP gate vs
its O(m·b) histogram approximation.

    PYTHONPATH=src python examples/online_recsys.py
"""
import numpy as np

from repro.core import ApproxBIPGate, OnlineBIPGate


def ctr_stream(rng, n, m, hot=2.0):
    """CTR scores where a few 'popular' slots dominate (collapse pressure)."""
    base = rng.standard_normal((n, m)) * 0.5 + hot * np.linspace(1.5, -1.5, m)
    e = np.exp(base - base.max(-1, keepdims=True))
    return e / e.sum(-1, keepdims=True)


def main():
    rng = np.random.default_rng(0)
    n, m, k = 4000, 8, 2
    s = ctr_stream(rng, n, m)

    greedy = np.argsort(-s, axis=-1)[:, :k]
    g_load = np.bincount(greedy.reshape(-1), minlength=m)
    g_ctr = np.take_along_axis(s, greedy, -1).sum()

    gate = OnlineBIPGate(n_tokens=n, n_experts=m, top_k=k, n_iters=2)
    approx = ApproxBIPGate(n_tokens=n, n_experts=m, top_k=k, n_bins=128, n_iters=2)
    picks_e, picks_a, ctr_e, ctr_a = [], [], 0.0, 0.0
    for i in range(n):
        idx, gains = gate.route(s[i])
        picks_e.append(idx)
        ctr_e += gains.sum()
        idx, gains = approx.route(s[i])
        picks_a.append(idx)
        ctr_a += gains.sum()
    e_load = np.bincount(np.concatenate(picks_e), minlength=m)
    a_load = np.bincount(np.concatenate(picks_a), minlength=m)

    mean = n * k / m
    print(f"{'policy':<22}{'total CTR':>10}{'CTR vs greedy':>15}{'MaxVio':>8}  load")
    for name, ctr, load in [
        ("greedy top-k", g_ctr, g_load),
        ("online BIP (Alg 3)", ctr_e, e_load),
        ("histogram BIP (Alg 4)", ctr_a, a_load),
    ]:
        print(
            f"{name:<22}{ctr:>10.1f}{ctr / g_ctr:>14.1%}"
            f"{load.max() / mean - 1:>8.2f}  {load}"
        )
    print("\nBIP trades a few % of CTR for near-uniform slot usage — the")
    print("multi-slot online matching guarantee from paper §5.")


if __name__ == "__main__":
    main()
