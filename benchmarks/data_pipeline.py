"""Streaming data-pipeline benchmark (DESIGN.md §Data).

    PYTHONPATH=src python -m benchmarks.data_pipeline            # full
    PYTHONPATH=src python -m benchmarks.data_pipeline --smoke    # CI guard

Measures, on the committed fixture corpus (or --data):

  host throughput   tokenizer encode tokens/s and loader batches/s
                    (tokenize -> shuffle -> pack -> batch, single thread)
  prefetch overlap  mean jitted train-step time at reduced minimind-16e
                    geometry for three input paths: the synthetic stream
                    (no host work), the real loader inline (host work on
                    the critical path), and the real loader behind the
                    double-buffered Prefetcher. The overlap ratio is the
                    fraction of the inline host cost the prefetcher hides:
                        1 - (t_prefetch - t_synth) / (t_inline - t_synth)
                    and `step_delta_vs_synth_pct` is the acceptance lens —
                    prefetched real-data steps should sit within a few % of
                    the synthetic baseline.

Writes BENCH_data_pipeline.json and prints repo-contract CSV
``name,us_per_call,derived``.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Any, Dict, List

CORPUS = "tests/fixtures/corpus"
BATCH = 8
SEQ_LEN = 64


def _host_throughput(shards, tok, steps: int) -> Dict[str, Any]:
    import itertools

    from repro.data import ShardedTextLoader, iter_corpus_texts

    texts = list(iter_corpus_texts(shards))
    t0 = time.perf_counter()
    n_tok = sum(len(tok.encode(t)) for t in texts)
    enc_s = time.perf_counter() - t0
    # second pass hits the per-chunk BPE cache — the steady-state rate
    t0 = time.perf_counter()
    sum(len(tok.encode(t)) for t in texts)
    enc_cached_s = time.perf_counter() - t0

    loader = ShardedTextLoader(
        shards, tok, batch_size=BATCH, seq_len=SEQ_LEN, pack_mode="pack", seed=0
    )
    t0 = time.perf_counter()
    n_batches = sum(1 for _ in itertools.islice(iter(loader), steps))
    load_s = time.perf_counter() - t0
    return {
        "corpus_docs": len(texts),
        "corpus_tokens": n_tok,
        "encode_tokens_per_s": round(n_tok / max(enc_s, 1e-9)),
        "encode_tokens_per_s_cached": round(n_tok / max(enc_cached_s, 1e-9)),
        "loader_batches_per_s": round(n_batches / max(load_s, 1e-9), 1),
        "loader_tokens_per_s": round(n_batches * BATCH * SEQ_LEN / max(load_s, 1e-9)),
    }


def _step_times(model, path_fns, steps: int, reps: int = 3):
    """Median wall-clock per train step for each input path, best of
    `reps` runs. Paths are interleaved within each rep so slow-machine
    epochs hit all paths equally; first 2 steps (compile + warmup) of
    every run are skipped."""
    import statistics

    import jax

    from repro.training import train_loop

    best = {name: float("inf") for name in path_fns}
    for _ in range(reps):
        for name, fn in path_fns.items():
            _, log = train_loop(
                model, fn(), key=jax.random.PRNGKey(0),
                total_steps=steps, warmup_steps=1,
            )
            ts = log.step_times[2:] or log.step_times
            best[name] = min(best[name], statistics.median(ts))
    return best


def run(smoke: bool = False, data: str = None) -> List[Dict[str, Any]]:
    from repro import configs
    from repro.data import (
        Prefetcher,
        ShardedTextLoader,
        SyntheticBatchStream,
        resolve_shards,
        train_tokenizer_from_files,
    )
    from repro.models import build_model

    steps = 8 if smoke else 30
    shards = resolve_shards(data or CORPUS)
    cfg = configs.reduced_for_smoke("minimind_moe_16e")

    t0 = time.perf_counter()
    tok = train_tokenizer_from_files(shards, vocab_size=cfg.vocab_size)
    tok_train_s = time.perf_counter() - t0

    host = _host_throughput(shards, tok, steps)
    model = build_model(cfg)

    def real(prefetch: bool):
        s = ShardedTextLoader(
            shards, tok, batch_size=BATCH, seq_len=SEQ_LEN, pack_mode="pack", seed=0
        )
        return Prefetcher(s, depth=2) if prefetch else s

    times = _step_times(
        model,
        {
            "synth": lambda: SyntheticBatchStream(cfg, BATCH, SEQ_LEN, steps),
            "inline": lambda: real(prefetch=False),
            "prefetch": lambda: real(prefetch=True),
        },
        steps,
    )
    t_synth, t_inline, t_prefetch = times["synth"], times["inline"], times["prefetch"]

    host_cost = t_inline - t_synth
    overlap = 1.0 - (t_prefetch - t_synth) / host_cost if host_cost > 1e-6 else 1.0
    out = {
        "meta": {
            "corpus": data or CORPUS,
            "batch": BATCH,
            "seq_len": SEQ_LEN,
            "steps": steps,
            "arch": cfg.name,
            "note": (
                "reduced geometry; overlap = fraction of inline host "
                "tokenize/pack cost hidden by the depth-2 prefetcher"
            ),
        },
        "tokenizer_train_s": round(tok_train_s, 3),
        "tokenizer_vocab": tok.vocab_size,
        "tokenizer_merges": len(tok.merges),
        **host,
        "step_time_synthetic_s": round(t_synth, 5),
        "step_time_real_inline_s": round(t_inline, 5),
        "step_time_real_prefetch_s": round(t_prefetch, 5),
        "prefetch_overlap_ratio": round(float(min(max(overlap, 0.0), 1.0)), 3),
        "step_delta_vs_synth_pct": round((t_prefetch / t_synth - 1.0) * 100, 2),
    }
    with open("BENCH_data_pipeline.json", "w") as f:
        json.dump(out, f, indent=1)

    return [
        {
            "name": "data_pipeline_encode",
            "us_per_call": round(1e6 / max(host["encode_tokens_per_s"], 1), 3),
            "derived": f"tokens_per_s={host['encode_tokens_per_s']};"
            f"cached={host['encode_tokens_per_s_cached']}",
        },
        {
            "name": "data_pipeline_loader",
            "us_per_call": round(1e6 / max(host["loader_tokens_per_s"], 1), 3),
            "derived": f"tokens_per_s={host['loader_tokens_per_s']};"
            f"batches_per_s={host['loader_batches_per_s']}",
        },
        {
            "name": "data_pipeline_step_prefetch",
            "us_per_call": round(t_prefetch * 1e6, 1),
            "derived": f"synth={t_synth * 1e6:.0f}us;inline={t_inline * 1e6:.0f}us;"
            f"overlap={out['prefetch_overlap_ratio']};"
            f"delta_vs_synth={out['step_delta_vs_synth_pct']}%",
        },
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI guard: few steps")
    ap.add_argument("--data", default=None, help="corpus dir/glob (default fixture)")
    args = ap.parse_args(argv)
    for r in run(smoke=args.smoke, data=args.data):
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
