"""Re-export: the loop-aware HLO cost model lives in repro.launch.hlo_cost."""
from repro.launch.hlo_cost import Cost, analyze, analyze_compiled, parse_hlo  # noqa: F401
