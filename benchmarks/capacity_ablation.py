"""Capacity-factor ablation — quantifies the paper's systems payoff.

Expert-parallel MoE needs a static per-expert capacity C = k·n/m·cf; tokens
over C are dropped. Unbalanced routing forces cf≈2.0 to keep drops low
early in training; BIP's per-batch balance should make cf=1.25 essentially
drop-free from step 1. This ablation measures the dropped-token fraction
per (strategy × cf) over the first training batches — the quantity that
converts MaxVio into wasted compute / lost tokens.

    PYTHONPATH=src python -m benchmarks.capacity_ablation
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import RouterConfig, init_router_state, route
from repro.models.moe import _dispatch_plan


def dropped_frac(idx, keep):
    return 1.0 - float(np.asarray(keep).sum()) / idx.size


def run(n: int = 4096, m: int = 16, k: int = 4, batches: int = 10):
    rng = np.random.default_rng(0)
    rows = []
    for strategy, t in [("aux_loss", 0), ("lossfree", 0), ("bip", 4)]:
        cfg = RouterConfig(n_experts=m, top_k=k, strategy=strategy, bip_iters=max(t, 1))
        for cf in (1.0, 1.25, 1.5, 2.0):
            state = init_router_state(cfg)
            cap = int(np.ceil(k * n / m * cf))
            drops, vios = [], []
            for b in range(batches):
                # router-collapse pressure grows over the first batches in
                # real runs; emulate with a drifting popularity skew
                logits = jnp.asarray(
                    (rng.standard_normal((n, m))
                     + (0.5 + 0.15 * b) * np.linspace(2, -2, m)[None, :]).astype(np.float32)
                )
                out = route(logits, state, cfg)
                state = out.state
                _, keep = _dispatch_plan(out.expert_index, m, cap)
                drops.append(dropped_frac(out.expert_index, keep))
                vios.append(float(out.metrics["max_vio"]))
            name = strategy if strategy != "bip" else f"bip_T{t}"
            rows.append({
                "name": f"capacity_{name}_cf{cf}",
                "us_per_call": round(float(np.mean(drops)) * 1e4) / 1e4,
                "derived": f"mean_dropped={np.mean(drops):.4f};max_dropped={np.max(drops):.4f};avg_maxvio={np.mean(vios):.3f}",
            })
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")
