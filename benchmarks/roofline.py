"""Roofline analysis from dry-run records (deliverable g).

Reads the JSONL written by repro.launch.dryrun and derives, per
(arch × shape) on the single-pod mesh:

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s        [s]
    memory term     = HLO_traffic_per_device / HBM_bw           [s]
    collective term = collective_bytes_per_device / link_bw     [s]

(the dry-run costs are already per-device — the compiled module is the SPMD
per-device program — so no further division by chip count is needed),
plus MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per device and the
usefulness ratio MODEL_FLOPS / HLO_FLOPs, and names the dominant term.

Usage:
    PYTHONPATH=src python -m benchmarks.roofline dryrun_results_single.jsonl
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List, Optional

from repro import configs
from repro.data.synthetic import INPUT_SHAPES
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def count_params(cfg, active_only: bool = False) -> float:
    """Analytic parameter count (embedding + per-layer) for MODEL_FLOPS."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    total = cfg.vocab_size * d  # embeddings (tied)
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * d
    for mixer, ffn in cfg.layer_kinds():
        if mixer in ("global", "local"):
            total += d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
            if cfg.n_enc_layers:  # cross attention
                total += d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
        else:  # mamba
            di = cfg.ssm.expand * d
            nh = di // cfg.ssm.head_dim
            conv_dim = di + 2 * cfg.ssm.n_groups * cfg.ssm.d_state
            total += d * (2 * di + 2 * cfg.ssm.n_groups * cfg.ssm.d_state + nh)
            total += cfg.ssm.d_conv * conv_dim + di * d
        if ffn == "dense":
            total += 3 * d * cfg.d_ff
        elif ffn == "moe":
            f = cfg.moe_d_ff or cfg.d_ff
            m = cfg.routing.n_experts
            n_eff = cfg.routing.top_k if active_only else m
            total += 3 * d * f * n_eff
            total += d * m  # router
            if cfg.dense_residual:
                total += 3 * d * cfg.d_ff
            if cfg.n_shared_experts:
                total += 3 * d * f * cfg.n_shared_experts
    if cfg.shared_attn_every:
        total += d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2) + 3 * d * cfg.d_ff
    if cfg.n_enc_layers:
        total += cfg.n_enc_layers * (
            d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2) + 3 * d * cfg.d_ff
        )
    return float(total)


def model_flops_per_device(arch: str, shape_name: str, n_chips: int) -> float:
    """6·N·D for training (N = active params, D = tokens); 2·N·D for
    inference steps. Per device = global / n_chips."""
    cfg = configs.get(arch)
    shape = INPUT_SHAPES[shape_name]
    n_active = count_params(cfg, active_only=True)
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        g = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        g = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        g = 2.0 * n_active * shape.global_batch
    return g / n_chips


def roofline_row(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    t_compute = rec["flops"] / PEAK_FLOPS_BF16
    t_memory = rec["traffic_bytes"] / HBM_BW
    t_coll = rec["collective_bytes"].get("total", 0.0) / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec["arch"], rec["shape"], rec["n_chips"])
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
        "dominant": dominant,
        "bound_s": terms[dominant],
        "model_flops": mf,
        "useful_ratio": mf / rec["flops"] if rec["flops"] else float("nan"),
        # TPU-adjusted peak: CPU-backend bf16->f32 dot-legalization copies
        # removed (dryrun record 'cpu_upcast_bytes'; methodology in
        # hlo_cost.cpu_bf16_upcast_bytes)
        "peak_gb": (rec.get("peak_bytes_tpu", rec.get("peak_bytes")) or 0) / 2**30,
        "peak_gb_raw": (rec.get("peak_bytes") or 0) / 2**30,
        "fits_16gb": ((rec.get("peak_bytes_tpu", rec.get("peak_bytes")) or 0) / 2**30)
        < 16.0,
    }


def analyze_file(path: str) -> List[Dict]:
    # keep the LAST record per (arch, shape, mesh) — re-runs supersede fails
    latest: Dict = {}
    order: List = []
    with open(path) as f:
        for line in f:
            rec = json.loads(line)
            key = (rec["arch"], rec["shape"], rec.get("mesh"))
            if key not in latest:
                order.append(key)
            latest[key] = rec
    rows = []
    for key in order:
        rec = latest[key]
        row = roofline_row(rec)
        if row:
            rows.append(row)
        elif rec.get("status", "").startswith("FAIL"):
            rows.append(
                {"arch": rec["arch"], "shape": rec["shape"],
                 "mesh": rec.get("mesh"), "dominant": "FAILED"}
            )
    return rows


def format_table(rows: List[Dict]) -> str:
    hdr = (
        f"{'arch':<24}{'shape':<13}{'compute_ms':>11}{'memory_ms':>11}"
        f"{'coll_ms':>10}{'dominant':>11}{'useful':>8}{'peakGB':>8}{'fits':>6}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r["dominant"] == "FAILED":
            lines.append(f"{r['arch']:<24}{r['shape']:<13}{'— FAILED —':>40}")
            continue
        lines.append(
            f"{r['arch']:<24}{r['shape']:<13}"
            f"{r['compute_s']*1e3:>11.2f}{r['memory_s']*1e3:>11.2f}"
            f"{r['collective_s']*1e3:>10.2f}{r['dominant']:>11}"
            f"{r['useful_ratio']:>8.2f}{r['peak_gb']:>8.2f}"
            f"{'y' if r.get('fits_16gb') else 'N':>6}"
        )
    return "\n".join(lines)


def main(argv=None):
    path = (argv or sys.argv[1:])[0] if (argv or sys.argv[1:]) else "dryrun_results_single.jsonl"
    rows = analyze_file(path)
    print(format_table(rows))
    # headline summaries for EXPERIMENTS.md
    ok = [r for r in rows if r["dominant"] != "FAILED"]
    if ok:
        worst = min(ok, key=lambda r: r["useful_ratio"] if r["useful_ratio"] == r["useful_ratio"] else 9)
        coll = max(ok, key=lambda r: r["collective_s"])
        print(f"\nworst useful-ratio: {worst['arch']} x {worst['shape']} ({worst['useful_ratio']:.3f})")
        print(f"most collective-bound: {coll['arch']} x {coll['shape']} ({coll['collective_s']*1e3:.1f} ms)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
