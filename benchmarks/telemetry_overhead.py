"""Telemetry overhead: instrumented vs bare train step (DESIGN.md §Observability).

    PYTHONPATH=src python -m benchmarks.telemetry_overhead [--smoke] \
        [--out-json BENCH_telemetry_overhead.json]

Compiles the reduced minimind-moe-16e train step twice — bare, and with the
full MetricStream pipeline (in-graph ring-buffer scatters, asynchronous host
drain every ``flush_every`` steps into a JSONL sink) — and times them
INTERLEAVED: bare step, instrumented step, bare, instrumented, ... Sequential
phases are useless on a shared CPU: scheduler/thermal drift between the two
phases dwarfs the telemetry cost and flips sign run to run; interleaving
subjects both programs to the same noise so the median difference isolates
the instrumentation. The instrumented path runs the real `TrainTelemetry`
host drain (buffer adoption, async copy, window materialization, sink
emission), so the measured overhead covers the whole pipeline, not just the
in-graph scatters. The estimate is the median of PAIRED per-iteration
differences (with the two programs' order alternating every iteration), so
common-mode scheduler/thermal noise cancels within each pair instead of
accumulating into the phase quantiles.

The acceptance budget is <2% at ``flush_every=10``. ``--smoke`` reports but
never gates — CI CPU quantiles still jitter a few percent either way.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np


def run(smoke: bool = True, flush_every: int = 10, out_json: str | None = None):
    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.data.synthetic import SyntheticBatchStream
    from repro.models import build_model
    from repro.optim import adamw as _adamw
    from repro.optim.schedules import linear_warmup_cosine
    from repro.telemetry import JSONLSink, TrainTelemetry
    from repro.training.loop import compile_train_step, init_train_state

    cfg = configs.reduced_for_smoke("minimind_moe_16e", vocab_size=256)
    model = build_model(cfg)
    opt_cfg = _adamw.from_model_config(cfg)
    key = jax.random.PRNGKey(0)
    batch = next(iter(SyntheticBatchStream(cfg, 4, 64, 1)))
    steps = 120 if smoke else 300
    lr_fn = linear_warmup_cosine(1e-3, 5, steps)

    # two independent states so both programs advance realistic (changing)
    # inputs; donation off so the states survive the interleaved loop
    state_a = init_train_state(model, key, opt_cfg)
    state_b = init_train_state(model, key, opt_cfg)
    f_bare = compile_train_step(
        model, opt_cfg, lr_fn, state_a, batch, donate=False
    )

    fd, tmp = tempfile.mkstemp(suffix=".jsonl")
    os.close(fd)
    sink = JSONLSink(tmp)
    tel = TrainTelemetry(sink=sink, flush_every=flush_every)
    f_tel = compile_train_step(
        model, opt_cfg, lr_fn, state_b, batch, donate=False, telemetry=tel
    )

    try:
        for i in range(2):  # compile + warm both programs
            state_a, mets = f_bare(state_a, batch)
            state_b, mets, buf = f_tel(
                state_b, batch, tel.buf, jnp.asarray(i, jnp.int32)
            )
            tel.after_step(i, buf)
        jax.block_until_ready((state_a, state_b))

        def run_bare():
            nonlocal state_a
            t0 = time.perf_counter()
            state_a, mets = f_bare(state_a, batch)
            jax.block_until_ready(mets["loss"])
            return time.perf_counter() - t0

        def run_instrumented(i):
            nonlocal state_b
            t0 = time.perf_counter()
            state_b, mets, buf = f_tel(
                state_b, batch, tel.buf, jnp.asarray(i, jnp.int32)
            )
            jax.block_until_ready(mets["loss"])
            tel.note_step_time(i, time.perf_counter() - t0)
            tel.after_step(i, buf)  # real host drain inside the timed region
            return time.perf_counter() - t0

        t_bare, t_tel = [], []
        for i in range(2, steps + 2):
            if i % 2:  # alternate order so neither program owns a bias slot
                t_tel.append(run_instrumented(i))
                t_bare.append(run_bare())
            else:
                t_bare.append(run_bare())
                t_tel.append(run_instrumented(i))
        tel.finish()
        n_records = tel.n_records
    finally:
        sink.close()
        os.unlink(tmp)

    bare = np.asarray(t_bare)
    instr = np.asarray(t_tel)
    # paired estimator: per-iteration differences cancel common-mode noise;
    # the interquartile mean of the diffs discards the heavy scheduler tail
    # both programs suffer while averaging enough pairs to resolve sub-ms
    # effects (a plain median of 0.1s-scale quantiles cannot)
    diffs = np.sort(instr - bare)
    q = len(diffs) // 4
    iqm_diff = float(diffs[q : len(diffs) - q].mean())
    overhead = iqm_diff / float(np.median(bare))

    record = {
        "bench": "telemetry_overhead",
        "arch": cfg.name,
        "steps": steps,
        "flush_every": flush_every,
        "bare_step_p50_s": float(np.median(bare)),
        "bare_step_min_s": float(bare.min()),
        "instrumented_step_p50_s": float(np.median(instr)),
        "instrumented_step_min_s": float(instr.min()),
        "overhead_frac": overhead,
        "overhead_min_frac": float(instr.min() / bare.min() - 1.0),
        "budget_frac": 0.02,
        "within_budget": bool(overhead < 0.02),
        "n_records": n_records,
    }
    if out_json:
        with open(out_json, "w") as f:
            json.dump(record, f, indent=2)
    return [
        {
            "name": f"telemetry_bare_step_f{flush_every}",
            "us_per_call": round(float(np.median(bare)) * 1e6, 1),
            "derived": f"min={bare.min() * 1e6:.1f}us",
        },
        {
            "name": f"telemetry_instrumented_step_f{flush_every}",
            "us_per_call": round(float(np.median(instr)) * 1e6, 1),
            "derived": (
                f"overhead={overhead * 100:+.2f}% (budget <2%); "
                f"{n_records} records drained"
            ),
        },
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short run; report overhead but do not gate on the "
                         "<2% budget (CI CPU timing noise)")
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--flush-every", type=int, default=10)
    ap.add_argument("--out-json", default="BENCH_telemetry_overhead.json")
    ap.set_defaults(smoke=True)
    args = ap.parse_args(argv)

    rows = run(smoke=args.smoke, flush_every=args.flush_every,
               out_json=args.out_json)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}", flush=True)
    print(f"wrote {args.out_json}")
    if args.smoke:
        return 0
    with open(args.out_json) as f:
        return 0 if json.load(f)["within_budget"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
