"""Router overhead — validates the paper's "very small time costs" claim.

Times route() per strategy on CPU at the paper's gate sizes (n tokens ×
m experts) and reports µs/call plus overhead relative to the vanilla top-k
gate. On TPU the ADMM update is the Pallas kernel (~0.5 ms/iteration at
n=32k, m=128, see kernels/bip_admm.py cost model); the CPU numbers here are
for RELATIVE comparison between strategies only.

Sync sweep (``--sync`` / ``run_sync_sweep``): times the sync='global' dual
update variants on a forced 4x2 host mesh against per-shard 'local' duals —
the PR 5 classic-bisection path (fanout=1, data-dependent bounds), the fused
multi-threshold path (fanout=32, static score bounds), the fused path with an
oracle forecaster window (the warm-start upper bound), and the collective
Pallas kernel — and writes ``BENCH_router_sync.json`` with the measured
step times plus the analytic collective-round counts per dual iteration.
The mesh child re-executes this module under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (device count is
locked at jax import, so the parent cannot host the mesh itself).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import RouterConfig, init_router_state, route


def _time_call(fn, *args, iters: int = 20) -> float:
    fn(*args)  # compile
    jax.block_until_ready(fn(*args).combine_weights)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out.combine_weights)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(n: int = 8192, m: int = 64, k: int = 8) -> List[Dict]:
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.standard_normal((n, m)).astype(np.float32))
    rows = []
    base_us = None
    for strategy, t in [
        ("topk", 0), ("aux_loss", 0), ("lossfree", 0),
        ("bip", 2), ("bip", 4), ("bip", 8), ("bip", 14),
    ]:
        cfg = RouterConfig(
            n_experts=m, top_k=k, strategy=strategy, bip_iters=max(t, 1)
        )
        state = init_router_state(cfg)
        fn = jax.jit(lambda l, s, c=cfg: route(l, s, c))
        us = _time_call(fn, logits, state)
        if strategy == "topk":
            base_us = us
        name = strategy if strategy != "bip" else f"bip_T{t}"
        rows.append(
            {
                "name": f"router_{name}_n{n}_m{m}",
                "us_per_call": round(us, 1),
                "derived": f"overhead_vs_topk={us / base_us:.2f}x",
            }
        )
    return rows


# ------------------------------------------------- sync-mode sweep (mesh)


def _sync_sweep_mesh_body(smoke: bool) -> Dict:
    """Runs INSIDE the forced-8-device child: mesh timings + round counts."""
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.core.ref_bip import (
        bip_dual_update,
        bip_dual_update_global,
        bisect_rounds,
    )
    from repro.kernels import ops as kernel_ops
    from repro.models.moe import _shard_map

    n_local = 256 if smoke else 1024
    m, k = 64, 8
    t_iters = 2 if smoke else 4
    iters = 5 if smoke else 20
    n_bisect, fanout = 26, 32

    mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
    n_glob = n_local * 4  # data-axis size
    rng = np.random.default_rng(0)
    logits = rng.standard_normal((n_glob, m)) + 1.5 * np.linspace(2, -2, m)[None, :]
    e = np.exp(logits - logits.max(-1, keepdims=True))
    s = jnp.asarray((e / e.sum(-1, keepdims=True)).astype(np.float32))
    q0 = jnp.zeros((m,), jnp.float32)

    def shard(fn):
        return jax.jit(_shard_map(
            fn, mesh=mesh, in_specs=(P("data", None), P(None)), out_specs=P(None)
        ))

    # oracle forecaster window: the true pre-clamp statistic of this batch
    # +- a tight margin (best-case warm-start; the trained EMA approaches it)
    _, _, t_stat = bip_dual_update_global(
        s, q0, top_k=k, n_iters=t_iters, n_bisect=n_bisect, fanout=fanout,
        score_bounds=(0.0, 1.0), with_stats=True,
    )
    w = (t_stat - 1e-5, t_stat + 1e-5)

    variants = {
        # per-shard duals + the production path's single warm-start pmean
        "local": lambda sl, q: jax.lax.pmean(
            bip_dual_update(sl, q, top_k=k, n_iters=t_iters)[0], ("data",)
        ),
        # PR 5 shape: classic bisection, data-dependent pmin/pmax bounds
        "global_pr5_fanout1": lambda sl, q: bip_dual_update_global(
            sl, q, top_k=k, n_iters=t_iters, axis_names=("data",),
            n_bisect=n_bisect, fanout=1,
        )[0],
        # this PR: fused multi-threshold rounds + static score bounds
        "global_fused": lambda sl, q: bip_dual_update_global(
            sl, q, top_k=k, n_iters=t_iters, axis_names=("data",),
            n_bisect=n_bisect, fanout=fanout, score_bounds=(0.0, 1.0),
        )[0],
        # + oracle warm-start window (convergence skips trailing rounds)
        "global_fused_warm": lambda sl, q: bip_dual_update_global(
            sl, q, top_k=k, n_iters=t_iters, axis_names=("data",),
            n_bisect=n_bisect, fanout=fanout, score_bounds=(0.0, 1.0), window=w,
        )[0],
        # collective Pallas ADMM kernel (psum'd histogram counts)
        "kernel_collective": lambda sl, q: kernel_ops.bip_dual_update(
            sl, q, top_k=k, n_iters=t_iters, axis_names=("data",)
        ),
    }

    rounds_pr5 = bisect_rounds(n_bisect, 1) + 2  # + pmin/pmax bound pair
    rounds_fused = bisect_rounds(n_bisect, fanout)
    counts = {
        "local": 0,
        "global_pr5_fanout1": rounds_pr5,
        "global_fused": rounds_fused,
        "global_fused_warm": rounds_fused,  # worst case; warm rounds converge early
        "kernel_collective": 1,  # one (m, n_bins) histogram psum
    }

    rows = []
    t_local = None
    with mesh:
        for name, fn in variants.items():
            sfn = shard(fn)
            jax.block_until_ready(sfn(s, q0))  # compile
            jax.block_until_ready(sfn(s, q0))
            t0 = time.perf_counter()
            for _ in range(iters):
                out = sfn(s, q0)
            jax.block_until_ready(out)
            us = (time.perf_counter() - t0) / iters * 1e6
            if name == "local":
                t_local = us
            rows.append({
                "name": f"dual_sync_{name}_n{n_glob}_m{m}_T{t_iters}",
                "us_per_call": round(us, 1),
                "derived": (
                    f"collectives_per_iter={counts[name]};"
                    f"vs_local={us / t_local:.2f}x"
                ),
            })

    # full router step (route(): scores + dual update + top-k dispatch +
    # metrics) — the ratio that prices global sync for a training step
    logits_j = jnp.asarray(
        rng.standard_normal((n_glob, m)).astype(np.float32)
        + 1.5 * np.linspace(2, -2, m)[None, :].astype(np.float32)
    )
    base = dict(n_experts=m, top_k=k, strategy="bip", bip_iters=t_iters,
                data_axes=("data",), n_bisect=n_bisect, bisect_fanout=fanout)
    route_cfgs = {
        "local": RouterConfig(sync="local", **base),
        "global_fused": RouterConfig(sync="global", **base),
        "global_forecast": RouterConfig(sync="global", forecast=True, **base),
        "global_kernel": RouterConfig(sync="global", use_kernel=True, **base),
    }
    t_route_local = None
    with mesh:
        for name, cfg in route_cfgs.items():
            st0 = init_router_state(cfg)
            specs = jax.tree.map(lambda _: P(None), st0)

            def block(lg, st, cfg=cfg):
                out = route(lg, st, cfg)
                new = dict(out.state)
                if cfg.sync == "local":
                    new["q"] = jax.lax.pmean(new["q"], ("data",))
                return out.combine_weights, new

            sfn = jax.jit(_shard_map(
                block, mesh=mesh,
                in_specs=(P("data", None), specs),
                out_specs=(P("data", None), specs),
            ))
            st = st0
            for _ in range(3):  # prime: warm duals + forecaster EMAs
                w_out, st = sfn(logits_j, st)
            jax.block_until_ready(w_out)
            t0 = time.perf_counter()
            for _ in range(iters):
                w_out, _ = sfn(logits_j, st)
            jax.block_until_ready(w_out)
            us = (time.perf_counter() - t0) / iters * 1e6
            if name == "local":
                t_route_local = us
            rows.append({
                "name": f"route_step_{name}_n{n_glob}_m{m}_T{t_iters}",
                "us_per_call": round(us, 1),
                "derived": f"vs_local={us / t_route_local:.2f}x",
            })

    return {
        "config": {
            "mesh": "4x2 forced host devices", "n_global": n_glob, "m": m,
            "k": k, "bip_iters": t_iters, "n_bisect": n_bisect,
            "bisect_fanout": fanout, "timing_iters": iters, "smoke": smoke,
        },
        "collective_rounds_per_iter": {
            "pr5_classic_bisection": rounds_pr5,
            "fused_multi_threshold": rounds_fused,
            "reduction": f"{rounds_pr5 / rounds_fused:.1f}x",
        },
        "rows": rows,
    }


def run_sync_sweep(smoke: bool = False, out_path: str = "BENCH_router_sync.json") -> List[Dict]:
    """Spawn the forced-8-device child, collect its JSON, write the artifact."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("PYTHONPATH", "src")
    args = [sys.executable, "-m", "benchmarks.router_overhead", "--sync-child"]
    if smoke:
        args.append("--smoke")
    out = subprocess.run(args, capture_output=True, text=True, env=env, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(f"sync sweep child failed:\n{out.stderr[-3000:]}")
    result = json.loads(out.stdout.splitlines()[-1])
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    return result["rows"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small sizes, few iters")
    ap.add_argument("--sync", action="store_true",
                    help="run the mesh sync sweep (writes BENCH_router_sync.json)")
    ap.add_argument("--sync-child", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args.sync_child:
        print(json.dumps(_sync_sweep_mesh_body(smoke=args.smoke)), flush=True)
        return
    if args.sync:
        for r in run_sync_sweep(smoke=args.smoke):
            print(f"{r['name']},{r['us_per_call']},{r['derived']}")
        return
    for r in run():
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")


if __name__ == "__main__":
    main()
