"""Router overhead — validates the paper's "very small time costs" claim.

Times route() per strategy on CPU at the paper's gate sizes (n tokens ×
m experts) and reports µs/call plus overhead relative to the vanilla top-k
gate. On TPU the ADMM update is the Pallas kernel (~0.5 ms/iteration at
n=32k, m=128, see kernels/bip_admm.py cost model); the CPU numbers here are
for RELATIVE comparison between strategies only.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import RouterConfig, init_router_state, route


def _time_call(fn, *args, iters: int = 20) -> float:
    fn(*args)  # compile
    jax.block_until_ready(fn(*args).combine_weights)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out.combine_weights)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(n: int = 8192, m: int = 64, k: int = 8) -> List[Dict]:
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.standard_normal((n, m)).astype(np.float32))
    rows = []
    base_us = None
    for strategy, t in [
        ("topk", 0), ("aux_loss", 0), ("lossfree", 0),
        ("bip", 2), ("bip", 4), ("bip", 8), ("bip", 14),
    ]:
        cfg = RouterConfig(
            n_experts=m, top_k=k, strategy=strategy, bip_iters=max(t, 1)
        )
        state = init_router_state(cfg)
        fn = jax.jit(lambda l, s, c=cfg: route(l, s, c))
        us = _time_call(fn, logits, state)
        if strategy == "topk":
            base_us = us
        name = strategy if strategy != "bip" else f"bip_T{t}"
        rows.append(
            {
                "name": f"router_{name}_n{n}_m{m}",
                "us_per_call": round(us, 1),
                "derived": f"overhead_vs_topk={us / base_us:.2f}x",
            }
        )
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")
