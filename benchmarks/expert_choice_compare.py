"""BIP vs Expert-Choice — two drop-free balancing philosophies, quantified.

Expert-Choice gets MaxVio == 0 for free but pays in token coverage and
objective mass, and cannot serve autoregressive decode. BIP keeps the
token-choice contract (every token gets exactly k experts, decode-safe)
with MaxVio ~= 0.05-0.3. This benchmark puts numbers on that trade over
skewed score streams, including the LP upper bound from the scipy oracle.

    PYTHONPATH=src python -m benchmarks.expert_choice_compare
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import balance_metrics, bip_route_reference
from repro.core.expert_choice import expert_choice_route
from repro.core.lp_oracle import routing_objective, solve_plp


def run(n: int = 256, m: int = 8, k: int = 2, skew: float = 1.5, seeds=(0, 1, 2)):
    rows = []
    for seed in seeds:
        rng = np.random.default_rng(seed)
        logits = rng.standard_normal((n, m)) + skew * np.linspace(2, -2, m)[None, :]
        e = np.exp(logits - logits.max(-1, keepdims=True))
        s = jnp.asarray((e / e.sum(-1, keepdims=True)).astype(np.float32))

        _, lp_opt = solve_plp(np.asarray(s), k)

        _, idx, _ = bip_route_reference(s, jnp.zeros((m,)), top_k=k, n_iters=8)
        bip_obj = routing_objective(np.asarray(s), np.asarray(idx))
        bip_vio = float(balance_metrics(idx, m, k)["max_vio"])

        gates, mets = expert_choice_route(s, k)
        rows.append({
            "seed": seed,
            "lp_opt": lp_opt,
            "bip_obj_ratio": bip_obj / lp_opt,
            "bip_max_vio": bip_vio,
            "ec_obj_ratio": float(mets["objective"]) / lp_opt,
            "ec_max_vio": 0.0,
            "ec_coverage_full": float(mets["coverage_full"]),
            "ec_coverage_zero": float(mets["coverage_zero"]),
        })
    return rows


def main():
    rows = run()
    agg = {k: float(np.mean([r[k] for r in rows])) for k in rows[0] if k != "seed"}
    print(f"{'':<18}{'obj/LP-opt':>12}{'MaxVio':>9}{'full-cov':>10}{'zero-cov':>10}")
    print(f"{'BIP T=8':<18}{agg['bip_obj_ratio']:>12.3f}{agg['bip_max_vio']:>9.3f}"
          f"{'1.000':>10}{'0.000':>10}")
    print(f"{'Expert-Choice':<18}{agg['ec_obj_ratio']:>12.3f}{0.0:>9.3f}"
          f"{agg['ec_coverage_full']:>10.3f}{agg['ec_coverage_zero']:>10.3f}")
    print("\nBIP keeps every token at exactly k experts (decode-safe) at the")
    print("cost of small MaxVio; Expert-Choice zeroes MaxVio but strands")
    print(f"{agg['ec_coverage_zero']:.1%} of tokens with no expert at all.")
    return [
        {"name": "ec_compare_bip", "us_per_call": round(agg["bip_obj_ratio"], 4),
         "derived": f"obj_ratio;maxvio={agg['bip_max_vio']:.3f}"},
        {"name": "ec_compare_expert_choice", "us_per_call": round(agg["ec_obj_ratio"], 4),
         "derived": f"obj_ratio;zero_cov={agg['ec_coverage_zero']:.3f}"},
    ]


if __name__ == "__main__":
    main()
