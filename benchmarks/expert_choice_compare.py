"""BIP vs Expert-Choice — two drop-free balancing philosophies, quantified.

Expert-Choice gets MaxVio == 0 for free but pays in token coverage and
objective mass, and cannot serve autoregressive decode. BIP keeps the
token-choice contract (every token gets exactly k experts, decode-safe)
with MaxVio ~= 0.05-0.3. This benchmark puts numbers on that trade over
skewed score streams, including the LP upper bound from the scipy oracle.

Both methods now run through the registry-backed `route()` via
`benchmarks.balance_sweep.router_level_compare` — the same code path the
training sweeps use (this script's historical private wiring around
bip_route_reference / expert_choice_route is retired), and the same
columns land in BENCH_balance_matrix.json's router_level section for ALL
registered methods. This entry point keeps the focused two-method table
and its CSV contract (`ec_compare_bip` / `ec_compare_expert_choice`).

    PYTHONPATH=src python -m benchmarks.expert_choice_compare
"""
from __future__ import annotations

import numpy as np

from benchmarks.balance_sweep import router_level_compare


def run(n: int = 256, m: int = 8, k: int = 2, skew: float = 1.5, seeds=(0, 1, 2)):
    rows = []
    for rec in router_level_compare(
        methods=("bip", "expert_choice"), n=n, m=m, k=k, skew=skew, seeds=seeds
    ):
        bip, ec = rec["methods"]["bip"], rec["methods"]["expert_choice"]
        rows.append({
            "seed": rec["seed"],
            "lp_opt": rec["lp_opt"],
            "bip_obj_ratio": bip["obj_ratio"],
            "bip_max_vio": bip["max_vio"],
            "ec_obj_ratio": ec["obj_ratio"],
            "ec_max_vio": ec["max_vio"],
            "ec_coverage_full": ec["coverage_full"],
            "ec_coverage_zero": ec["coverage_zero"],
        })
    return rows


def main():
    rows = run()
    agg = {k: float(np.mean([r[k] for r in rows])) for k in rows[0] if k != "seed"}
    print(f"{'':<18}{'obj/LP-opt':>12}{'MaxVio':>9}{'full-cov':>10}{'zero-cov':>10}")
    print(f"{'BIP T=8':<18}{agg['bip_obj_ratio']:>12.3f}{agg['bip_max_vio']:>9.3f}"
          f"{'1.000':>10}{'0.000':>10}")
    print(f"{'Expert-Choice':<18}{agg['ec_obj_ratio']:>12.3f}"
          f"{max(agg['ec_max_vio'], 0.0):>9.3f}"
          f"{agg['ec_coverage_full']:>10.3f}{agg['ec_coverage_zero']:>10.3f}")
    print("\nBIP keeps every token at exactly k experts (decode-safe) at the")
    print("cost of small MaxVio; Expert-Choice zeroes MaxVio but strands")
    print(f"{agg['ec_coverage_zero']:.1%} of tokens with no expert at all.")
    return [
        {"name": "ec_compare_bip", "us_per_call": round(agg["bip_obj_ratio"], 4),
         "derived": f"obj_ratio;maxvio={agg['bip_max_vio']:.3f}"},
        {"name": "ec_compare_expert_choice", "us_per_call": round(agg["ec_obj_ratio"], 4),
         "derived": f"obj_ratio;zero_cov={agg['ec_coverage_zero']:.3f}"},
    ]


if __name__ == "__main__":
    main()
