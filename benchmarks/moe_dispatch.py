"""Dispatch/FFN microbenchmark: sort-based ragged plan vs one-hot/cumsum.

    PYTHONPATH=src python -m benchmarks.moe_dispatch           # full shapes
    PYTHONPATH=src python -m benchmarks.moe_dispatch --smoke   # CI guard

Measures, for the minimind-moe-16e (m=16, k=4) and 64e (m=64, k=8) routing
shapes at d_model=512:

1. dispatch+combine wall-clock — the seed formulation ((n·k, m) one-hot,
   serial cumsum, repeat(x, k) + scatter-add pack, clamped-index gather
   combine) vs the sort-based DispatchPlan (stable argsort + segment
   offsets, pack/combine as pure gathers). An identity "FFN" isolates the
   bookkeeping + data movement from the expert GEMMs.
2. a jaxpr audit of the new path: no intermediate of shape (n·k, m) may
   appear (the one-hot/cumsum bookkeeping is gone, not just faster).
3. grouped expert FFN: einsum vs the Pallas kernel pair. On CPU the kernels
   execute in interpret mode (Python per grid cell), so this row is a
   correctness/robustness exercise there; set REPRO_PALLAS_INTERPRET=0 on
   TPU for a real comparison.

Emits ``name,us_per_call,derived`` CSV lines (repo contract) and writes
BENCH_moe_dispatch.json with tokens/s and dispatch-µs per shape.
"""
from __future__ import annotations

import argparse
import json
import time

SHAPES = {
    # name -> (n_experts, top_k, d_model)  [minimind-moe configs, Table 1]
    "minimind-moe-16e": (16, 4, 512),
    "minimind-moe-64e": (64, 8, 512),
}


def _old_dispatch(x, idx, w, m, cap, k):
    """Seed formulation, frozen for comparison (see models/moe history)."""
    import jax
    import jax.numpy as jnp

    n, d = x.shape
    flat = idx.reshape(-1)
    onehot = jax.nn.one_hot(flat, m, dtype=jnp.int32)  # (n*k, m)
    pos_flat = jnp.cumsum(onehot, axis=0) - 1
    pos = jnp.take_along_axis(pos_flat, flat[:, None], axis=1)[:, 0]
    keep = pos < cap
    src = jnp.repeat(x, k, axis=0) * keep[:, None]
    buf = jnp.zeros((m, cap, d), x.dtype)
    buf = buf.at[flat, jnp.where(keep, pos, 0)].add(
        jnp.where(keep[:, None], src, 0.0)
    )
    y = buf  # identity FFN: isolate dispatch + combine
    gathered = y[flat, jnp.where(keep, pos, 0)]
    contrib = jnp.where(keep[:, None], gathered * w.reshape(-1, 1), 0.0)
    return contrib.reshape(n, k, d).sum(axis=1)


def _new_dispatch(x, idx, w, m, cap):
    from repro.core.router import make_dispatch_plan

    plan = make_dispatch_plan(idx, m, cap)
    buf = plan.pack(x)
    return plan.combine(buf, w)


def _assert_no_nk_m_intermediate(fn, args, nk, m):
    """Audit every equation in the jaxpr (incl. sub-jaxprs): no (n·k, m)."""
    import jax

    jaxpr = jax.make_jaxpr(fn)(*args)

    def walk(jp):
        for eqn in jp.eqns:
            for v in list(eqn.outvars) + list(eqn.invars):
                aval = getattr(v, "aval", None)
                if aval is not None and tuple(getattr(aval, "shape", ())) == (nk, m):
                    raise AssertionError(
                        f"(n*k, m)=({nk}, {m}) intermediate found: {eqn.primitive}"
                    )
            for sub in eqn.params.values():
                if hasattr(sub, "jaxpr"):
                    walk(sub.jaxpr)

    walk(jaxpr.jaxpr)


def _time(fn, args, iters):
    import jax

    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(smoke: bool = False, out_path: str = "BENCH_moe_dispatch.json"):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops, ref

    token_counts = [2048] if smoke else [8192, 32768]
    iters = 2 if smoke else 5
    rows = []
    results = {"smoke": smoke, "backend": jax.default_backend(), "shapes": []}
    rng = np.random.default_rng(0)

    for name, (m, k, d) in SHAPES.items():
        for n in token_counts:
            cap = int(np.ceil(k * n / m * 1.25))
            idx = jnp.asarray(rng.integers(0, m, (n, k)), jnp.int32)
            x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
            w = jnp.asarray(rng.random((n, k)), jnp.float32)

            f_old = jax.jit(lambda x, i, w: _old_dispatch(x, i, w, m, cap, k))
            f_new = jax.jit(lambda x, i, w: _new_dispatch(x, i, w, m, cap))
            np.testing.assert_allclose(
                np.asarray(f_old(x, idx, w)),
                np.asarray(f_new(x, idx, w)),
                atol=1e-5,
            )
            _assert_no_nk_m_intermediate(f_new, (x, idx, w), n * k, m)

            t_old = _time(f_old, (x, idx, w), iters)
            t_new = _time(f_new, (x, idx, w), iters)
            rec = {
                "config": name,
                "n_tokens": n,
                "n_experts": m,
                "top_k": k,
                "d_model": d,
                "capacity": cap,
                "dispatch_us_onehot": round(t_old * 1e6, 1),
                "dispatch_us_sorted": round(t_new * 1e6, 1),
                "speedup": round(t_old / t_new, 2),
                "tokens_per_s_onehot": round(n / t_old, 1),
                "tokens_per_s_sorted": round(n / t_new, 1),
                "no_nk_m_intermediate": True,
            }
            results["shapes"].append(rec)
            rows.append({
                "name": f"moe_dispatch_{name}_n{n}",
                "us_per_call": rec["dispatch_us_sorted"],
                "derived": (
                    f"onehot={rec['dispatch_us_onehot']}us;"
                    f"speedup={rec['speedup']}x;"
                    f"tok/s={rec['tokens_per_s_sorted']:.0f}"
                ),
            })

    # grouped FFN: einsum vs Pallas pair (interpret mode off-TPU — see module
    # docstring; kept small so the CI smoke stays cheap)
    for name, (m, k, d) in SHAPES.items():
        # small shapes: interpret mode executes the kernel body per grid
        # cell in Python, so the FFN row stays a bounded-cost exercise off-TPU
        f = 256 if smoke else 1408
        n_ffn = 128 if smoke else 512
        cap = int(np.ceil(k * n_ffn / m * 1.25))
        xb = jnp.asarray(rng.standard_normal((m, cap, d)), jnp.float32) * 0.3
        wg = jnp.asarray(rng.standard_normal((m, d, f)), jnp.float32) * 0.05
        wu = jnp.asarray(rng.standard_normal((m, d, f)), jnp.float32) * 0.05
        wd = jnp.asarray(rng.standard_normal((m, f, d)), jnp.float32) * 0.05
        fn_e = jax.jit(lambda *a: ref.expert_ffn_ref(*a))
        fn_p = jax.jit(lambda *a: ops.expert_ffn(*a))
        t_e = _time(fn_e, (xb, wg, wu, wd), max(1, iters - 1))
        t_p = _time(fn_p, (xb, wg, wu, wd), 1)
        flops = 6 * m * cap * d * f
        rec = {
            "config": name,
            "ffn_tokens": n_ffn,
            "capacity": cap,
            "ffn_us_einsum": round(t_e * 1e6, 1),
            "ffn_us_pallas": round(t_p * 1e6, 1),
            "ffn_flops": flops,
            "pallas_interpret": ops._interpret_default(),
        }
        results["shapes"].append(rec)
        rows.append({
            "name": f"moe_ffn_{name}_c{cap}",
            "us_per_call": rec["ffn_us_pallas"],
            "derived": (
                f"einsum={rec['ffn_us_einsum']}us;flops={flops:.2e};"
                f"interpret={rec['pallas_interpret']}"
            ),
        })

    with open(out_path, "w") as fh:
        json.dump(results, fh, indent=2)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny shapes for CI")
    ap.add_argument("--out", default="BENCH_moe_dispatch.json")
    args = ap.parse_args()
    for r in run(smoke=args.smoke, out_path=args.out):
        print(f"{r['name']},{r['us_per_call']},{r['derived']}", flush=True)


if __name__ == "__main__":
    main()
