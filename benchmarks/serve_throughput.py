"""Serving throughput under a synthetic Poisson request stream.

    PYTHONPATH=src python -m benchmarks.serve_throughput \
        --arch minimind_moe_16e --reduced --requests 32 --rate 50

Two measurements (DESIGN.md §Serving):

1. Prefill throughput: the same prompt batch prefilled (a) the seed way —
   one token per jit'd decode_step call in a host loop — and (b) through the
   engine's chunked prefill. Reports tokens/s for both and the speedup
   (acceptance: >= 5x on the reduced minimind-moe-16e).

2. Continuous batching under load: requests with Poisson arrivals and mixed
   prompt/output lengths stream through the slot pool; reports end-to-end
   tokens/s, step count, and the per-expert load histogram accumulated over
   every serve step — the BIP router should keep MaxVio small even though
   prefill chunks and single decode tokens share each router invocation.
   With ``--deadline-ms`` / ``--queue-timeout-ms`` the same stream also
   measures overload degradation (DESIGN.md §Robustness): deadline-miss
   rate and shed/timeout count ride along in the output, so "how gracefully
   does it fail" is benchmarked next to "how fast does it go".

3. Bursty multi-tenant sweep: requests arrive in Poisson BURSTS (compound
   Poisson — burst epochs are exponential, burst sizes geometric), each
   from one of a few tenants with Zipf-skewed popularity. A tenant draws
   its prompt tokens from its own vocabulary slice — tenant skew is topic
   skew, the serving analogue of the real-text routing-skew sweep — and
   its own prompt/output length profile (short chat vs long documents, so
   packed prefill and spreading both engage). The sweep runs the SAME
   streams at several offered loads through an unsharded engine and (with
   ``--mesh DxM``) an expert-parallel mesh engine, reporting p50/p99 TTFT
   and inter-token latency vs offered load, tokens/s/device, and the
   per-expert MaxVio under live traffic through the SLO plane.

Prints ``name,us_per_call,derived`` CSV lines per the repo contract;
``--out-json`` additionally writes the BENCH_serve_throughput record.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


# ------------------------------------------------- multi-tenant generator


def make_multitenant_stream(
    seed: int,
    vocab_size: int,
    n_requests: int,
    rate: float,
    max_prompt: int,
    max_gen: int,
    n_tenants: int = 4,
    burst_mean: float = 3.0,
):
    """Compound-Poisson bursty arrivals from Zipf-popular tenants.

    Returns [(t_arrival, tenant, prompt ndarray, n_gen)] sorted by time.
    `rate` is the OFFERED LOAD in requests/s: burst epochs are Poisson at
    rate/burst_mean and each burst carries Geometric(1/burst_mean) requests
    back-to-back, so the long-run request rate is `rate` but arrivals
    cluster — the regime where queue depth, TTFT tails, and routing skew
    actually separate schedulers. Tenant t draws prompt tokens from its own
    slice of the vocabulary (topic skew -> routing skew) and has its own
    length profile: even tenants are "chat" (short prompts, short outputs),
    odd tenants are "document" (long prompts that exercise packed prefill
    spreading, longer outputs)."""
    rng = np.random.default_rng(seed)
    # Zipf tenant popularity: tenant 0 dominates the stream
    pop = 1.0 / np.arange(1, n_tenants + 1)
    pop = pop / pop.sum()
    slice_w = vocab_size // n_tenants
    out = []
    t = 0.0
    while len(out) < n_requests:
        t += rng.exponential(burst_mean / rate)  # burst epoch
        size = 1 + rng.geometric(1.0 / burst_mean)
        for _ in range(min(size, n_requests - len(out))):
            tenant = int(rng.choice(n_tenants, p=pop))
            if tenant % 2 == 0:  # chat profile
                plen = int(rng.integers(4, max(max_prompt // 4, 5)))
                gen = int(rng.integers(4, max_gen + 1))
            else:  # document profile
                plen = int(rng.integers(max_prompt // 2, max_prompt + 1))
                gen = int(rng.integers(2, max(max_gen // 2, 3)))
            lo = tenant * slice_w
            prompt = rng.integers(lo, lo + slice_w, (plen,))
            out.append((t, tenant, prompt, gen))
    return out


def _drive(eng, stream, n_devices: int = 1):
    """Replay an arrival-stamped stream through an engine; returns the
    measured-phase summary (SLO quantiles, throughput, expert balance)."""
    # warm both traced programs outside the timed phase: a short prompt
    # compiles the legacy step, a lone long prompt (> chunk, idle rows to
    # spread into) compiles the packed-prefill step
    wlen = max(len(p) for _, _, p, _ in stream)
    for toks in ([1, 2, 3], [1] * wlen):
        warm = eng.submit(toks, 2, ignore_eos=True)
        assert warm is not None
        eng.run()
    eng.telemetry.reset()

    t0 = time.perf_counter()
    pending = list(stream)
    n_done = 0
    while pending or eng.scheduler.has_work:
        now = time.perf_counter() - t0
        while pending and pending[0][0] <= now:
            a, _tenant, p, g = pending[0]
            if eng.submit(p, g, ignore_eos=True, arrival_time=a) is None:
                break  # backpressure: queue full, keep stepping
            pending.pop(0)
        if eng.scheduler.has_work:
            n_done += len(eng.step())
        elif pending:
            time.sleep(min(0.001, max(pending[0][0] - now, 0.0)))
    wall = time.perf_counter() - t0

    slo = eng.telemetry.summary()
    tp = eng.telemetry.throughput(wall, n_devices)
    load = eng.expert_load
    mean = max(load.mean(), 1e-9)
    return {
        "n_completed": n_done,
        "n_steps": eng.n_steps,
        "wall_s": wall,
        "tokens_per_s": tp["tokens_per_s"],
        "tokens_per_s_per_device": tp["tokens_per_s_per_device"],
        "n_devices": n_devices,
        "ttft_p50": slo["ttft"]["p50"],
        "ttft_p99": slo["ttft"]["p99"],
        "itl_p50": slo["itl"]["p50"],
        "itl_p99": slo["itl"]["p99"],
        "queue_depth_max": slo["queue_depth_max"],
        "expert_maxvio": float(load.max() / mean - 1.0),
        "expert_load": [float(x) for x in load],
    }


def _per_token_prefill_tps(model, params, prompts, max_seq_len) -> float:
    """Seed ServeEngine.prefill semantics: one decode_step per position."""
    import jax
    import jax.numpy as jnp

    decode = jax.jit(model.decode_step)
    states = model.init_router_states()
    cache = model.init_cache(params, {"tokens": prompts}, max_seq_len)
    logits, cache2, states2 = decode(params, prompts[:, :1], cache, states)
    jax.block_until_ready(logits)  # compile outside the timed region

    cache = model.init_cache(params, {"tokens": prompts}, max_seq_len)
    st = model.init_router_states()
    t0 = time.perf_counter()
    for t in range(prompts.shape[1]):
        logits, cache, st = decode(params, prompts[:, t : t + 1], cache, st)
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    return prompts.size / dt


def _chunked_prefill_tps(model, params, prompts, max_seq_len, chunk) -> float:
    import jax
    import jax.numpy as jnp

    b, s = prompts.shape
    pad = (-s) % chunk
    padded = jnp.pad(prompts, ((0, 0), (0, pad)))
    step = jax.jit(model.prefill_chunk)
    lengths_full = jnp.full((b,), chunk, jnp.int32)
    lengths_tail = jnp.full((b,), s - (s // chunk) * chunk or chunk, jnp.int32)

    def run():
        cache = model.init_slot_cache(params, b, max_seq_len)
        st = model.init_router_states()
        logits = None
        for t in range(0, s, chunk):
            lengths = lengths_full if t + chunk <= s else lengths_tail
            logits, cache, st, _ = step(
                params, padded[:, t : t + chunk], cache, st, lengths
            )
        jax.block_until_ready(logits)

    run()  # compile
    t0 = time.perf_counter()
    run()
    dt = time.perf_counter() - t0
    return prompts.size / dt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minimind_moe_16e")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--n-slots", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=32)
    ap.add_argument("--max-seq-len", type=int, default=256)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--prefill-batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=100.0, help="Poisson req/s")
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    # robustness / overload knobs (DESIGN.md §Robustness)
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request latency budget for the Poisson stream")
    ap.add_argument("--queue-timeout-ms", type=float, default=None,
                    help="max admission wait before a request times out")
    ap.add_argument("--shed-on-full", action="store_true",
                    help="shed oldest waiting request instead of refusing "
                         "new submissions under backpressure")
    # bursty multi-tenant sweep knobs
    ap.add_argument("--rates", default="50,200",
                    help="comma-separated offered loads (req/s) for the "
                         "multi-tenant sweep")
    ap.add_argument("--sweep-requests", type=int, default=24,
                    help="requests per sweep point (smoke uses fewer)")
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="also sweep an expert-parallel engine on a "
                         "(data D x model M) host mesh; run under "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    ap.add_argument("--out-json", default=None,
                    help="write the BENCH_serve_throughput record here")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: report everything but do not gate on "
                         "the >=5x prefill-speedup acceptance")
    args = ap.parse_args(argv)

    import jax.numpy as jnp

    from repro import configs
    from repro.models import build_model
    from repro.serving import ContinuousBatchingEngine

    import jax

    cfg = (
        configs.reduced_for_smoke(args.arch, vocab_size=512)
        if args.reduced
        else configs.get(args.arch)
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    # ---- 1. prefill: seed per-token loop vs chunked --------------------
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.prefill_batch, args.prompt_len)),
        jnp.int32,
    )
    tps_seed = _per_token_prefill_tps(model, params, prompts, args.max_seq_len)
    tps_chunk = _chunked_prefill_tps(
        model, params, prompts, args.max_seq_len, args.chunk
    )
    speedup = tps_chunk / tps_seed
    print(f"prefill_per_token,{1e6 / tps_seed:.2f},{tps_seed:.0f} tok/s")
    print(f"prefill_chunked,{1e6 / tps_chunk:.2f},{tps_chunk:.0f} tok/s")
    print(f"prefill_speedup,,{speedup:.2f}x")

    # ---- 2. Poisson stream through the engine --------------------------
    eng = ContinuousBatchingEngine(
        model,
        params,
        n_slots=args.n_slots,
        chunk_size=args.chunk,
        max_seq_len=args.max_seq_len,
        seed=args.seed,
        default_deadline=(
            args.deadline_ms / 1e3 if args.deadline_ms else None
        ),
        queue_timeout=(
            args.queue_timeout_ms / 1e3 if args.queue_timeout_ms else None
        ),
        shed_on_full=args.shed_on_full,
    )
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, size=args.requests))
    reqs = []
    for a in arrivals:
        plen = int(rng.integers(8, args.prompt_len + 1))
        gen = int(rng.integers(4, args.gen + 1))
        reqs.append(
            (a, rng.integers(0, cfg.vocab_size, (plen,)), gen)
        )

    # warm the trace (one tiny request), then reset telemetry so the
    # measured phase starts from clean counters and SLO histograms
    r = eng.submit([1, 2, 3], 2, ignore_eos=True)
    eng.run()
    eng.telemetry.reset()

    t0 = time.perf_counter()
    pending = list(reqs)
    n_done = 0
    while pending or eng.scheduler.has_work:
        now = time.perf_counter() - t0
        while pending and pending[0][0] <= now:
            a, p, g = pending[0]
            if eng.submit(p, g, ignore_eos=True, arrival_time=a) is None:
                break  # backpressure: queue full, keep stepping
            pending.pop(0)
        if eng.scheduler.has_work:
            n_done += len(eng.step())
        elif pending:
            time.sleep(min(0.001, pending[0][0] - now))
    wall = time.perf_counter() - t0

    total = eng.prefill_tokens + eng.decode_tokens
    print(f"serve_stream,{1e6 * wall / max(total, 1):.2f},"
          f"{total / wall:.0f} tok/s ({n_done} reqs, {eng.n_steps} steps)")
    miss_rate = eng.n_deadline_missed / max(args.requests, 1)
    print(f"serve_deadline_miss_rate,,{miss_rate:.3f} "
          f"({eng.n_deadline_missed}/{args.requests})")
    print(f"serve_shed,,{eng.n_shed}")
    slo = eng.telemetry.summary()
    print(f"serve_ttft_p50,{1e6 * slo['ttft']['p50']:.2f},"
          f"p99 {1e3 * slo['ttft']['p99']:.2f} ms")
    print(f"serve_itl_p50,{1e6 * slo['itl']['p50']:.2f},"
          f"p99 {1e3 * slo['itl']['p99']:.2f} ms")
    print(f"serve_queue_depth,,max {slo['queue_depth_max']} "
          f"mean {slo['queue_depth_mean']:.1f}")
    maxvio = None
    if cfg.is_moe:
        load = eng.expert_load
        mean = max(load.mean(), 1e-9)
        maxvio = load.max() / mean - 1.0
        print(f"serve_expert_maxvio,,{maxvio:.3f}")
        print("serve_expert_load,," + "|".join(f"{x:.0f}" for x in load))

    # ---- 3. bursty multi-tenant offered-load sweep ---------------------
    # One engine per placement, reused across rates (the jit caches live on
    # the engine); each point replays a fresh arrival-stamped stream.
    rates = [float(r) for r in args.rates.split(",") if r]
    n_sweep = max(args.sweep_requests // 2, 6) if args.smoke else args.sweep_requests
    engines = [("local", eng, 1)]
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_host_mesh

        d, m = (int(v) for v in args.mesh.lower().split("x"))
        if jax.device_count() < d * m:
            print(
                f"serve_sweep_mesh_skipped,,need {d * m} devices, have "
                f"{jax.device_count()} (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={d * m})"
            )
            args.mesh = None
    if args.mesh:
        mesh = make_host_mesh(d, m)
        eng_mesh = ContinuousBatchingEngine(
            model,
            params,
            n_slots=args.n_slots,
            chunk_size=args.chunk,
            max_seq_len=args.max_seq_len,
            seed=args.seed,
            mesh=mesh,
        )
        engines.append((f"ep{d}x{m}", eng_mesh, mesh.size))

    sweep = []
    for rate in rates:
        stream = make_multitenant_stream(
            args.seed,
            cfg.vocab_size,
            n_sweep,
            rate,
            max_prompt=args.prompt_len,
            max_gen=args.gen,
            n_tenants=args.tenants,
        )
        for name, e, n_dev in engines:
            res = _drive(e, stream, n_dev)
            sweep.append({"config": name, "rate": rate, **res})
            print(
                f"serve_sweep_{name}_r{rate:g},"
                f"{1e6 / max(res['tokens_per_s'], 1e-9):.2f},"
                f"ttft p50 {1e3 * res['ttft_p50']:.1f}/p99 "
                f"{1e3 * res['ttft_p99']:.1f} ms, itl p50 "
                f"{1e3 * res['itl_p50']:.2f}/p99 {1e3 * res['itl_p99']:.2f} ms, "
                f"{res['tokens_per_s_per_device']:.0f} tok/s/dev, "
                f"maxvio {res['expert_maxvio']:.3f}"
            )

    if args.out_json:
        record = {
            "bench": "serve_throughput",
            "arch": args.arch,
            "reduced": args.reduced,
            "n_slots": args.n_slots,
            "chunk": args.chunk,
            "requests": args.requests,
            "rate": args.rate,
            "prefill_per_token_tps": tps_seed,
            "prefill_chunked_tps": tps_chunk,
            "prefill_speedup": speedup,
            "serve_tps": total / wall,
            "serve_steps": eng.n_steps,
            "serve_wall_s": wall,
            "n_completed": n_done,
            # overload degradation (DESIGN.md §Robustness)
            "deadline_ms": args.deadline_ms,
            "queue_timeout_ms": args.queue_timeout_ms,
            "shed_on_full": args.shed_on_full,
            "n_deadline_missed": eng.n_deadline_missed,
            "deadline_miss_rate": miss_rate,
            "n_shed": eng.n_shed,
            "expert_maxvio": maxvio,
            # SLO histograms (telemetry/slo.py): quantiles + sparse buckets
            "ttft": slo["ttft"],
            "itl": slo["itl"],
            "queue_wait": slo["queue_wait"],
            "queue_depth_max": slo["queue_depth_max"],
            "queue_depth_mean": slo["queue_depth_mean"],
            # bursty multi-tenant offered-load sweep (docstring §3):
            # p50/p99 TTFT + ITL vs rate, tokens/s/device, live MaxVio,
            # for the unsharded engine and (with --mesh) the EP engine
            "mesh": args.mesh,
            "tenants": args.tenants,
            "sweep_requests": n_sweep,
            "sweep": sweep,
        }
        with open(args.out_json, "w") as f:
            json.dump(record, f, indent=2)
        print(f"wrote {args.out_json}")
    return 0 if args.smoke or speedup >= 5.0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
