"""Per-step balance-method sweep — the paper's Tables 2-5 quantities, end to
end through the real training harness.

    PYTHONPATH=src python -m benchmarks.balance_sweep            # full sweep
    PYTHONPATH=src python -m benchmarks.balance_sweep --smoke    # CI guard

For BOTH paper models (minimind-moe-16e and 64e, reduced to smoke depth/width
but at their REAL expert counts — 16 experts k=4 and 64 experts k=8, the
balance problem is the expert count) and each routing method

    bip       BIP-Based Balancing (the paper's algorithm; ADMM dual ascent)
    lossfree  Loss-Free bias update   [Wang et al. 2024, aux-loss-free LB]
    aux_loss  Loss-Controlled         (switch-style auxiliary loss)
    topk      plain softmax top-k     (no balancing; collapse baseline)

every method trains the SAME deterministic token stream from the SAME
parameter init through `repro.training.train_loop`, recording per step:

    max_vio_per_layer   the paper's MaxVio, per MoE layer per batch
    perplexity          training perplexity
    step_time_s         wall-clock per jitted step

This is the step-wise load-evolution lens ("from the first step to the last
step", paper §4.2): BIP must hold MaxVio near 0 from step 0 while the
learning-based baselines start unbalanced and converge slowly — and topk
drifts. Writes BENCH_balance_sweep.json and prints the repo-contract CSV
``name,us_per_call,derived``.

``--data DIR_OR_GLOB`` swaps the synthetic stream for the real-text
pipeline (DESIGN.md §Data): a byte-BPE tokenizer is trained once per
config on the corpus (or loaded via --tokenizer), and every method reads
the SAME shuffled+packed document stream — real corpora are where routing
skew actually bites (the synthetic stream's near-uniform statistics
understate it), so this is the claim-bearing mode for the paper's
balance-on-real-data story.

``--sync local|global|both`` switches to the CROSS-SHARD lens (DESIGN.md
§Global-sync): BIP trains on a ``--mesh DxM`` host mesh (force host
devices first, e.g. XLA_FLAGS=--xla_force_host_platform_device_count=8)
under the requested dual-sync mode(s), next to an unsharded single-device
reference on the same stream. sync='global' must reproduce the
single-device MaxVio trajectory (psum'd duals == paper duals); sync='local'
solves per-shard BIPs and drifts — that contrast is the sharded
counterpart of the committed BENCH_balance_sweep.json table, and it lands
in BENCH_balance_sweep_sync.json with every entry's sync/mesh recorded.

``--matrix`` runs the FULL-DEPTH all-method matrix instead: every
registered balancer (the paper's four plus phi / lpr / expert_choice) at
full minimind depth (8 layers, d_model 512 — clearing the reduced-geometry
caveat) on 16e and 64e, over {synthetic, real text} × {local, global
sync}, per-step per-layer MaxVio + final ppl per cell, plus the
router-level objective/coverage comparison against the LP oracle →
BENCH_balance_matrix.json. ``--methods a,b,c`` restricts any mode to a
subset (names resolve through the balancer registry).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

# the historical single-device sweep table (BENCH_balance_sweep.json)
# compares the paper's four methods; --methods / --matrix reach the rest
METHODS = ("bip", "lossfree", "aux_loss", "topk")
# matrix order: paper methods first, then the registry additions
MATRIX_METHODS = (
    "bip", "lossfree", "aux_loss", "topk", "phi", "lpr", "expert_choice"
)

# reduced sweep geometry: enough tokens/step that per-expert loads are
# meaningful at m=64 (batch*seq = 512 tokens, k=8 -> 64 slots/expert mean)
BATCH = 8
SEQ_LEN = 64


def _sweep_cfg(arch: str):
    """Reduced (smoke-depth) config but with the REAL routing table."""
    import repro.configs as configs

    full = configs.get(arch)
    return configs.reduced_for_smoke(arch, routing=full.routing)


def _matrix_cfg(arch: str):
    """Full minimind DEPTH (n_layers, d_model) and the real routing table;
    the narrow dims (head count, expert hidden, vocab) stay reduced so the
    matrix is runnable on CPU — the balance problem is experts × depth."""
    import repro.configs as configs

    full = configs.get(arch)
    return configs.reduced_for_smoke(
        arch,
        routing=full.routing,
        n_layers=full.n_layers,
        d_model=full.d_model,
    )


def _resolve_methods(spec: Optional[str], default: Tuple[str, ...]):
    """--methods csv -> tuple, each name validated against the registry."""
    from repro.core import get_balancer

    if not spec:
        return default
    methods = tuple(s.strip() for s in spec.split(",") if s.strip())
    for name in methods:
        get_balancer(name)  # raises ValueError listing registered names
    return methods


def _get_tokenizer(data: str, tokenizer_path: str, vocab_size: int):
    """Load --tokenizer if given+present, else train on the corpus (cached
    per vocab size so the 16e/64e configs don't retrain)."""
    import os

    from repro.data import ByteBPETokenizer, resolve_shards, train_tokenizer_from_files

    if tokenizer_path and os.path.exists(tokenizer_path):
        tok = ByteBPETokenizer.load(tokenizer_path)
        assert tok.vocab_size <= vocab_size, (
            f"tokenizer vocab {tok.vocab_size} exceeds model vocab {vocab_size}"
        )
        return tok
    cache = _get_tokenizer.__dict__.setdefault("cache", {})
    if vocab_size not in cache:
        cache[vocab_size] = train_tokenizer_from_files(
            resolve_shards(data), vocab_size=vocab_size
        )
        if tokenizer_path:
            cache[vocab_size].save(tokenizer_path)
    return cache[vocab_size]


def _run_method(
    cfg,
    method: str,
    steps: int,
    lr: float,
    data: str = None,
    tokenizer_path: str = None,
    pack_mode: str = "pack",
    sync: str = None,
    mesh_shape: tuple = None,
) -> Dict[str, Any]:
    import jax
    import numpy as np

    from repro.data import make_batches
    from repro.models import build_model
    from repro.training import train_loop

    cfg = dataclasses.replace(
        cfg,
        routing=dataclasses.replace(
            cfg.routing, strategy=method, sync=sync or cfg.routing.sync
        ),
    )
    mesh = None
    if mesh_shape is not None:
        from repro.distributed import make_mesh_ctx
        from repro.launch.mesh import make_host_mesh

        assert len(jax.devices()) >= mesh_shape[0] * mesh_shape[1], (
            f"mesh {mesh_shape} needs {mesh_shape[0] * mesh_shape[1]} devices, "
            f"have {len(jax.devices())} — set XLA_FLAGS=--xla_force_host_"
            f"platform_device_count=N (or run on real accelerators)"
        )
        mesh = make_host_mesh(*mesh_shape)
        model = build_model(cfg, make_mesh_ctx(mesh))
    else:
        model = build_model(cfg)
    if data:
        from repro.data import Prefetcher, ShardedTextLoader, resolve_shards

        tok = _get_tokenizer(data, tokenizer_path, cfg.vocab_size)
        # same shards + seed per method -> identical document stream
        batches = Prefetcher(
            ShardedTextLoader(
                resolve_shards(data), tok,
                batch_size=BATCH, seq_len=SEQ_LEN, pack_mode=pack_mode, seed=0,
            )
        )
    else:
        batches = make_batches(cfg, BATCH, SEQ_LEN, steps, seed=0)
    t0 = time.perf_counter()
    _, log = train_loop(
        model,
        batches,
        key=jax.random.PRNGKey(0),
        lr=lr,
        warmup_steps=max(steps // 10, 1),
        total_steps=steps,
        mesh=mesh,
    )
    wall = time.perf_counter() - t0
    vio = np.stack(log.max_vio_steps) if log.max_vio_steps else np.zeros((0, 0))
    return {
        "strategy": method,
        # sync/mesh recorded per entry so trajectories are unambiguous:
        # single-device runs compute paper-global duals whatever cfg says,
        # but sync='global' still selects the threshold solver (the sync
        # sweep's reference runs it so the contrast is solver-for-solver)
        "sync": cfg.routing.sync
        if mesh is not None
        else (
            "n/a (single device, threshold solver: sync='global')"
            if cfg.routing.sync == "global"
            else "n/a (single device)"
        ),
        "mesh": list(mesh_shape) if mesh_shape is not None else None,
        "max_vio_per_step": [[round(float(v), 5) for v in row] for row in vio],
        "ppl_per_step": [round(p, 3) for p in log.perplexities],
        "step_time_s": [round(t, 5) for t in log.step_times],
        "first_step_max_vio": float(vio[0].max()) if vio.size else None,
        "train_wall_s": round(wall, 2),
        # summary carries final_ppl and mean_step_time (first 2 steps skipped)
        **log.summary(),
    }


def run(
    smoke: bool = False,
    steps: int = 0,
    data: str = None,
    tokenizer_path: str = None,
    pack_mode: str = "pack",
    sync: str = None,
    mesh: tuple = None,
    methods: Sequence[str] = METHODS,
) -> List[Dict[str, Any]]:
    """Returns CSV rows; writes BENCH_balance_sweep.json as a side effect
    (BENCH_balance_sweep_data.json in --data mode, BENCH_balance_sweep_sync
    .json in --sync mode, so the single-device table isn't clobbered).

    --sync mode sweeps BIP's cross-shard dual-sync axis instead of the
    method axis: an unsharded single-device run (the paper trajectory) next
    to `--mesh` runs under the requested sync mode(s). Everything shares
    one init + token stream, so trajectory differences are purely the dual
    semantics: 'global' must track the single-device MaxVio curve, 'local'
    legitimately drifts (per-shard duals).
    """
    import numpy as np

    steps = steps or (12 if smoke else 80)
    sync_modes = (
        None if sync is None else (["local", "global"] if sync == "both" else [sync])
    )
    mesh = tuple(mesh) if mesh else ((4, 2) if sync_modes else None)
    out: Dict[str, Any] = {
        "meta": {
            "batch": BATCH,
            "seq_len": SEQ_LEN,
            "steps": steps,
            "data": data,
            "pack_mode": pack_mode if data else None,
            "mesh": list(mesh) if sync_modes else None,
            "note": (
                "reduced minimind-moe geometry at real expert counts; "
                "identical init + token stream per method; MaxVio = "
                "max_load/mean_load - 1 per MoE layer per batch"
                + ("; real-text stream via data/ pipeline" if data else "")
                + (
                    "; cross-shard sync sweep: BIP on a DxM host mesh per "
                    "sync mode vs the unsharded single-device reference"
                    if sync_modes
                    else "; single-device runs: duals span the full batch "
                    "(paper-global) regardless of cfg sync"
                )
            ),
        },
        "configs": {},
    }
    rows = []
    for arch in ("minimind_moe_16e", "minimind_moe_64e"):
        cfg = _sweep_cfg(arch)
        entry: Dict[str, Any] = {
            "n_experts": cfg.routing.n_experts,
            "top_k": cfg.routing.top_k,
            "n_layers": cfg.n_layers,
            "d_model": cfg.d_model,
            "bip_iters": cfg.routing.bip_iters,
            "methods": {},
        }
        if sync_modes:
            # the unsharded reference also runs sync='global' (mesh=None):
            # route() then uses the same threshold/bisection solver as the
            # mesh runs, so the trajectory contrast is solver-for-solver
            # (DESIGN.md §Global-sync — the sort solver parks q exactly on
            # the degenerate capacity-marginal tie)
            variants = [("bip", "bip[single-device]", "global", None)] + [
                ("bip", f"bip[sync={sm}]", sm, mesh) for sm in sync_modes
            ]
        else:
            variants = [(m, m, None, None) for m in methods]
        for method, label, sm, msh in variants:
            rec = _run_method(
                cfg, method, steps, lr=1e-3,
                data=data, tokenizer_path=tokenizer_path, pack_mode=pack_mode,
                sync=sm, mesh_shape=msh,
            )
            entry["methods"][label] = rec
            step_s = rec["mean_step_time"] or float(np.mean(rec["step_time_s"]))
            # suffix mirrors the output file: sync wins over data
            suffix = "_sync" if sync_modes else ("_data" if data else "")
            rows.append(
                {
                    "name": f"balance_sweep_{cfg.name}_{label}{suffix}",
                    "us_per_call": round(step_s * 1e6, 1),
                    "derived": (
                        f"AvgMaxVio={rec['AvgMaxVio']:.4f};"
                        f"SupMaxVio={rec['SupMaxVio']:.4f};"
                        f"step0MaxVio={rec['first_step_max_vio']:.4f};"
                        f"ppl={rec['final_ppl']:.1f}"
                    ),
                }
            )
            print(
                f"  {cfg.name} {label:18s} AvgMaxVio={rec['AvgMaxVio']:.4f} "
                f"step0={rec['first_step_max_vio']:.4f} "
                f"ppl={rec['final_ppl']:.1f} "
                f"step={step_s * 1e3:.1f}ms",
                flush=True,
            )
        out["configs"][cfg.name] = entry

    fname = (
        "BENCH_balance_sweep_sync.json"
        if sync_modes
        else ("BENCH_balance_sweep_data.json" if data else "BENCH_balance_sweep.json")
    )
    with open(fname, "w") as f:
        json.dump(out, f, indent=1)
    return rows


def router_level_compare(
    methods: Sequence[str] = ("bip", "expert_choice"),
    n: int = 256,
    m: int = 8,
    k: int = 2,
    skew: float = 1.5,
    seeds: Sequence[int] = (0, 1, 2),
) -> List[Dict[str, Any]]:
    """Single-gate comparison on skewed score streams vs the LP oracle.

    Every method goes through the SAME registry-backed `route()` call the
    training paths use (no private per-method wiring), on softmax scores
    with a deliberate expert-popularity skew, next to the scipy LP upper
    bound. Per method: routed-objective ratio (Σ selected score mass /
    LP-opt), MaxVio, and token coverage (fraction with all k / zero
    experts — the expert-choice trade axis; 1.0 / 0.0 by construction for
    token-choice methods).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import RouterConfig, init_router_state, route
    from repro.core.lp_oracle import solve_plp

    rows = []
    for seed in seeds:
        rng = np.random.default_rng(seed)
        logits = jnp.asarray(
            rng.standard_normal((n, m)) + skew * np.linspace(2, -2, m)[None, :],
            jnp.float32,
        )
        s = jax.nn.softmax(logits, axis=-1)
        _, lp_opt = solve_plp(np.asarray(s), k)
        row: Dict[str, Any] = {"seed": seed, "lp_opt": float(lp_opt), "methods": {}}
        for method in methods:
            cfg = RouterConfig(n_experts=m, top_k=k, strategy=method, bip_iters=8)
            out = route(logits, init_router_state(cfg), cfg)
            idx = np.asarray(out.expert_index)
            per_token = (idx < m).sum(axis=-1)
            # combine weights are the raw scores of kept selections (zero on
            # expert_choice's uncovered sentinel slots), so their sum IS the
            # routed objective the LP bounds
            row["methods"][method] = {
                "obj_ratio": float(np.asarray(out.combine_weights).sum()) / lp_opt,
                "max_vio": float(out.metrics["max_vio"]),
                "coverage_full": float(np.mean(per_token >= k)),
                "coverage_zero": float(np.mean(per_token == 0)),
            }
        rows.append(row)
    return rows


def _aggregate_router_level(rows: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Mean over seeds, per method."""
    import numpy as np

    methods = rows[0]["methods"].keys()
    return {
        method: {
            col: round(float(np.mean([r["methods"][method][col] for r in rows])), 4)
            for col in rows[0]["methods"][method]
        }
        for method in methods
    }


def run_matrix(
    smoke: bool = False,
    steps: int = 0,
    data: str = None,
    tokenizer_path: str = None,
    pack_mode: str = "pack",
    methods: Sequence[str] = MATRIX_METHODS,
) -> List[Dict[str, Any]]:
    """The all-method balance matrix -> BENCH_balance_matrix.json.

    method × {16e, 64e} × {synthetic, real text} × {local, global sync} at
    full minimind depth (smoke keeps the reduced sweep geometry so CI stays
    fast), per-step per-layer MaxVio + final ppl per cell. Cells run
    single-device (the matrix is a method comparison, not a sharding one —
    BENCH_balance_sweep_sync.json holds the cross-shard lens), which makes
    the sync axis honest but degenerate for every method except bip: with
    no data axes the cross-shard reductions are no-ops, so sync='global'
    only changes bip (threshold/bisection solver vs the sort-based one).
    Those bip cells are re-run; the other global cells copy their local
    trajectory with a note instead of burning identical compute.
    """
    import numpy as np

    from repro.core import get_balancer

    steps = steps or (4 if smoke else 24)
    for name in methods:
        get_balancer(name)
    if data is None and os.path.isdir("tests/fixtures/corpus"):
        data = "tests/fixtures/corpus"
    data_modes = [("synthetic", None)] + ([("real_text", data)] if data else [])
    out: Dict[str, Any] = {
        "meta": {
            "batch": BATCH,
            "seq_len": SEQ_LEN,
            "steps": steps,
            "smoke": smoke,
            "data": data,
            "pack_mode": pack_mode if data else None,
            "methods": list(methods),
            "note": (
                ("reduced smoke geometry; " if smoke else
                 "FULL minimind depth (n_layers / d_model from the real "
                 "config; narrow dims reduced for CPU); ")
                + "identical init + token stream per cell; cells are "
                "single-device, so sync='global' re-runs only bip (the dual "
                "solver changes); other methods' global cells copy the "
                "local trajectory (cross-shard reductions are no-ops "
                "without data axes) — see BENCH_balance_sweep_sync.json "
                "for the true cross-shard lens"
            ),
        },
        # single-gate objective/coverage columns vs the LP oracle
        # (absorbs benchmarks/expert_choice_compare's comparison)
        "router_level": _aggregate_router_level(
            router_level_compare(methods=methods)
        ),
        "configs": {},
    }
    rows: List[Dict[str, Any]] = []
    for arch in ("minimind_moe_16e", "minimind_moe_64e"):
        cfg = _sweep_cfg(arch) if smoke else _matrix_cfg(arch)
        entry: Dict[str, Any] = {
            "n_experts": cfg.routing.n_experts,
            "top_k": cfg.routing.top_k,
            "n_layers": cfg.n_layers,
            "d_model": cfg.d_model,
            "bip_iters": cfg.routing.bip_iters,
            "cells": {},
        }
        for mode_name, mode_data in data_modes:
            for method in methods:
                rec = _run_method(
                    cfg, method, steps, lr=1e-3,
                    data=mode_data, tokenizer_path=tokenizer_path,
                    pack_mode=pack_mode, sync="local",
                )
                entry["cells"][f"{mode_name}/local/{method}"] = rec
                if method == "bip":
                    rec_g = _run_method(
                        cfg, method, steps, lr=1e-3,
                        data=mode_data, tokenizer_path=tokenizer_path,
                        pack_mode=pack_mode, sync="global",
                    )
                else:
                    rec_g = dict(rec)
                    rec_g["note"] = (
                        "copied from the local cell: single-device "
                        "trajectory is identical under either sync mode "
                        "for this method (no data axes)"
                    )
                entry["cells"][f"{mode_name}/global/{method}"] = rec_g
                for sync_label, r in (("local", rec), ("global", rec_g)):
                    rows.append(
                        {
                            "name": (
                                f"balance_matrix_{cfg.name}_{mode_name}"
                                f"_{sync_label}_{method}"
                            ),
                            "us_per_call": round(
                                (
                                    r["mean_step_time"]
                                    or float(np.mean(r["step_time_s"]))
                                ) * 1e6,
                                1,
                            ),
                            "derived": (
                                f"AvgMaxVio={r['AvgMaxVio']:.4f};"
                                f"SupMaxVio={r['SupMaxVio']:.4f};"
                                f"ppl={r['final_ppl']:.1f}"
                            ),
                        }
                    )
                print(
                    f"  {cfg.name} {mode_name:9s} {method:14s} "
                    f"AvgMaxVio={rec['AvgMaxVio']:.4f} "
                    f"ppl={rec['final_ppl']:.1f}",
                    flush=True,
                )
        out["configs"][cfg.name] = entry

    with open("BENCH_balance_matrix.json", "w") as f:
        json.dump(out, f, indent=1)
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI guard: few steps")
    ap.add_argument("--steps", type=int, default=0, help="override step count")
    ap.add_argument("--data", default=None,
                    help="corpus dir/glob: run the sweep on real text through "
                         "the streaming data pipeline instead of synthetic")
    ap.add_argument("--tokenizer", default=None,
                    help="tokenizer JSON (trained on --data if missing)")
    ap.add_argument("--pack-mode", default="pack",
                    choices=["pack", "pack_nocross", "pad"])
    ap.add_argument("--sync", default=None, choices=["local", "global", "both"],
                    help="cross-shard sweep: train BIP on --mesh under this "
                         "dual-sync mode (plus a single-device reference) "
                         "instead of sweeping methods; needs >= D*M host "
                         "devices (XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=8 for the default 4x2)")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="host mesh for --sync runs (default 4x2)")
    ap.add_argument("--methods", default=None,
                    help="comma-separated subset of registered balancers "
                         "(default: the paper's four; --matrix: all)")
    ap.add_argument("--matrix", action="store_true",
                    help="all-method full-depth matrix (see module docs) "
                         "-> BENCH_balance_matrix.json")
    args = ap.parse_args(argv)
    mesh = None
    if args.mesh:
        if not args.sync:
            ap.error("--mesh only applies to --sync runs (the method sweep "
                     "is single-device by design)")
        mesh = tuple(int(v) for v in args.mesh.lower().split("x"))
    try:
        methods = _resolve_methods(
            args.methods, MATRIX_METHODS if args.matrix else METHODS
        )
    except ValueError as e:
        ap.error(str(e))
    if args.matrix:
        if args.sync or mesh:
            ap.error("--matrix and --sync/--mesh are separate lenses; the "
                     "matrix is single-device (see BENCH_balance_sweep_sync"
                     ".json for the cross-shard sweep)")
        rows = run_matrix(smoke=args.smoke, steps=args.steps, data=args.data,
                          tokenizer_path=args.tokenizer,
                          pack_mode=args.pack_mode, methods=methods)
    else:
        rows = run(smoke=args.smoke, steps=args.steps, data=args.data,
                   tokenizer_path=args.tokenizer, pack_mode=args.pack_mode,
                   sync=args.sync, mesh=mesh, methods=methods)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
