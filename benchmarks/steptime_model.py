"""Step-time model — the mechanism behind the paper's >=13% training-time
saving, quantified with OUR measured MaxVio trajectories.

In expert-parallel execution the MoE-FFN phase finishes when the most
loaded expert-owner finishes, so its duration scales with
(1 + MaxVio_batch). Integrated over a training run:

    T_run(method) = T_nonmoe + T_moe_balanced · mean_b(1 + MaxVio_b)
                  + T_drop_recompute(capacity overflow)

The MoE-FFN fraction of a step comes from the dry-run roofline (expert GEMM
FLOPs / total FLOPs); MaxVio trajectories come from the paper-repro runs.
The paper's 13-14% saving on Loss-Controlled corresponds to AvgMaxVio
around 0.4-0.7 with a 40-60% MoE-heavy step — this benchmark reports the
same derivation for our measured trajectories.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional


def step_time_ratio(
    avg_max_vio: float, moe_fraction: float, dropped_frac: float = 0.0
) -> float:
    """Step time relative to a perfectly balanced run (lower is better)."""
    return (1.0 - moe_fraction) + moe_fraction * (1.0 + avg_max_vio) + dropped_frac


def run(repro_json: str = "paper_repro_results.json") -> List[Dict]:
    rows: List[Dict] = []
    if not os.path.exists(repro_json):
        return [{
            "name": "steptime_model",
            "us_per_call": 0,
            "derived": f"SKIPPED ({repro_json} missing; run benchmarks.paper_repro first)",
        }]
    with open(repro_json) as f:
        tables = json.load(f)
    # MoE fraction of a minimind-16e training step from expert-GEMM share:
    # experts are ~92% of parameters => ~0.6 of step FLOPs after attention.
    moe_fraction = 0.6
    for tbl in tables:
        base = None
        for r in tbl["rows"]:
            ratio = step_time_ratio(r["AvgMaxVio"], moe_fraction)
            if r["strategy"] == "aux_loss":
                base = ratio
            rows.append(
                {
                    "name": f"steptime_{tbl['table']}_{r['strategy']}",
                    "us_per_call": round(ratio, 4),
                    "derived": (
                        f"vs_losscontrolled={ratio / base:.4f}" if base else "baseline"
                    ),
                }
            )
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")
