"""Paper reproduction — Tables 2/3 (+ per-layer Tables 4/5, Fig 1/2 data).

Trains reduced-scale Minimind-MoE models (same m, k, layer count as the
paper; smaller d_model/seq so it runs on this CPU container) with the three
routing strategies and reports AvgMaxVio / SupMaxVio / test perplexity /
wall-clock — the paper's exact measurement set.

What must reproduce (paper §4.2):
  * BIP holds MaxVio low from the FIRST batch; LC/LF start high, fall slowly.
  * AvgMaxVio(BIP) « AvgMaxVio(LF) < AvgMaxVio(LC); SupMaxVio(BIP) < 0.6.
  * BIP perplexity <= LC/LF perplexity (no conflicting aux gradients).
  * the gap GROWS from m=16 to m=64 (paper Fig 2 vs Fig 1).

Scale note: the paper's absolute numbers come from 0.3B/1.1B models on a
Chinese web corpus; with the synthetic corpus + reduced dims the comparison
is RELATIVE between methods on identical data/seeds, which is what the
paper's claims assert (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List

import jax
import numpy as np

from repro import configs
from repro.data import make_batches
from repro.models import build_model
from repro.training import train_loop
from repro.training.loop import evaluate_ppl


def run_one(
    base_arch: str,
    strategy: str,
    bip_iters: int,
    *,
    steps: int,
    seed: int = 0,
    d_model: int = 128,
    n_layers: int = 4,
    seq_len: int = 128,
    batch: int = 8,
) -> Dict:
    cfg = configs.get(base_arch)
    routing = dataclasses.replace(
        cfg.routing, strategy=strategy, bip_iters=bip_iters
    )
    cfg = dataclasses.replace(
        cfg,
        n_layers=n_layers,
        d_model=d_model,
        n_heads=4,
        n_kv_heads=4,
        head_dim=32,
        moe_d_ff=256,
        d_ff=256,
        vocab_size=512,
        max_seq_len=seq_len,
        attn_chunk=64,
        routing=routing,
    )
    model = build_model(cfg)
    train = make_batches(cfg, batch, seq_len, steps, seed=seed, split="train")
    t0 = time.perf_counter()
    state, log = train_loop(
        model, train, lr=1e-3, warmup_steps=10, total_steps=steps,
        key=jax.random.PRNGKey(seed),
    )
    wall = time.perf_counter() - t0
    test = make_batches(cfg, batch, seq_len, 4, seed=seed, split="test")
    ppl = evaluate_ppl(model, state, test)
    s = log.summary()
    return {
        "strategy": strategy if strategy != "bip" else f"bip_T{bip_iters}",
        "AvgMaxVio": round(s["AvgMaxVio"], 4),
        "SupMaxVio": round(s["SupMaxVio"], 4),
        "perplexity": round(ppl, 4),
        "train_wall_s": round(wall, 1),
        "AvgMaxVio_per_layer": [round(v, 4) for v in s["AvgMaxVio_per_layer"]],
        "maxvio_trajectory": [
            round(float(v.max()), 4) for v in log.max_vio_steps
        ],
        "first_batch_maxvio": round(float(log.max_vio_steps[0].max()), 4)
        if log.max_vio_steps
        else None,
    }


def table(base_arch: str, variants: List, steps: int, tag: str) -> Dict:
    print(f"\n=== {tag} ({base_arch}, {steps} steps/method) ===", flush=True)
    rows = []
    for strategy, t in variants:
        r = run_one(base_arch, strategy, t, steps=steps)
        rows.append(r)
        print(
            f"{r['strategy']:<16} AvgMaxVio {r['AvgMaxVio']:<8} "
            f"SupMaxVio {r['SupMaxVio']:<8} ppl {r['perplexity']:<9} "
            f"wall {r['train_wall_s']}s first-batch {r['first_batch_maxvio']}",
            flush=True,
        )
    return {"table": tag, "arch": base_arch, "rows": rows}


def main(steps: int = 150, out: str = "paper_repro_results.json"):
    results = []
    # Table 2 analogue: m=16, k=4
    results.append(
        table(
            "minimind_moe_16e",
            [("aux_loss", 0), ("lossfree", 0), ("bip", 2), ("bip", 4), ("bip", 8)],
            steps,
            "table2_m16_k4",
        )
    )
    # Table 3 analogue: m=64, k=8
    results.append(
        table(
            "minimind_moe_64e",
            [("aux_loss", 0), ("lossfree", 0), ("bip", 4), ("bip", 14)],
            steps,
            "table3_m64_k8",
        )
    )
    with open(out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"\nwrote {out}")

    # paper-claim checks (soft: prints PASS/FAIL lines consumed by EXPERIMENTS)
    for tbl in results:
        by = {r["strategy"]: r for r in tbl["rows"]}
        bip_rows = [r for k, r in by.items() if k.startswith("bip")]
        best_bip = min(bip_rows, key=lambda r: r["AvgMaxVio"])
        lc, lf = by["aux_loss"], by["lossfree"]
        checks = {
            "bip_avgmaxvio_lowest": best_bip["AvgMaxVio"] < min(lc["AvgMaxVio"], lf["AvgMaxVio"]),
            "bip_supmaxvio_lowest": min(r["SupMaxVio"] for r in bip_rows)
            < min(lc["SupMaxVio"], lf["SupMaxVio"]),
            "bip_balanced_from_step1": any(
                r["first_batch_maxvio"] is not None and r["first_batch_maxvio"] < 0.6
                for r in bip_rows
            ),
            "bip_ppl_competitive": min(r["perplexity"] for r in bip_rows)
            <= 1.02 * min(lc["perplexity"], lf["perplexity"]),
        }
        for name, ok in checks.items():
            print(f"[{tbl['table']}] {name}: {'PASS' if ok else 'FAIL'}")
    return results


if __name__ == "__main__":
    import sys

    steps = int(sys.argv[1]) if len(sys.argv) > 1 else 150
    main(steps=steps)
