"""Benchmark harness entry point — one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # standard pass
    PYTHONPATH=src python -m benchmarks.run --full     # full-length repro runs

Prints ``name,us_per_call,derived`` CSV lines per the repo contract.

Benchmarks:
  table2/table3 (+ per-layer tables 4/5, Fig 1/2 data)  -> benchmarks.paper_repro
  router gate overhead ("very small time costs")        -> benchmarks.router_overhead
  step-time model (the >=13% training-time mechanism)   -> benchmarks.steptime_model
  kernel microbench (ADMM iteration + expert GEMM)      -> below
  dispatch plan old-vs-new + Pallas FFN                 -> benchmarks.moe_dispatch
  streaming data pipeline (tokens/s, prefetch overlap)  -> benchmarks.data_pipeline
  serving throughput + multi-tenant offered-load sweep  -> benchmarks.serve_throughput
  roofline table (if dry-run results exist)             -> benchmarks.roofline
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def _kernel_microbench():
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops

    rows = []
    rng = np.random.default_rng(0)
    n, m, k = 4096, 64, 8
    e = np.exp(rng.standard_normal((n, m)))
    s = jnp.asarray((e / e.sum(-1, keepdims=True)).astype(np.float32))
    q0 = jnp.zeros((m,), jnp.float32)

    fn = jax.jit(lambda s, q: ops.bip_dual_update(s, q, top_k=k, n_iters=4))
    fn(s, q0).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        out = fn(s, q0)
    out.block_until_ready()
    us = (time.perf_counter() - t0) / 5 * 1e6
    rows.append({
        "name": f"kernel_bip_admm_T4_n{n}_m{m}",
        "us_per_call": round(us, 1),
        "derived": "interpret-mode CPU; TPU est ~0.5ms/iter at n=32k m=128",
    })

    ee, c, d, f = 4, 128, 128, 256
    x = jnp.asarray(rng.standard_normal((ee, c, d)).astype(np.float32)) * 0.3
    wg = jnp.asarray(rng.standard_normal((ee, d, f)).astype(np.float32)) * 0.1
    wu = jnp.asarray(rng.standard_normal((ee, d, f)).astype(np.float32)) * 0.1
    wd = jnp.asarray(rng.standard_normal((ee, f, d)).astype(np.float32)) * 0.1
    fn2 = jax.jit(lambda *a: ops.expert_ffn(*a, block_c=64, block_f=128, block_d=64))
    fn2(x, wg, wu, wd).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(3):
        y = fn2(x, wg, wu, wd)
    y.block_until_ready()
    us = (time.perf_counter() - t0) / 3 * 1e6
    flops = 6 * ee * c * d * f
    rows.append({
        "name": f"kernel_expert_ffn_e{ee}_c{c}",
        "us_per_call": round(us, 1),
        "derived": f"flops={flops:.2e} (interpret mode)",
    })
    return rows


def _rows(rows) -> None:
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}", flush=True)


def _bench_kernels(args) -> None:
    print("# kernel microbenchmarks", flush=True)
    _rows(_kernel_microbench())


def _bench_moe_dispatch(args) -> None:
    print("# MoE dispatch: sort-based ragged plan vs one-hot/cumsum", flush=True)
    from benchmarks import moe_dispatch

    _rows(moe_dispatch.run(smoke=not args.full))


def _bench_router_overhead(args) -> None:
    print("# router overhead (paper: 'very small time costs')", flush=True)
    from benchmarks import router_overhead

    _rows(router_overhead.run())
    print("# router dual sync sweep on a 4x2 mesh (BENCH_router_sync.json)", flush=True)
    _rows(router_overhead.run_sync_sweep(smoke=not args.full))


def _bench_paper_repro(args) -> None:
    if args.skip_train:
        return
    print("# paper tables 2/3 reproduction (reduced scale)", flush=True)
    from benchmarks import paper_repro

    steps = 300 if args.full else 120
    tables = paper_repro.main(steps=steps)
    for tbl in tables:
        for r in tbl["rows"]:
            print(
                f"{tbl['table']}_{r['strategy']},{r['train_wall_s'] * 1e6:.0f},"
                f"AvgMaxVio={r['AvgMaxVio']};SupMaxVio={r['SupMaxVio']};"
                f"ppl={r['perplexity']}",
                flush=True,
            )


def _bench_balance_sweep(args) -> None:
    if args.skip_train:
        return
    print("# per-step balance-method sweep (paper's step-wise MaxVio lens)", flush=True)
    from benchmarks import balance_sweep

    _rows(balance_sweep.run(smoke=not args.full))


def _bench_data_pipeline(args) -> None:
    if args.skip_train:
        return
    print("# streaming data pipeline (host tokens/s, prefetch overlap)", flush=True)
    from benchmarks import data_pipeline

    _rows(data_pipeline.run(smoke=not args.full))


def _bench_steptime_model(args) -> None:
    print("# step-time model (>=13% saving mechanism)", flush=True)
    from benchmarks import steptime_model

    _rows(steptime_model.run())


def _bench_capacity_ablation(args) -> None:
    print("# capacity-factor ablation (drops vs cf per strategy)", flush=True)
    from benchmarks import capacity_ablation

    _rows(capacity_ablation.run())


def _bench_expert_choice(args) -> None:
    print("# BIP vs Expert-Choice (beyond-paper comparison)", flush=True)
    from benchmarks import expert_choice_compare

    _rows(expert_choice_compare.main())


def _bench_telemetry_overhead(args) -> None:
    if args.skip_train:
        return
    print("# telemetry overhead (instrumented vs bare train step)", flush=True)
    from benchmarks import telemetry_overhead

    _rows(telemetry_overhead.run(smoke=not args.full))


def _bench_serve_throughput(args) -> None:
    if args.skip_train:
        return
    print("# serving throughput (prefill speedup + multi-tenant sweep)", flush=True)
    from benchmarks import serve_throughput

    # the mesh rows ride along when forced host devices are available
    # (CI exports XLA_FLAGS=--xla_force_host_platform_device_count=8);
    # otherwise the bench prints a skip row and sweeps unsharded only
    argv = ["--out-json", "BENCH_serve_throughput.json", "--mesh", "4x2"]
    if not args.full:
        argv += ["--smoke", "--requests", "16", "--sweep-requests", "12"]
    serve_throughput.main(argv)


def _bench_roofline(args) -> None:
    if os.path.exists("dryrun_results_single.jsonl"):
        print("# roofline (from dry-run artifacts)", flush=True)
        from benchmarks import roofline

        roofline.main(["dryrun_results_single.jsonl"])


# registry: name -> section runner; `python -m benchmarks.run NAME [NAME..]`
# runs a subset, no names runs everything in order
BENCHES = {
    "kernels": _bench_kernels,
    "moe_dispatch": _bench_moe_dispatch,
    "router_overhead": _bench_router_overhead,
    "paper_repro": _bench_paper_repro,
    "balance_sweep": _bench_balance_sweep,
    "data_pipeline": _bench_data_pipeline,
    "steptime_model": _bench_steptime_model,
    "capacity_ablation": _bench_capacity_ablation,
    "expert_choice": _bench_expert_choice,
    "telemetry_overhead": _bench_telemetry_overhead,
    "serve_throughput": _bench_serve_throughput,
    "roofline": _bench_roofline,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("benchmarks", nargs="*", metavar="NAME",
                    help="benchmark(s) to run (default: all); one of: "
                         + ", ".join(BENCHES))
    ap.add_argument("--full", action="store_true", help="full-length repro runs")
    ap.add_argument("--skip-train", action="store_true", help="skip training benches")
    args = ap.parse_args(argv)

    unknown = [n for n in args.benchmarks if n not in BENCHES]
    if unknown:
        ap.error(
            f"unknown benchmark(s): {', '.join(sorted(unknown))}. "
            f"Registered benchmarks: {', '.join(BENCHES)}"
        )

    selected = args.benchmarks or list(BENCHES)
    for name in selected:
        BENCHES[name](args)


if __name__ == "__main__":
    main()
