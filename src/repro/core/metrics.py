"""Load-balance measurements from the paper (Section 4.1).

MaxVio_batch = max_j Load_j / mean_load - 1, where Load_j is the number of
tokens matched to expert j in the batch and mean_load = k*n/m.

AvgMaxVio / SupMaxVio are the mean / max of MaxVio over all training batches;
they are accumulated outside jit by `BalanceTracker`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax.numpy as jnp
import numpy as np


def expert_load(expert_index: jnp.ndarray, n_experts: int) -> jnp.ndarray:
    """Tokens matched per expert. expert_index: (..., k) int32 -> (m,) int32.

    Integer counts end-to-end (telemetry dtype audit): a count histogram is
    exact under any cross-shard psum order, so local/global sync produce
    bit-identical load telemetry. Out-of-range indices (the expert-choice
    sentinel m) are dropped by the scatter, same as the float formulation.
    """
    flat = expert_index.reshape(-1)
    return jnp.zeros((n_experts,), jnp.int32).at[flat].add(1)


def max_violation(load: jnp.ndarray, n_tokens: int, top_k: int) -> jnp.ndarray:
    """MaxVio for one batch given the per-expert load vector."""
    mean_load = (n_tokens * top_k) / load.shape[0]
    return jnp.max(load) / mean_load - 1.0


def balance_metrics(
    expert_index: jnp.ndarray, n_experts: int, top_k: int
) -> Dict[str, jnp.ndarray]:
    n = int(np.prod(expert_index.shape[:-1]))
    load = expert_load(expert_index, n_experts)  # (m,) int32 counts
    mean_load = (n * top_k) / n_experts
    frac = load / jnp.maximum(load.sum(), 1.0)
    entropy = -jnp.sum(frac * jnp.log(frac + 1e-9))
    return {
        "load": load,
        "max_vio": jnp.max(load) / mean_load - 1.0,
        "min_load_frac": jnp.min(load) / mean_load,
        "load_entropy": entropy / np.log(n_experts),  # 1.0 == perfectly uniform
        "dropped_frac_cap1": jnp.sum(jnp.maximum(load - mean_load, 0.0))
        / jnp.maximum(load.sum(), 1.0),
    }


@dataclasses.dataclass
class BalanceTracker:
    """Accumulates per-batch MaxVio into AvgMaxVio / SupMaxVio (host side).

    One tracker per MoE layer; `add` takes the already-device-fetched scalar.
    """

    max_vios: List[float] = dataclasses.field(default_factory=list)

    def add(self, max_vio: float) -> None:
        self.max_vios.append(float(max_vio))

    @property
    def avg_max_vio(self) -> float:
        return float(np.mean(self.max_vios)) if self.max_vios else 0.0

    @property
    def sup_max_vio(self) -> float:
        return float(np.max(self.max_vios)) if self.max_vios else 0.0

    def summary(self) -> Dict[str, float]:
        return {"AvgMaxVio": self.avg_max_vio, "SupMaxVio": self.sup_max_vio}
