"""Unified top-k router with all three balancing strategies from the paper.

One API for:
  * 'topk'      — vanilla top-k (no balancing; the collapse-prone baseline)
  * 'aux_loss'  — Loss-Controlled (GShard/Switch auxiliary loss, α·Σ f_j P_j)
  * 'lossfree'  — Loss-Free (Wang et al. 2024): per-batch sign update of bias b
  * 'bip'       — BIP-Based Balancing (this paper): per-gate ADMM dual update of q

All strategies share RouterState {'q': (m,)}; for 'lossfree' the vector plays
the role of the bias b (added), for 'bip' the dual price q (subtracted). Gate
*values* are always the raw scores of the selected experts, so neither vector
receives gradient — only 'aux_loss' shapes gradients, via its explicit loss.

The router is functional: `route(logits, state, cfg)` returns RouterOutput with
the new state; the training loop threads state through like any other pytree.

Distribution note (see DESIGN.md §3.3 / §Global-sync): under plain jit/pjit
the math below is written over the *global* token batch, so single-program
callers get paper-global duals for free — XLA inserts the collectives for the
column order statistic when tokens are sharded. Inside a shard_map (the EP
paths in models/moe.py) each device sees only its token shard, and
cfg.sync selects the semantics: 'global' runs the threshold dual update with
psum-reduced counts over cfg.data_axes (`ref_bip.bip_dual_update_global`) so
every device converges on the same q over the global batch; 'local' solves a
per-shard BIP and the caller averages the warm-start duals. sync='local' with
`local_shards > 1` additionally lets a single-program caller emulate the
per-shard semantics by vmapping the dual update over token groups.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import ref_bip
from repro.core.metrics import balance_metrics
from repro.core.types import RouterConfig, RouterOutput, init_router_state


# ------------------------------------------------------- dispatch plan
#
# Sort-based ragged dispatch (megablocks-style, Gale et al.): one stable
# argsort of the (n·k,) expert assignments replaces the (n·k, m) one-hot +
# serial cumsum bookkeeping, and packing/combining become pure gathers —
# no m-wide intermediate, no repeat(x, k) materialization, no scatter-add
# over d-wide activations. Semantics match the historical one-hot plan
# bit-for-bit: capacity queues are token-ordered (earlier tokens win),
# slot-major within a token, and token_mask rows never occupy capacity.


@dataclasses.dataclass(frozen=True)
class DispatchPlan:
    """Ragged routing plan consumed within a single trace (not a pytree).

    order    (n·k,) stable argsort of expert assignments (masked → sentinel m)
    offsets  (m+1,) segment start of each expert's queue in sorted order
    pos      (n, k) position of each (token, slot) in its expert's queue
    keep     (n, k) slot survives capacity (and token_mask)
    """

    expert_index: jnp.ndarray  # (n, k) int32
    order: jnp.ndarray
    offsets: jnp.ndarray
    pos: jnp.ndarray
    keep: jnp.ndarray
    capacity: int
    top_k: int

    @property
    def counts(self) -> jnp.ndarray:
        """Per-expert assigned load (m,), pre-capacity, masked rows excluded."""
        return self.offsets[1:] - self.offsets[:-1]

    def pack(
        self,
        x: jnp.ndarray,  # (n, d)
        *,
        expert_offset=0,  # first expert owned locally (may be traced)
        n_local: Optional[int] = None,  # experts packed (static); default all
    ) -> jnp.ndarray:
        """Gather tokens into the (n_local, capacity, d) expert buffers."""
        nk = self.order.shape[0]
        m_loc = (self.offsets.shape[0] - 1) if n_local is None else n_local
        cap = self.capacity
        slots = jnp.arange(m_loc * cap, dtype=jnp.int32)
        se = expert_offset + slots // cap
        src_sorted = jnp.take(self.offsets, se) + slots % cap
        valid = src_sorted < jnp.take(self.offsets, se + 1)
        src_tok = jnp.take(self.order, jnp.minimum(src_sorted, nk - 1)) // self.top_k
        buf = jnp.take(x, src_tok, axis=0) * valid[:, None].astype(x.dtype)
        return buf.reshape(m_loc, cap, x.shape[-1])

    def combine(
        self,
        y: jnp.ndarray,  # (n_local, capacity, d) expert outputs
        weights: jnp.ndarray,  # (n, k) combine weights
        *,
        expert_offset=0,
    ) -> jnp.ndarray:
        """Gather expert outputs back per (token, slot), weight, and sum."""
        m_loc, cap, d = y.shape
        n, k = self.expert_index.shape
        e_rel = self.expert_index - expert_offset
        ok = (self.keep & (e_rel >= 0) & (e_rel < m_loc)).reshape(-1)
        slot = (e_rel * cap + self.pos).reshape(-1)
        g = jnp.take(y.reshape(m_loc * cap, d), jnp.where(ok, slot, 0), axis=0)
        w = weights.reshape(-1, 1).astype(y.dtype)
        contrib = jnp.where(ok[:, None], g * w, 0.0)
        return contrib.reshape(n, k, d).sum(axis=1)


def make_dispatch_plan(
    expert_index: jnp.ndarray,  # (n, k) int32
    n_experts: int,
    capacity: int,
    token_mask: Optional[jnp.ndarray] = None,  # (n,) bool; False never dispatches
) -> DispatchPlan:
    """Build the sort-based plan for one routed batch.

    Masked tokens are re-keyed to the sentinel expert m, so the stable sort
    pushes them past every real segment: they neither occupy capacity nor
    displace real tokens, and `counts` covers real traffic only.
    """
    n, k = expert_index.shape
    nk = n * k
    flat = expert_index.reshape(-1).astype(jnp.int32)
    if token_mask is not None:
        flat = jnp.where(jnp.repeat(token_mask, k), flat, n_experts)
    order = jnp.argsort(flat, stable=True).astype(jnp.int32)
    sorted_e = jnp.take(flat, order)
    offsets = jnp.searchsorted(
        sorted_e, jnp.arange(n_experts + 1, dtype=jnp.int32)
    ).astype(jnp.int32)
    # rank within the expert's segment == position in its capacity queue
    pos_sorted = jnp.arange(nk, dtype=jnp.int32) - jnp.take(offsets, sorted_e)
    pos = jnp.zeros((nk,), jnp.int32).at[order].set(pos_sorted).reshape(n, k)
    keep = pos < capacity
    if token_mask is not None:
        keep = keep & token_mask[:, None]
    return DispatchPlan(
        expert_index=expert_index.astype(jnp.int32),
        order=order,
        offsets=offsets,
        pos=pos,
        keep=keep,
        capacity=capacity,
        top_k=k,
    )


def compute_scores(logits: jnp.ndarray, cfg: RouterConfig) -> jnp.ndarray:
    """Gating function G. Paper / minimind: softmax over experts."""
    logits = logits.astype(cfg.router_dtype)
    if cfg.score_fn == "softmax":
        return jax.nn.softmax(logits, axis=-1)
    return jax.nn.sigmoid(logits)


def _topk_select(
    s: jnp.ndarray, corrected: jnp.ndarray, cfg: RouterConfig
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k on `corrected` scores, gate values gathered from raw `s`."""
    _, idx = lax.top_k(corrected, cfg.top_k)
    w = jnp.take_along_axis(s, idx, axis=-1)
    if cfg.norm_topk_prob:
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return w, idx.astype(jnp.int32)


def _aux_loss(
    s: jnp.ndarray, idx: jnp.ndarray, cfg: RouterConfig, token_mask=None
) -> jnp.ndarray:
    """L_balance = α Σ_j f_j P_j (Loss-Controlled method).

    f_j = m/(k n) Σ_i δ_ij  (token fraction, non-differentiable -> stopped),
    P_j = 1/n Σ_i s_ij      (mean gate score, carries the gradient).
    With token_mask, both means run over the real rows only.
    """
    n, m = s.shape
    onehot = jax.nn.one_hot(idx, m, dtype=s.dtype)  # (n, k, m)
    if token_mask is not None:
        w = token_mask.astype(s.dtype)
        n_eff = jnp.maximum(jnp.sum(w), 1.0)
        f = lax.stop_gradient((onehot * w[:, None, None]).sum(axis=(0, 1))) * (
            m / (cfg.top_k * n_eff)
        )
        p_mean = jnp.sum(s * w[:, None], axis=0) / n_eff
    else:
        f = lax.stop_gradient(onehot.sum(axis=(0, 1))) * (m / (cfg.top_k * n))
        p_mean = s.mean(axis=0)
    return cfg.aux_loss_alpha * jnp.sum(f * p_mean)


_warned: set = set()


def _warn_once(key: str, msg: str) -> None:
    """Emit a config-degradation warning once per process (trace-time)."""
    if key not in _warned:
        _warned.add(key)
        warnings.warn(msg, stacklevel=3)


def _bip_q(s: jnp.ndarray, q0: jnp.ndarray, cfg: RouterConfig) -> jnp.ndarray:
    """Dispatch the ADMM dual update to the reference or the Pallas kernel."""
    if cfg.use_kernel:
        from repro.kernels import ops as kernel_ops  # lazy: avoid import cycle

        return kernel_ops.bip_dual_update(
            s, q0, top_k=cfg.top_k, n_iters=cfg.bip_iters
        )
    q, _ = ref_bip.bip_dual_update(s, q0, top_k=cfg.top_k, n_iters=cfg.bip_iters)
    return q


def route(
    logits: jnp.ndarray,
    state: Dict[str, jnp.ndarray],
    cfg: RouterConfig,
    *,
    local_shards: int = 1,
    token_mask=None,
) -> RouterOutput:
    """Route a flattened batch of tokens.

    logits: (n, m) router logits (pre-gating-function).
    state:  {'q': (m,)} carried vector (ADMM warm start / Loss-Free bias);
      with cfg.forecast also {'q_ema', 'q_err'} (m,) dual-forecaster EMAs.
      Unrecognized keys pass through untouched.
    token_mask: optional (n,) bool — serving padding rows are False; they
      still get selections (static shapes) but are excluded from every
      state update and loss, so the carried q tracks real traffic only
      even when decode-heavy chunks are mostly padding (DESIGN.md §Serving).
    """
    n, m = logits.shape
    assert m == cfg.n_experts, (m, cfg.n_experts)
    s = compute_scores(logits, cfg)
    q0 = state["q"]
    aux = jnp.zeros((), dtype=cfg.router_dtype)
    new_q = q0
    # carry every state key through unchanged unless a branch updates it, so
    # the router-state pytree structure is stable across scan/loop carries
    new_state = dict(state)

    if cfg.guard_duals:
        # dual-health watchdog: q and the forecaster EMAs are one coupled
        # carry, so any non-finite/runaway entry in any of them resets the
        # whole layer to safe init (zeros — the fresh-layer warm start).
        # jnp.where on the scalar verdict keeps healthy carries bitwise
        # unchanged, so the watchdog is free to leave enabled.
        fkeys = [k for k in ("q_ema", "q_err") if k in state]
        stacked = jnp.concatenate([q0] + [state[k] for k in fkeys]) if fkeys else q0
        _, dual_healthy = ref_bip.sanitize_duals(stacked, cfg.dual_abs_limit)
        q0 = jnp.where(dual_healthy, q0, jnp.zeros_like(q0))
        for k in fkeys:
            new_state[k] = jnp.where(
                dual_healthy, state[k], jnp.zeros_like(state[k])
            )
        state = new_state  # the forecaster below must read the sanitized carry
        new_q = q0

    # sync='global': the dual update runs with psum-reduced counts over the
    # data axes, so q converges identically on every shard (DESIGN.md
    # §Global-sync). Empty data_axes (single device, or a caller outside
    # shard_map) degrades to the plain per-batch update.
    global_axes = tuple(cfg.data_axes) if cfg.sync == "global" else ()

    if cfg.strategy == "bip":
        if cfg.forecast and (cfg.sync != "global" or cfg.use_kernel):
            _warn_once(
                "forecast-inactive",
                "RouterConfig.forecast only drives the reference sync='global' "
                "bisection path; with sync='local' or use_kernel=True the "
                "forecaster state is carried but never consulted.",
            )
        if cfg.sync == "global" and cfg.use_kernel and token_mask is None:
            # collective Pallas path: the kernel's (m, n_bins) histogram
            # counts are psum'd across cfg.data_axes between the count pass
            # and the rank location, so the kernel now has a true global
            # form (kernels/ops.py). Empty data_axes degrades to the plain
            # single-device kernel.
            from repro.kernels import ops as kernel_ops  # lazy: import cycle

            q = kernel_ops.bip_dual_update(
                lax.stop_gradient(s), q0,
                top_k=cfg.top_k, n_iters=cfg.bip_iters,
                axis_names=global_axes,
            )
            corrected = s - q[None, :]
            new_q = q
        elif cfg.sync == "global" or token_mask is not None:
            # one implementation serves the mesh path (axis_names), the
            # serving path (token_mask), AND the unsharded sync='global'
            # reference (axes=()): all three share the bisection numerics,
            # so a sharded global-sync run reproduces the single-device
            # trajectory bit-for-bit at the dual level — the sort-based
            # update would instead park q exactly ON the capacity-marginal
            # token's score and make the comparison tie-degenerate.
            if cfg.use_kernel:  # only reachable with a token mask
                _warn_once(
                    "kernel-masked",
                    "use_kernel=True has no masked (serving-padding) form; "
                    "falling back to the reference masked dual update.",
                )
            # load forecaster: predict the pre-clamp order statistic t from
            # its EMA, bracket it by the EMA'd error, and let the bisection
            # validate the bracket in-band (free when stale, rounds saved
            # when right)
            use_forecast = cfg.forecast and not cfg.use_kernel and "q_ema" in state
            window = None
            if use_forecast:
                half = cfg.forecast_margin * state["q_err"] + cfg.forecast_floor
                window = (state["q_ema"] - half, state["q_ema"] + half)
            # scores are softmax/sigmoid outputs, so [0, 1] is a static
            # bracket: no data-dependent (pmin/pmax) bound collectives
            q, _, t = ref_bip.bip_dual_update_global(
                lax.stop_gradient(s), q0,
                top_k=cfg.top_k, n_iters=cfg.bip_iters,
                token_mask=token_mask, axis_names=global_axes,
                n_bisect=cfg.n_bisect, fanout=cfg.bisect_fanout,
                score_bounds=(0.0, 1.0), window=window, with_stats=True,
            )
            if use_forecast:
                d = cfg.forecast_decay
                err = jnp.abs(t - state["q_ema"])
                new_state["q_ema"] = d * state["q_ema"] + (1.0 - d) * t
                new_state["q_err"] = d * state["q_err"] + (1.0 - d) * err
            corrected = s - q[None, :]
            new_q = q
        elif local_shards > 1 and cfg.sync == "local":
            s_grp = lax.stop_gradient(s).reshape(local_shards, n // local_shards, m)
            q_grp = jax.vmap(lambda sg: _bip_q(sg, q0, cfg))(s_grp)  # (S, m)
            corrected = (
                s.reshape(local_shards, -1, m) - q_grp[:, None, :]
            ).reshape(n, m)
            new_q = q_grp.mean(axis=0)  # replicated warm start for next batch
        else:
            q = _bip_q(lax.stop_gradient(s), q0, cfg)
            corrected = s - q[None, :]
            new_q = q
        w, idx = _topk_select(s, corrected, cfg)
        if not cfg.bip_warm_start:
            new_q = jnp.zeros_like(q0)

    elif cfg.strategy == "lossfree":
        # bias is ADDED to scores for selection (Wang et al. eq. for g').
        corrected = s + q0[None, :]
        w, idx = _topk_select(s, corrected, cfg)
        # Per-batch sign update: b += u * sign(mean_load - load_j).
        onehot = jax.nn.one_hot(idx, m, dtype=cfg.router_dtype)
        if token_mask is not None:
            onehot = onehot * token_mask.astype(cfg.router_dtype)[:, None, None]
        load = lax.stop_gradient(onehot.sum(axis=(0, 1)))
        if global_axes:
            # global sign update: every shard sees the same selection
            # histogram, so the carried bias stays bit-identical across
            # devices (vs pmean-averaging per-shard sign updates)
            load = lax.psum(load, global_axes)
        err = load.mean() - load
        new_q = q0 + cfg.lossfree_lr * jnp.sign(err)

    elif cfg.strategy == "aux_loss":
        w, idx = _topk_select(s, s, cfg)
        aux = _aux_loss(s, idx, cfg, token_mask)

    else:  # 'topk'
        w, idx = _topk_select(s, s, cfg)

    metrics = balance_metrics(idx, m, cfg.top_k)
    new_state["q"] = new_q
    return RouterOutput(
        combine_weights=w,
        expert_index=idx,
        state={k: lax.stop_gradient(v) for k, v in new_state.items()},
        aux_loss=aux,
        metrics=metrics,
    )


__all__ = [
    "DispatchPlan",
    "compute_scores",
    "init_router_state",
    "make_dispatch_plan",
    "route",
    "RouterConfig",
    "RouterOutput",
]
