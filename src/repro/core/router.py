"""Unified top-k router — a thin orchestrator over the balancer registry.

`route()` resolves cfg.strategy through `core.balancers` and drives the hook
protocol in a fixed order (score → guard → score_adjust → select → aux_loss →
update_state → metrics); every balancing method — the paper's four
(topk / aux_loss / lossfree / bip) and the registry additions (phi / lpr /
expert_choice) — plugs in behind the same call. See core/balancers.py for
the protocol and the per-method semantics.

All strategies share RouterState {'q': (m,)}; for 'lossfree' the vector plays
the role of the bias b (added), for 'bip' the dual price q (subtracted), for
'phi' the multiplicative log-correction. Gate *values* are always the raw
scores of the selected experts, so none of these vectors receive gradient —
only 'aux_loss' shapes gradients, via its explicit loss.

The router is functional: `route(logits, state, cfg)` returns RouterOutput with
the new state; the training loop threads state through like any other pytree.

Distribution note (see DESIGN.md §3.3 / §Global-sync): under plain jit/pjit
the math below is written over the *global* token batch, so single-program
callers get paper-global duals for free — XLA inserts the collectives for the
column order statistic when tokens are sharded. Inside a shard_map (the EP
paths in models/moe.py) each device sees only its token shard, and
cfg.sync selects the semantics: 'global' runs the threshold dual update with
psum-reduced counts over cfg.data_axes (`ref_bip.bip_dual_update_global`) so
every device converges on the same q over the global batch; 'local' solves a
per-shard BIP and the caller averages the warm-start duals. sync='local' with
`local_shards > 1` additionally lets a single-program caller emulate the
per-shard semantics by vmapping the dual update over token groups.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import balancers, ref_bip
from repro.core.types import RouterConfig, RouterOutput, init_router_state


# ------------------------------------------------------- dispatch plan
#
# Sort-based ragged dispatch (megablocks-style, Gale et al.): one stable
# argsort of the (n·k,) expert assignments replaces the (n·k, m) one-hot +
# serial cumsum bookkeeping, and packing/combining become pure gathers —
# no m-wide intermediate, no repeat(x, k) materialization, no scatter-add
# over d-wide activations. Semantics match the historical one-hot plan
# bit-for-bit: capacity queues are token-ordered (earlier tokens win),
# slot-major within a token, and token_mask rows never occupy capacity.


@dataclasses.dataclass(frozen=True)
class DispatchPlan:
    """Ragged routing plan consumed within a single trace (not a pytree).

    order    (n·k,) stable argsort of expert assignments (masked → sentinel m)
    offsets  (m+1,) segment start of each expert's queue in sorted order
    pos      (n, k) position of each (token, slot) in its expert's queue
    keep     (n, k) slot survives capacity (and token_mask)
    """

    expert_index: jnp.ndarray  # (n, k) int32
    order: jnp.ndarray
    offsets: jnp.ndarray
    pos: jnp.ndarray
    keep: jnp.ndarray
    capacity: int
    top_k: int

    @property
    def counts(self) -> jnp.ndarray:
        """Per-expert assigned load (m,), pre-capacity, masked rows excluded."""
        return self.offsets[1:] - self.offsets[:-1]

    def pack(
        self,
        x: jnp.ndarray,  # (n, d)
        *,
        expert_offset=0,  # first expert owned locally (may be traced)
        n_local: Optional[int] = None,  # experts packed (static); default all
    ) -> jnp.ndarray:
        """Gather tokens into the (n_local, capacity, d) expert buffers."""
        nk = self.order.shape[0]
        m_loc = (self.offsets.shape[0] - 1) if n_local is None else n_local
        cap = self.capacity
        slots = jnp.arange(m_loc * cap, dtype=jnp.int32)
        se = expert_offset + slots // cap
        src_sorted = jnp.take(self.offsets, se) + slots % cap
        valid = src_sorted < jnp.take(self.offsets, se + 1)
        src_tok = jnp.take(self.order, jnp.minimum(src_sorted, nk - 1)) // self.top_k
        buf = jnp.take(x, src_tok, axis=0) * valid[:, None].astype(x.dtype)
        return buf.reshape(m_loc, cap, x.shape[-1])

    def combine(
        self,
        y: jnp.ndarray,  # (n_local, capacity, d) expert outputs
        weights: jnp.ndarray,  # (n, k) combine weights
        *,
        expert_offset=0,
    ) -> jnp.ndarray:
        """Gather expert outputs back per (token, slot), weight, and sum."""
        m_loc, cap, d = y.shape
        n, k = self.expert_index.shape
        e_rel = self.expert_index - expert_offset
        ok = (self.keep & (e_rel >= 0) & (e_rel < m_loc)).reshape(-1)
        slot = (e_rel * cap + self.pos).reshape(-1)
        g = jnp.take(y.reshape(m_loc * cap, d), jnp.where(ok, slot, 0), axis=0)
        w = weights.reshape(-1, 1).astype(y.dtype)
        contrib = jnp.where(ok[:, None], g * w, 0.0)
        return contrib.reshape(n, k, d).sum(axis=1)


def make_dispatch_plan(
    expert_index: jnp.ndarray,  # (n, k) int32
    n_experts: int,
    capacity: int,
    token_mask: Optional[jnp.ndarray] = None,  # (n,) bool; False never dispatches
) -> DispatchPlan:
    """Build the sort-based plan for one routed batch.

    Masked tokens are re-keyed to the sentinel expert m, so the stable sort
    pushes them past every real segment: they neither occupy capacity nor
    displace real tokens, and `counts` covers real traffic only.
    """
    n, k = expert_index.shape
    nk = n * k
    flat = expert_index.reshape(-1).astype(jnp.int32)
    if token_mask is not None:
        flat = jnp.where(jnp.repeat(token_mask, k), flat, n_experts)
    order = jnp.argsort(flat, stable=True).astype(jnp.int32)
    sorted_e = jnp.take(flat, order)
    offsets = jnp.searchsorted(
        sorted_e, jnp.arange(n_experts + 1, dtype=jnp.int32)
    ).astype(jnp.int32)
    # rank within the expert's segment == position in its capacity queue
    pos_sorted = jnp.arange(nk, dtype=jnp.int32) - jnp.take(offsets, sorted_e)
    pos = jnp.zeros((nk,), jnp.int32).at[order].set(pos_sorted).reshape(n, k)
    keep = pos < capacity
    if token_mask is not None:
        keep = keep & token_mask[:, None]
    return DispatchPlan(
        expert_index=expert_index.astype(jnp.int32),
        order=order,
        offsets=offsets,
        pos=pos,
        keep=keep,
        capacity=capacity,
        top_k=k,
    )


def compute_scores(logits: jnp.ndarray, cfg: RouterConfig) -> jnp.ndarray:
    """Gating function G. Paper / minimind: softmax over experts."""
    logits = logits.astype(cfg.router_dtype)
    if cfg.score_fn == "softmax":
        return jax.nn.softmax(logits, axis=-1)
    return jax.nn.sigmoid(logits)


def route(
    logits: jnp.ndarray,
    state: Dict[str, jnp.ndarray],
    cfg: RouterConfig,
    *,
    local_shards: int = 1,
    token_mask=None,
) -> RouterOutput:
    """Route a flattened batch of tokens.

    logits: (n, m) router logits (pre-gating-function).
    state:  {'q': (m,)} carried vector (ADMM warm start / Loss-Free bias /
      φ-correction); methods add their own leaves (bip forecast:
      'q_ema'/'q_err' EMAs; lpr: 'proto' prototype matrix). Unrecognized
      keys pass through untouched.
    token_mask: optional (n,) bool — serving padding rows are False; they
      still get selections (static shapes) but are excluded from every
      state update and loss, so the carried q tracks real traffic only
      even when decode-heavy chunks are mostly padding (DESIGN.md §Serving).
      Strategies whose selection is not per-token causal (expert_choice)
      reject the masked/serving path outright.
    """
    n, m = logits.shape
    assert m == cfg.n_experts, (m, cfg.n_experts)
    bal = balancers.get_balancer(cfg.strategy)
    bal.check_config(cfg)
    if token_mask is not None and not bal.serving_ok:
        raise NotImplementedError(
            f"strategy {cfg.strategy!r} is training-only: its selection for "
            "one token depends on the whole batch (an expert's top-C can "
            "evict a token when later tokens arrive), so the masked "
            "serving/decode path would break causality."
        )
    s = compute_scores(logits, cfg)
    # carry every state key through unchanged unless a hook updates it, so
    # the router-state pytree structure is stable across scan/loop carries
    new_state = dict(state)

    if cfg.guard_duals:
        # dual-health watchdog: the balancer's guarded keys (q, plus e.g.
        # the bip forecaster EMAs) are one coupled carry, so any
        # non-finite/runaway entry in any of them resets them all to safe
        # init (zeros — the fresh-layer warm start). jnp.where on the
        # scalar verdict keeps healthy carries bitwise unchanged, so the
        # watchdog is free to leave enabled.
        gkeys = bal.guard_keys(state)
        vecs = [state[k] for k in gkeys]
        stacked = jnp.concatenate(vecs) if len(vecs) > 1 else vecs[0]
        _, dual_healthy = ref_bip.sanitize_duals(stacked, cfg.dual_abs_limit)
        for k in gkeys:
            new_state[k] = jnp.where(
                dual_healthy, state[k], jnp.zeros_like(state[k])
            )
        # the hooks below must read the sanitized carry (a copy, so later
        # new_state updates cannot leak into the hooks' view of `state`)
        state = dict(new_state)

    # sync='global': state updates run with psum-reduced statistics over the
    # data axes, so the carried state converges identically on every shard
    # (DESIGN.md §Global-sync). Empty data_axes (single device, or a caller
    # outside shard_map) degrades to the plain per-batch update.
    global_axes = tuple(cfg.data_axes) if cfg.sync == "global" else ()

    with jax.named_scope("router/score_adjust"):
        adjusted = bal.score_adjust(
            s, state, cfg,
            token_mask=token_mask, axis_names=global_axes,
            local_shards=local_shards,
        )
    # hooks may return (corrected, updates) or (corrected, updates,
    # telemetry): the optional third dict carries method-specific health
    # scalars (e.g. bip forecaster error / window-hit rate) straight into
    # the metrics — already-computed values only, never extra collectives
    if len(adjusted) == 3:
        corrected, pre_updates, hook_telemetry = adjusted
    else:
        corrected, pre_updates = adjusted
        hook_telemetry = {}
    new_state.update(pre_updates)
    with jax.named_scope("router/select"):
        w, idx = bal.select(s, corrected, cfg)
    aux = bal.aux_loss(s, idx, cfg, token_mask)
    with jax.named_scope("router/update_state"):
        new_state.update(
            bal.update_state(
                s, idx, state, cfg, token_mask=token_mask, axis_names=global_axes
            )
        )
    metrics = dict(balancers.router_metrics(bal, s, w, idx, cfg))
    metrics.update(hook_telemetry)
    # dual-carry magnitude: every strategy carries 'q' (bias / dual price /
    # log-correction), so its sup-norm is a universal health signal
    metrics["q_abs_max"] = jnp.max(jnp.abs(new_state["q"]))
    return RouterOutput(
        combine_weights=w,
        expert_index=idx,
        state={k: lax.stop_gradient(v) for k, v in new_state.items()},
        aux_loss=aux,
        metrics=metrics,
    )


__all__ = [
    "DispatchPlan",
    "compute_scores",
    "init_router_state",
    "make_dispatch_plan",
    "route",
    "RouterConfig",
    "RouterOutput",
]
