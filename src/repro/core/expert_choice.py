"""Expert-Choice routing [Zhou et al. 2022] — the beyond-paper comparison.

Instead of tokens picking experts (token-choice, what BIP balances), each
EXPERT picks its top-C tokens (C = k·n/m). Balance is then perfect *by
construction* — but the assignment solves a different program: column-wise
greedy selection rather than the global (BIP) objective, so

  * tokens may receive fewer than k experts (possibly zero) — "coverage"
    loss instead of capacity drops;
  * the total routed score mass is below the LP optimum whenever popular
    tokens crowd out others;
  * it is incompatible with autoregressive DECODING (an expert's top-C over
    the batch leaks future tokens within a sequence during training-style
    batched selection) — the standard caveat.

`benchmarks.expert_choice_compare` quantifies the trade against BIP:
balance (trivially 0 violation) vs objective ratio vs token coverage.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def expert_choice_route(
    s: jnp.ndarray, top_k: int
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Each expert takes its top-C tokens, C = ceil(k·n/m).

    Returns (assignment mask (n, m) float — gate values on selected pairs,
    metrics dict with coverage/load stats).
    """
    n, m = s.shape
    c = max((n * top_k) // m, 1)
    # top-C tokens per expert (column-wise)
    _, idx = lax.top_k(s.T, c)  # (m, C) token indices
    mask = jnp.zeros((n, m), s.dtype)
    expert_ids = jnp.broadcast_to(jnp.arange(m)[:, None], (m, c))
    mask = mask.at[idx.reshape(-1), expert_ids.reshape(-1)].set(1.0)
    gates = mask * s

    per_token = mask.sum(axis=1)  # experts per token
    mets = {
        "load": mask.sum(axis=0),               # == C per expert (perfect)
        "max_vio": jnp.zeros(()),               # by construction
        "coverage_full": jnp.mean((per_token >= top_k).astype(jnp.float32)),
        "coverage_zero": jnp.mean((per_token == 0).astype(jnp.float32)),
        "mean_experts_per_token": per_token.mean(),
        "objective": gates.sum(),
    }
    return gates, mets


def expert_choice_select(
    s: jnp.ndarray, top_k: int, *, norm_topk_prob: bool = False
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-choice assignment in the router's (n, k) token-slot interface.

    Runs the per-expert top-C selection, then re-reads it token-wise: each
    token keeps its k highest-gate assignments as (combine_weights,
    expert_index) rows. Slots beyond a token's assignments carry the
    SENTINEL index m with zero weight — the dispatch plan sorts the
    sentinel past every real segment, so uncovered slots occupy no
    capacity and no load. A token picked by more than k experts keeps only
    its k best (the interface is fixed-width); coverage metrics count the
    kept assignments.
    """
    n, m = s.shape
    gates, _ = expert_choice_route(s, top_k)  # (n, m) gate values on pairs
    w, idx = lax.top_k(gates, top_k)
    selected = w > 0.0
    idx = jnp.where(selected, idx, m).astype(jnp.int32)
    w = jnp.where(selected, w, 0.0)
    if norm_topk_prob:
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return w, idx
