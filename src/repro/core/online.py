"""Algorithm 3 — online BIP-Based Balancing, one routing gate.

Tokens arrive one at a time; the gate keeps, per expert j, the multiset
Q_j = {s_j - p} of price-shifted scores seen so far, and the current dual
price q_j. Each arrival is routed by top-k over (s - q), then q is refreshed
by T rounds of:

    p   = max(0, (k+1)-th largest of {s_l - q_l})
    q_j = max(0, (rank)-th largest of Q_j ∪ {s_j - p})

Two capacity modes:

* faithful (adaptive_capacity=False): rank = nk/m + 1 with n the full nominal
  horizon, exactly Algorithm 3. The capacity constraint only starts to bind
  once |Q_j| exceeds nk/m, so balance is a property of the *whole* stream,
  not of early prefixes. Per-expert min-heaps keep the top (cap+1) members —
  lossless for this query since adding elements can only move the order
  statistic up — giving the paper's O(m log n) per-token cost (§5.2).

* adaptive (adaptive_capacity=True, default): rank = t·k/m + 1 where t is the
  number of tokens seen so far. The price binds from the start, giving prefix
  balance (the property the batch Algorithm 1 has). Needs the full multiset
  (ranks grow), so it stores all shifted scores — use ApproxBIPGate
  (Algorithm 4) for constant-space adaptive behaviour at scale.
"""
from __future__ import annotations

import heapq
from typing import List, Sequence, Tuple

import numpy as np


class OnlineBIPGate:
    """Streaming gate: call .route(scores) once per arriving token."""

    def __init__(
        self,
        n_tokens: int,
        n_experts: int,
        top_k: int,
        n_iters: int = 2,
        adaptive_capacity: bool = True,
    ):
        self.n = n_tokens            # nominal horizon (faithful-mode capacity)
        self.m = n_experts
        self.k = top_k
        self.t_iters = n_iters
        self.adaptive = adaptive_capacity
        self.q = np.zeros(n_experts, dtype=np.float64)
        self.cap = max(int(n_tokens * top_k // n_experts), 1)
        # faithful mode: min-heap per expert with top min(|Q_j|, cap+1) members
        self.heaps: List[List[float]] = [[] for _ in range(n_experts)]
        # adaptive mode: full history, shape (m, t)
        self._hist: List[np.ndarray] = []
        self.seen = 0

    # -- order statistics ----------------------------------------------------

    def _kth_of_union_heap(self, j: int, extra: float) -> float:
        """(cap+1)-th largest of Q_j ∪ {extra}, O(1), faithful mode."""
        h = self.heaps[j]
        size = self.seen  # |Q_j| == tokens seen (every token feeds every Q_j)
        if size + 1 <= self.cap:
            return 0.0  # union smaller than cap+1 -> capacity constraint slack
        if size == self.cap:
            return min(h[0], extra)  # union has exactly cap+1: its minimum
        root = h[0]  # heap holds top cap+1 of Q_j; root IS the answer sans extra
        if extra <= root:
            return root
        second = min(h[1:3]) if len(h) > 1 else extra
        return min(extra, second)

    def _kth_adaptive(self, shifted: np.ndarray) -> np.ndarray:
        """rank_t-th largest of Q_j ∪ {shifted_j}, vectorized over experts."""
        t = self.seen + 1  # union size
        rank = int(t * self.k // self.m) + 1  # (t·k/m + 1)-th largest
        if rank > t:
            return np.zeros(self.m)
        hist = np.vstack(self._hist + [shifted])  # (t, m)
        part = np.partition(hist, t - rank, axis=0)[t - rank]  # rank-th largest
        return np.maximum(part, 0.0)

    # -- public API -----------------------------------------------------------

    def route(self, scores: Sequence[float]) -> Tuple[np.ndarray, np.ndarray]:
        """Route one token. Returns (top-k expert ids, gate values = raw s)."""
        s = np.asarray(scores, dtype=np.float64)
        assert s.shape == (self.m,)
        corrected = s - self.q
        idx = np.argsort(-corrected, kind="stable")[: self.k]
        gates = s[idx]

        p = 0.0
        for _ in range(self.t_iters):
            if self.k < self.m:
                part = np.partition(s - self.q, self.m - self.k - 1)
                p = max(0.0, float(part[self.m - self.k - 1]))
            shifted = s - p
            if self.adaptive:
                self.q = self._kth_adaptive(shifted)
            else:
                for j in range(self.m):
                    self.q[j] = max(0.0, self._kth_of_union_heap(j, float(shifted[j])))

        # Commit s_j - p into each Q_j (line 13-14 of Algorithm 3).
        shifted = s - p
        if self.adaptive:
            self._hist.append(shifted.copy())
        else:
            for j in range(self.m):
                h = self.heaps[j]
                if len(h) <= self.cap:  # keep up to cap+1 members
                    heapq.heappush(h, float(shifted[j]))
                elif shifted[j] > h[0]:
                    heapq.heapreplace(h, float(shifted[j]))
        self.seen += 1
        return idx.astype(np.int64), gates

    def load_stats(self, assignments: np.ndarray) -> dict:
        load = np.bincount(assignments.reshape(-1), minlength=self.m)
        mean = max(self.seen * self.k / self.m, 1e-9)
        return {"load": load, "max_vio": float(load.max()) / mean - 1.0}
