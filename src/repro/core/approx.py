"""Algorithm 4 — online BIP balancing with O(m·b) constant space (histograms).

Instead of keeping the multisets Q_j, keep per-expert histograms over [0, 1)
with b bins. The (nk/m + 1)-th largest member is located by walking bin counts
from the top and linearly interpolating inside the located bin. Space is
O(m·b) regardless of stream length — the variant the paper recommends for
recommendation/ad-allocation scale (§5.2).

Vectorized over experts with numpy (this is a host-side streaming algorithm).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np


class ApproxBIPGate:
    """Streaming gate with histogram-approximated order statistics."""

    def __init__(
        self,
        n_tokens: int,
        n_experts: int,
        top_k: int,
        n_bins: int = 64,
        n_iters: int = 2,
        adaptive_capacity: bool = True,
    ):
        self.n = n_tokens
        self.m = n_experts
        self.k = top_k
        self.b = n_bins
        self.t_iters = n_iters
        self.adaptive = adaptive_capacity
        self.cap = max(int(n_tokens * top_k // n_experts), 1)
        self.q = np.zeros(n_experts, dtype=np.float64)
        # hist[j, l] counts members of Q_j in [l/b, (l+1)/b). Negative shifted
        # scores (s_j - p < 0) are clamped out (they can never top the order
        # statistic that matters, since q >= 0).
        self.hist = np.zeros((n_experts, n_bins), dtype=np.float64)
        self.seen = 0

    def _q_from_hist(self, extra: np.ndarray) -> np.ndarray:
        """Vectorized: (cap+1)-th largest of hist_j ∪ {extra_j}, interpolated."""
        h = self.hist.copy()
        valid = extra >= 0.0
        bins = np.clip((extra * self.b).astype(np.int64), 0, self.b - 1)
        h[np.arange(self.m)[valid], bins[valid]] += 1.0
        # cumulative count from the top bin downwards
        desc = h[:, ::-1]
        csum = np.cumsum(desc, axis=1)  # csum[:, i] = count in top i+1 bins
        if self.adaptive:  # rank grows with the stream: (t·k/m + 1)-th largest
            rank = int((self.seen + 1) * self.k // self.m) + 1
        else:
            rank = self.cap + 1
        total = csum[:, -1]
        located = csum >= rank  # first True column holds the answer
        has = located.any(axis=1)
        first = np.where(has, located.argmax(axis=1), 0)
        l = self.b - 1 - first  # original bin index
        # interpolate inside bin [l/b, (l+1)/b): fraction of the bin's count
        # still above the target rank.
        cnt_in = np.take_along_axis(h, l[:, None], axis=1)[:, 0]
        cnt_above = np.where(
            first > 0,
            np.take_along_axis(csum, (first - 1)[:, None].clip(min=0), axis=1)[:, 0],
            0.0,
        )
        need = rank - cnt_above  # 1 <= need <= cnt_in where located
        frac = np.where(cnt_in > 0, 1.0 - need / np.maximum(cnt_in, 1.0), 0.0)
        val = (l + frac) / self.b
        q = np.where(has & (total >= rank), np.maximum(val, 0.0), 0.0)
        return q

    def route(self, scores: Sequence[float]) -> Tuple[np.ndarray, np.ndarray]:
        s = np.asarray(scores, dtype=np.float64)
        assert s.shape == (self.m,)
        corrected = s - self.q
        idx = np.argsort(-corrected, kind="stable")[: self.k]
        gates = s[idx]

        p = 0.0
        for _ in range(self.t_iters):
            if self.k < self.m:
                p = max(0.0, float(np.partition(s - self.q, self.m - self.k - 1)[self.m - self.k - 1]))
            shifted = s - p
            self.q = self._q_from_hist(shifted)

        # Commit into histograms (line 15: Q = Q').
        shifted = s - p
        valid = shifted >= 0.0
        bins = np.clip((shifted * self.b).astype(np.int64), 0, self.b - 1)
        self.hist[np.arange(self.m)[valid], bins[valid]] += 1.0
        self.seen += 1
        return idx.astype(np.int64), gates

    def load_stats(self, assignments: np.ndarray) -> dict:
        load = np.bincount(assignments.reshape(-1), minlength=self.m)
        mean = max(self.seen * self.k / self.m, 1e-9)
        return {"load": load, "max_vio": float(load.max()) / mean - 1.0}
