"""Exact LP-relaxation oracle for the assignment problem (test-time only).

Solves (P-LP) from the paper with scipy.optimize.linprog (HiGHS):

    max Σ s_ij x_ij   s.t.  Σ_j x_ij <= k,  Σ_i x_ij <= kn/m,  0 <= x <= 1.

Used by tests/benchmarks to measure how close the ADMM-iterated routing gets
to the true optimum (objective ratio), and to check that the primal solution
recovered from the dual prices matches complementary slackness.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def solve_plp(s: np.ndarray, top_k: int) -> Tuple[np.ndarray, float]:
    """Returns (x (n,m) in [0,1], optimal objective value)."""
    from scipy.optimize import linprog
    from scipy.sparse import lil_matrix

    n, m = s.shape
    cap = top_k * n / m
    nv = n * m
    a = lil_matrix((n + m, nv))
    for i in range(n):  # row constraints: sum_j x_ij <= k
        a[i, i * m : (i + 1) * m] = 1.0
    for j in range(m):  # column constraints: sum_i x_ij <= kn/m
        a[n + j, j::m] = 1.0
    b = np.concatenate([np.full(n, float(top_k)), np.full(m, cap)])
    res = linprog(
        c=-s.reshape(-1),
        A_ub=a.tocsr(),
        b_ub=b,
        bounds=(0.0, 1.0),
        method="highs",
    )
    if not res.success:
        raise RuntimeError(f"linprog failed: {res.message}")
    return res.x.reshape(n, m), -res.fun


def routing_objective(s: np.ndarray, expert_index: np.ndarray) -> float:
    """Σ s_ij over the selected (token, expert) pairs."""
    return float(np.take_along_axis(s, expert_index, axis=-1).sum())


def greedy_balanced_objective(s: np.ndarray, top_k: int) -> float:
    """Cheap feasible lower bound: tokens in order, greedy under hard capacity."""
    n, m = s.shape
    cap = int(np.ceil(top_k * n / m))
    load = np.zeros(m, dtype=np.int64)
    total = 0.0
    for i in range(n):
        order = np.argsort(-s[i])
        picked = 0
        for j in order:
            if load[j] < cap:
                load[j] += 1
                total += s[i, j]
                picked += 1
                if picked == top_k:
                    break
    return total
