"""repro.core — BIP-Based Expert Load Balancing (the paper's contribution).

Public surface:
  RouterConfig / init_router_state / route   — unified gate over the registry
  Balancer / register_balancer / get_balancer — pluggable strategy protocol
  bip_dual_update / bip_route_reference      — pure-jnp Algorithm 1/2 oracle
  OnlineBIPGate / ApproxBIPGate              — Algorithm 3 / 4 (streaming)
  balance_metrics / BalanceTracker           — MaxVio / AvgMaxVio / SupMaxVio
"""
from repro.core.approx import ApproxBIPGate
from repro.core.balancers import (
    Balancer,
    get_balancer,
    register_balancer,
    registered_balancers,
)
from repro.core.metrics import BalanceTracker, balance_metrics, expert_load, max_violation
from repro.core.online import OnlineBIPGate
from repro.core.ref_bip import (
    bisect_rounds,
    bip_dual_update,
    bip_dual_update_global,
    bip_dual_update_masked,
    bip_dual_update_threshold,
    bip_route_reference,
    bip_topk,
    kth_largest,
    kth_largest_threshold,
)
from repro.core.router import DispatchPlan, compute_scores, make_dispatch_plan, route
from repro.core.types import RouterConfig, RouterOutput, init_router_state

__all__ = [
    "ApproxBIPGate",
    "Balancer",
    "BalanceTracker",
    "OnlineBIPGate",
    "RouterConfig",
    "RouterOutput",
    "balance_metrics",
    "get_balancer",
    "register_balancer",
    "registered_balancers",
    "bisect_rounds",
    "bip_dual_update",
    "bip_dual_update_global",
    "bip_dual_update_masked",
    "bip_dual_update_threshold",
    "bip_route_reference",
    "bip_topk",
    "compute_scores",
    "DispatchPlan",
    "expert_load",
    "init_router_state",
    "make_dispatch_plan",
    "kth_largest",
    "kth_largest_threshold",
    "max_violation",
    "route",
]
