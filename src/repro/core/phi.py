"""φ-Balancing (arxiv 2605.15403) — gradient-free multiplicative gate correction.

Where Loss-Free adds a bias to the selection scores and BIP subtracts a dual
price, φ-Balancing rescales each expert's gate multiplicatively: the carried
log-correction φ_j shrinks over-loaded experts' scores by exp(-φ_j) and the
per-batch update integrates the relative load error,

    corrected_ij = s_ij · exp(-φ_j)
    φ_j        += φ_lr · (Load_j / mean_load − 1)
    φ          −= mean(φ)                       (recentring)

The recentring keeps φ bounded without changing any selection: a uniform
shift of φ multiplies every corrected score by the same exp(c) > 0, and
top-k is invariant to a positive uniform scaling. Like Loss-Free the update
is gradient-free (gate VALUES stay the raw scores, so φ receives no
gradient), but the correction is proportional rather than additive, so its
strength follows the score scale instead of competing with it — relevant
for sigmoid scoring where additive biases can dominate small scores.

The carried φ lives in the shared 'q' state slot ((m,) like the BIP dual /
Loss-Free bias), so checkpoints, layer stacking, sharding specs, and the
dual-health watchdog all apply unchanged. Under cfg.sync='global' the load
histogram is psum-reduced over the data axes before the update, so every
shard integrates the same error and φ stays bit-identical across devices;
masked serving rows are excluded from the histogram (token_mask) exactly as
for Loss-Free.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.balancers import Balancer, register_balancer, selection_load


@register_balancer("phi")
class PhiBalancer(Balancer):
    """Multiplicative gate correction with an integrating load-error update."""

    uses_sync = True

    def score_adjust(self, s, state, cfg, *, token_mask=None, axis_names=(),
                     local_shards=1):
        return s * jnp.exp(-state["q"])[None, :], {}

    def update_state(self, s, idx, state, cfg, *, token_mask=None, axis_names=()):
        m = s.shape[-1]
        load = selection_load(idx, m, cfg.router_dtype, token_mask, axis_names)
        # masked serving chunks can be entirely padding -> zero mean load
        mean_load = jnp.maximum(load.mean(), 1e-9)
        phi = state["q"] + cfg.phi_lr * (load / mean_load - 1.0)
        return {"q": phi - phi.mean()}
