"""Shared types for the routing core.

Everything is a frozen dataclass (static config) or a plain pytree (state), so it
composes with jax.jit / pjit without hashability surprises.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax.numpy as jnp

Array = Any  # jax.Array; kept loose so ShapeDtypeStruct stand-ins also pass.


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Static configuration of one routing gate.

    Attributes:
      n_experts: m, number of routed experts.
      top_k: k, experts chosen per token.
      strategy: any name in the balancer registry (core/balancers.py) —
        'topk' | 'aux_loss' | 'lossfree' | 'bip' | 'phi' | 'lpr' |
        'expert_choice' as shipped; validation resolves through
        `balancers.get_balancer`, so registering a new method makes it a
        valid strategy everywhere at once.
      bip_iters: T in Algorithm 1 (ADMM dual iterations per gate invocation).
      bip_warm_start: carry q across batches (paper: q is maintained per layer).
      aux_loss_alpha: α for the Loss-Controlled method.
      lossfree_lr: u, bias update rate for the Loss-Free method.
      norm_topk_prob: renormalize the selected gate values to sum to 1.
      score_fn: 'softmax' (paper / minimind) or 'sigmoid' (DeepSeek-V3 style).
      router_dtype: dtype for score/dual computation (fp32 for stability).
      use_kernel: route the ADMM dual update through the Pallas kernel.
      sync: 'local' computes dual prices from the device-local token shard
        (the caller averages them into the warm start); 'global' runs the
        threshold dual update with psum-reduced order statistics over
        data_axes so q matches the single-device paper semantics exactly
        (ref_bip.bip_dual_update_global; lossfree's sign update likewise
        uses the psum'd global selection histogram).
      data_axes: mesh axis name(s) tokens are sharded over (for sync='global';
        () means single-program / single-device, where global is the default).
      n_bisect: bits of bisection resolution for the threshold order
        statistic (sync='global' / masked paths); final bracket width is
        initial width * 2^-n_bisect.
      bisect_fanout: thresholds probed per fused bisection round; each round
        costs ONE collective and shrinks the bracket (fanout+1)x, so 32
        reaches 26-bit resolution in 6 rounds instead of 26.
      forecast: carry an EMA forecaster of the dual order statistic in
        router state and warm-start each bisection with its predicted
        bracket (validated in-band, so stale forecasts only cost the saved
        rounds). Reference global path only; adds 'q_ema'/'q_err' state.
      forecast_decay: EMA decay for the forecaster's mean and error scale.
      forecast_margin: half-width multiplier on the EMA'd |error| when
        forming the predicted bracket.
      forecast_floor: minimum half-width of the predicted bracket (keeps a
        freshly converged forecaster from proposing a degenerate window).
      guard_duals: dual-health watchdog (DESIGN.md §Robustness): before each
        update, reset a layer's carried state (q, and the forecaster EMAs
        when present) to safe init if any entry is non-finite or exceeds
        dual_abs_limit in magnitude. Healthy values pass through bitwise
        unchanged, so enabling the watchdog does not perturb a healthy run.
      dual_abs_limit: |q| runaway threshold for guard_duals. Softmax scores
        live in [0, 1] and useful duals in roughly [-1, 1], so the default
        is far outside any trajectory a healthy run produces.
      phi_lr: φ-Balancing integration rate for the multiplicative
        log-correction update (strategy='phi').
      lpr_decay: EMA decay d of the Latent-Prototype-Routing k-means
        prototype update (strategy='lpr').
      lpr_blend: λ ∈ [0, 1] mixing raw scores with prototype affinities in
        the LPR selection scores (0 = raw top-k, 1 = pure prototype
        assignment).
    """

    n_experts: int
    top_k: int
    strategy: str = "bip"
    bip_iters: int = 4
    bip_warm_start: bool = True
    aux_loss_alpha: float = 0.1
    lossfree_lr: float = 0.001
    norm_topk_prob: bool = False
    score_fn: str = "softmax"
    router_dtype: Any = jnp.float32
    use_kernel: bool = False
    sync: str = "local"
    data_axes: tuple = ()
    n_bisect: int = 26
    bisect_fanout: int = 32
    forecast: bool = False
    forecast_decay: float = 0.9
    forecast_margin: float = 4.0
    forecast_floor: float = 1e-3
    guard_duals: bool = False
    dual_abs_limit: float = 100.0
    phi_lr: float = 0.01
    lpr_decay: float = 0.99
    lpr_blend: float = 0.5

    def __post_init__(self):
        # strategy names resolve through the balancer registry — one
        # validation path for configs, CLIs, and sweeps (lazy import:
        # balancers imports RouterConfig from this module)
        from repro.core import balancers

        balancers.get_balancer(self.strategy)
        if not (0 < self.top_k <= self.n_experts):
            raise ValueError("need 0 < top_k <= n_experts")
        if self.score_fn not in ("softmax", "sigmoid"):
            raise ValueError(f"unknown score_fn {self.score_fn!r}")
        if self.sync not in ("local", "global"):
            raise ValueError(f"unknown sync mode {self.sync!r}")
        if self.n_bisect < 1:
            raise ValueError(f"n_bisect must be >= 1, got {self.n_bisect}")
        if self.bisect_fanout < 1:
            raise ValueError(f"bisect_fanout must be >= 1, got {self.bisect_fanout}")
        if not (0.0 <= self.forecast_decay < 1.0):
            raise ValueError(f"forecast_decay must be in [0, 1), got {self.forecast_decay}")
        if self.forecast_margin <= 0.0 or self.forecast_floor <= 0.0:
            raise ValueError("forecast_margin and forecast_floor must be > 0")
        if self.dual_abs_limit <= 0.0:
            raise ValueError(
                f"dual_abs_limit must be > 0, got {self.dual_abs_limit}"
            )
        if self.phi_lr <= 0.0:
            raise ValueError(f"phi_lr must be > 0, got {self.phi_lr}")
        if not (0.0 <= self.lpr_decay < 1.0):
            raise ValueError(f"lpr_decay must be in [0, 1), got {self.lpr_decay}")
        if not (0.0 <= self.lpr_blend <= 1.0):
            raise ValueError(f"lpr_blend must be in [0, 1], got {self.lpr_blend}")


def init_router_state(cfg: RouterConfig) -> Dict[str, Array]:
    """Per-gate mutable state, carried through the training loop as a pytree.

    Delegates to the registered balancer's `init_state` hook. Every method
    carries the (m,) 'q' slot (the ADMM warm start / Loss-Free bias /
    φ-correction), so checkpoints are strategy-portable; methods add their
    own leaves on top — bip's forecaster EMAs ('q_ema'/'q_err', (m,)),
    lpr's prototype matrix ('proto', (m, m)). All leaves ride the generic
    pytree machinery (tiling into layer stacks, replicated specs, npz
    checkpoints) with no special cases — and bit-exact checkpoint resume
    requires them to be saved/restored alongside q.
    """
    from repro.core import balancers  # lazy: balancers imports this module

    return balancers.get_balancer(cfg.strategy).init_state(cfg)


import jax


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RouterOutput:
    """Result of routing one flattened batch of n tokens.

    combine_weights: (n, k) gate values g for the selected experts.
    expert_index:    (n, k) int32 selected expert ids.
    state:           updated router state (q / bias vector).
    aux_loss:        scalar auxiliary loss (0 unless strategy='aux_loss').
    metrics:         dict with 'load' (m,), 'max_vio' (scalar), 'scores_mean'...
    """

    combine_weights: Array
    expert_index: Array
    state: Dict[str, Array]
    aux_loss: Array
    metrics: Dict[str, Array]
