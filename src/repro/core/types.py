"""Shared types for the routing core.

Everything is a frozen dataclass (static config) or a plain pytree (state), so it
composes with jax.jit / pjit without hashability surprises.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax.numpy as jnp

Array = Any  # jax.Array; kept loose so ShapeDtypeStruct stand-ins also pass.


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Static configuration of one routing gate.

    Attributes:
      n_experts: m, number of routed experts.
      top_k: k, experts chosen per token.
      strategy: one of 'topk' | 'aux_loss' | 'lossfree' | 'bip'.
      bip_iters: T in Algorithm 1 (ADMM dual iterations per gate invocation).
      bip_warm_start: carry q across batches (paper: q is maintained per layer).
      aux_loss_alpha: α for the Loss-Controlled method.
      lossfree_lr: u, bias update rate for the Loss-Free method.
      norm_topk_prob: renormalize the selected gate values to sum to 1.
      score_fn: 'softmax' (paper / minimind) or 'sigmoid' (DeepSeek-V3 style).
      router_dtype: dtype for score/dual computation (fp32 for stability).
      use_kernel: route the ADMM dual update through the Pallas kernel.
      sync: 'local' computes dual prices from the device-local token shard
        (the caller averages them into the warm start); 'global' runs the
        threshold dual update with psum-reduced order statistics over
        data_axes so q matches the single-device paper semantics exactly
        (ref_bip.bip_dual_update_global; lossfree's sign update likewise
        uses the psum'd global selection histogram).
      data_axes: mesh axis name(s) tokens are sharded over (for sync='global';
        () means single-program / single-device, where global is the default).
    """

    n_experts: int
    top_k: int
    strategy: str = "bip"
    bip_iters: int = 4
    bip_warm_start: bool = True
    aux_loss_alpha: float = 0.1
    lossfree_lr: float = 0.001
    norm_topk_prob: bool = False
    score_fn: str = "softmax"
    router_dtype: Any = jnp.float32
    use_kernel: bool = False
    sync: str = "local"
    data_axes: tuple = ()

    def __post_init__(self):
        if self.strategy not in ("topk", "aux_loss", "lossfree", "bip"):
            raise ValueError(f"unknown routing strategy {self.strategy!r}")
        if not (0 < self.top_k <= self.n_experts):
            raise ValueError("need 0 < top_k <= n_experts")
        if self.score_fn not in ("softmax", "sigmoid"):
            raise ValueError(f"unknown score_fn {self.score_fn!r}")
        if self.sync not in ("local", "global"):
            raise ValueError(f"unknown sync mode {self.sync!r}")


def init_router_state(cfg: RouterConfig) -> Dict[str, Array]:
    """Per-gate mutable state, carried through the training loop as a pytree.

    'q' doubles as the Loss-Free bias vector b (same shape, same role: an
    additive correction that reorders top-k), so checkpoints are strategy
    portable.
    """
    return {"q": jnp.zeros((cfg.n_experts,), dtype=cfg.router_dtype)}


import jax


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RouterOutput:
    """Result of routing one flattened batch of n tokens.

    combine_weights: (n, k) gate values g for the selected experts.
    expert_index:    (n, k) int32 selected expert ids.
    state:           updated router state (q / bias vector).
    aux_loss:        scalar auxiliary loss (0 unless strategy='aux_loss').
    metrics:         dict with 'load' (m,), 'max_vio' (scalar), 'scores_mean'...
    """

    combine_weights: Array
    expert_index: Array
    state: Dict[str, Array]
    aux_loss: Array
    metrics: Dict[str, Array]
