"""Latent Prototype Routing (arxiv 2506.21328) — prototype-assignment gating.

LPR reframes routing as online clustering in the gate-score simplex: each
expert j owns a learned prototype p_j, and a token's affinity to expert j is
its (squared-distance) closeness to p_j rather than the raw gate score
alone. With score row s_i, the affinity

    a_ij = −‖s_i − p_j‖² = 2 s_i·p_j − ‖p_j‖² − ‖s_i‖²

drops the per-token constant ‖s_i‖² (it shifts every expert's affinity for
token i equally, so top-k is invariant), and selection runs on the blend

    corrected_ij = (1 − λ) · s_ij + λ · (2 s_i·p_j − ‖p_j‖²),   λ = lpr_blend.

Prototypes track their assigned tokens with a gradient-free EMA k-means
step over the batch's selections:

    p_j ← d · p_j + (1 − d) · mean{ s_i : j ∈ topk(i) },   d = lpr_decay,

with empty clusters carried through unchanged. Under cfg.sync='global' the
assignment counts and score sums are psum-reduced over the data axes before
the division, so every shard applies the same prototype step (bit-identical
replicated state); masked serving rows are excluded from both sums.

State: the standard 'q' slot (carried but unused — keeps checkpoints
strategy-portable) plus 'proto', an (m, m) leaf initialized to the identity
(prototype j starts as the one-hot corner of expert j, which makes the
initial affinity ranking coincide with raw-score ranking as ‖p_j‖² is then
uniform). 'proto' is the first 2-D router-state leaf: it threads through
the generic pytree machinery (layer stacking, replicated sharding specs,
npz checkpoints) with no special cases — that genericity is pinned by the
checkpoint-resume bit-exactness test. The dual-health watchdog covers only
the (m,)-shaped 'q' slot; a poisoned prototype matrix would need a reset to
identity rather than zeros, so 'proto' is deliberately outside guard_keys.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.balancers import Balancer, register_balancer


@register_balancer("lpr")
class LPRBalancer(Balancer):
    """Prototype-assignment gate with an EMA k-means prototype update."""

    uses_sync = True
    # EP paths under sync='local' average BOTH carried leaves across data
    # shards, so the replicated-state invariant holds for 'proto' too
    local_avg_keys = ("q", "proto")

    def init_state(self, cfg):
        return {
            "q": jnp.zeros((cfg.n_experts,), dtype=cfg.router_dtype),
            "proto": jnp.eye(cfg.n_experts, dtype=cfg.router_dtype),
        }

    def score_adjust(self, s, state, cfg, *, token_mask=None, axis_names=(),
                     local_shards=1):
        proto = state["proto"]  # (m, m): row j = prototype of expert j
        affinity = 2.0 * (s @ proto.T) - jnp.sum(proto * proto, axis=-1)[None, :]
        lam = cfg.lpr_blend
        return (1.0 - lam) * s + lam * affinity, {}

    def update_state(self, s, idx, state, cfg, *, token_mask=None, axis_names=()):
        m = s.shape[-1]
        onehot = jax.nn.one_hot(idx, m, dtype=cfg.router_dtype)  # (n, k, m)
        if token_mask is not None:
            onehot = onehot * token_mask.astype(cfg.router_dtype)[:, None, None]
        assign = lax.stop_gradient(onehot.sum(axis=1))  # (n, m)
        counts = assign.sum(axis=0)  # (m,)
        sums = assign.T @ lax.stop_gradient(s)  # (m, m): Σ s_i over cluster j
        if axis_names:
            counts = lax.psum(counts, axis_names)
            sums = lax.psum(sums, axis_names)
        proto = state["proto"]
        mean = sums / jnp.maximum(counts, 1.0)[:, None]
        target = jnp.where((counts > 0.0)[:, None], mean, proto)
        d = cfg.lpr_decay
        return {"proto": d * proto + (1.0 - d) * target}
