"""Pure-jnp reference implementation of BIP-Based Balancing (Algorithm 1 / 2).

This is the oracle. The Pallas kernel (`repro.kernels.bip_admm`) and the
distributed variants are tested against these functions.

Algorithm 1 (inner loop, per gate invocation), for score matrix s in R^{n x m}:

    for t = 1..T:
        P   = s - 1_n^T q                      # (n, m)
        p_i = max(0, (k+1)-th largest of P_i)  # row-wise selection
        Q   = s^T - 1_m^T p                    # (m, n);  Q_ji = s_ij - p_i
        q_j = max(0, (nk/m+1)-th largest of Q_j)

    g_ij = s_ij  if  s_ij - q_j in TopK({s_it - q_t}, k)  else 0

Interpretation: (p, q) are the dual prices of the relaxed assignment LP; ADMM
coordinate steps on the dual are closed-form order statistics. Gate *values*
stay the raw scores, so q carries no gradient (like Loss-Free's bias).
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def expert_kth_index(n: int, k: int, m: int) -> int:
    """0-based order-statistic index for the (nk/m + 1)-th largest of n values.

    Returns floor(n*k/m); values at that index or beyond are "over capacity".
    If the index falls past the end (m >= n*k, more capacity than tokens) the
    constraint is slack and q_j must be 0 — signalled by returning -1.
    """
    idx = (n * k) // m
    return -1 if idx >= n else idx


def kth_largest(x: jnp.ndarray, kth: int, axis: int = -1) -> jnp.ndarray:
    """Value of the (kth+1)-th largest element along `axis` (0-based kth)."""
    # lax.top_k operates on the last axis.
    moved = jnp.moveaxis(x, axis, -1)
    vals = lax.top_k(moved, kth + 1)[0][..., kth]
    return vals


def bip_dual_update(
    s: jnp.ndarray,
    q0: jnp.ndarray,
    *,
    top_k: int,
    n_iters: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """T iterations of the ADMM dual update. Returns (q, p).

    s:  (n, m) routing scores for the current batch (float).
    q0: (m,) warm-start expert prices (zeros on the first batch).
    """
    n, m = s.shape
    cap_idx = expert_kth_index(n, top_k, m)

    def body(_, pq):
        q, _p = pq
        # p_i = max(0, (k+1)-th largest of s_i - q); k == m -> no (k+1)-th
        # largest exists (all experts selected), token constraint is slack.
        if top_k >= m:
            p = jnp.zeros((n,), s.dtype)
        else:
            p = jnp.maximum(0.0, kth_largest(s - q[None, :], top_k, axis=-1))
        # q_j = max(0, (nk/m + 1)-th largest of s_:j - p)
        if cap_idx < 0:
            q_new = jnp.zeros_like(q)
        else:
            q_new = jnp.maximum(0.0, kth_largest(s - p[:, None], cap_idx, axis=0))
        return (q_new, p)

    # inherit s's varying-manual-axes type (shard_map vma): inside a
    # shard_map over data axes the loop carry must be typed 'varying' from
    # iteration 0, and adding 0·s does exactly that with no semantic change
    p0 = 0.0 * s[:, 0]
    q_init = q0.astype(s.dtype) + 0.0 * s[0]
    q, p = lax.fori_loop(0, n_iters, body, (q_init, p0))
    return q, p


def bip_topk(
    s: jnp.ndarray, q: jnp.ndarray, top_k: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Select top-k experts by corrected scores s - q; gate values are raw s.

    Returns (combine_weights (n,k), expert_index (n,k) int32).
    """
    corrected = s - q[None, :]
    _, idx = lax.top_k(corrected, top_k)
    weights = jnp.take_along_axis(s, idx, axis=-1)
    return weights, idx.astype(jnp.int32)


def bip_route_reference(
    s: jnp.ndarray, q0: jnp.ndarray, *, top_k: int, n_iters: int
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full Algorithm 1 gate: dual update then biased top-k.

    Returns (combine_weights, expert_index, q_new).
    """
    q, _ = bip_dual_update(s, q0, top_k=top_k, n_iters=n_iters)
    w, idx = bip_topk(s, q, top_k)
    return w, idx, q


# ---------------------------------------------------------------------------
# Sort-free variant: order statistics via threshold binary search.
#
# This mirrors what the Pallas kernel does on TPU (compare + reduce only, no
# sort network), and is also the building block for sync='global' routing:
# the count reduction can be extended with lax.psum over data axes so the
# order statistic is computed over the *global* token set while each device
# only holds its local shard.
# ---------------------------------------------------------------------------


def bisect_ladder_depth(fanout: int) -> int:
    """Midpoint-ladder depth r for a requested per-round probe budget.

    The fused round probes a depth-r midpoint ladder of the bracket —
    2^r - 1 interior points, every one a chain of exact (a+b)*0.5
    midpoints — so `fanout` rounds UP to the next 2^r - 1. The ladder
    construction (rather than equally spaced convex combinations) is what
    keeps the thresholds bit-deterministic across compilation contexts:
    (a+b)*0.5 has no mul+add to contract into an fma, so eager reference
    runs, jitted mesh programs, and every device of a shard_map agree
    bitwise — which the cross-shard parity suite checks down to exact
    load histograms.
    """
    if fanout < 1:
        raise ValueError(f"fanout must be >= 1, got {fanout}")
    return max(1, math.ceil(math.log2(fanout + 1.0)))


def bisect_rounds(n_bisect: int, fanout: int) -> int:
    """Worst-case fused-bisection rounds for `n_bisect` bits of resolution.

    Each round shrinks the bracket 2^r x (r = bisect_ladder_depth(fanout)),
    so fanout=1 is classic bisection (n_bisect rounds) and fanout=F needs
    ceil(n_bisect / r) rounds for the same final width — 5 rounds at the
    production defaults (n_bisect=26, fanout=32 -> r=6).
    """
    if n_bisect < 1:
        raise ValueError(f"n_bisect must be >= 1, got {n_bisect}")
    return max(1, math.ceil(n_bisect / bisect_ladder_depth(fanout)))


def kth_largest_threshold(
    x: jnp.ndarray,
    kth: int,
    *,
    axis: int = -1,
    n_bisect: int = 26,
    axis_names: tuple = (),
    lo: Optional[jnp.ndarray] = None,
    hi: Optional[jnp.ndarray] = None,
    fanout: int = 1,
    window: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
) -> jnp.ndarray:
    """(kth+1)-th largest along `axis` via fused multi-threshold bisection.

    Finds the largest threshold t such that #{x > t} <= kth; the order
    statistic lies in a bracket (t_lo, t_hi] that each round shrinks 2^r x
    (r = bisect_ladder_depth(fanout)): the round probes the bracket's
    depth-r midpoint ladder — 2^r - 1 interior thresholds — with ONE fused
    exceedance count (with `axis_names`, one (probes * batch)-sized psum
    across those mesh axes instead of 2^r - 1 sequential round-trips),
    then GATHERS the sub-interval whose edge counts bracket `kth` out of
    the ladder. fanout=1 is classic midpoint bisection. Every ladder point
    is a chain of (a+b)*0.5 midpoints (exact multiply, no fma-contractible
    mul+add) and the new bounds are selected, never recomputed, so the
    thresholds are bit-identical across eager/jit/shard_map programs —
    the parity suite's exact load-histogram checks depend on this.

    Rounds run under a static `bisect_rounds(n_bisect, fanout)` trip
    count, but each round branches on convergence (every bracket narrower
    than the target resolution, initial width * 2^-n_bisect) and skips its
    count — and its collective — once converged. The convergence predicate
    only reads collectively-reduced bounds, so it is replicated and every
    device in the mesh takes the identical branch (a lax.cond, not a
    lax.while_loop, because shard_map's replication checker has rules for
    scan/cond but not while on this jax version).

    `window` is an optional (w_lo, w_hi) predicted bracket per batch element
    (see the router's load forecaster). Its validity check — the statistic
    lies in (w_lo, w_hi] iff count(w_lo) > kth >= count(w_hi) — rides in
    round 0's fused count at zero extra collectives; where valid it is
    intersected with round 0's sub-interval, where stale the full-range
    sub-interval is used, so a wrong forecast costs nothing but the saved
    rounds.

    Exactness: for routing we only need the *set* {x > t} to have kth
    elements; 26 bits over a [-2, 2] range give ~6e-8 resolution, far below
    any meaningful score gap in fp32 softmax outputs. Counts are small exact
    integers in f32, so given identical (replicated) brackets every device
    converges on bit-identical thresholds.
    """
    axis_names = tuple(axis_names)
    if lo is None:
        lo = jnp.min(x, axis=axis)
        if axis_names:
            lo = lax.pmin(lo, axis_names)
    if hi is None:
        hi = jnp.max(x, axis=axis)
        if axis_names:
            hi = lax.pmax(hi, axis_names)

    xm = jnp.moveaxis(x, axis, 0)  # (n, *rest)
    rest = xm.shape[1:]
    dt = xm.dtype
    # ensure the answer is strictly inside (lo, hi]
    lo = jnp.broadcast_to(jnp.asarray(lo, dt), rest) - jnp.asarray(1e-6, dt)
    hi = jnp.broadcast_to(jnp.asarray(hi, dt), rest)

    depth = bisect_ladder_depth(fanout)
    n_probes = 2 ** depth - 1
    max_rounds = bisect_rounds(n_bisect, fanout)
    target = jnp.max(hi - lo) * jnp.asarray(2.0 ** (-n_bisect), dt)

    def fused_counts(pts, extra=()):
        # exceedance counts for the interior ladder points pts[1:-1], via
        # bucketize (searchsorted + scatter histogram + reverse cumsum):
        # O(n log P) comparisons instead of the O(n*P) broadcast compare,
        # and still exact small-integer counts. `extra` thresholds (the
        # window validation probes) are counted by direct compare and ride
        # the SAME psum — one collective either way.
        n_pts = pts.shape[0]
        ptsf = pts.reshape(n_pts, -1)
        xf = xm.reshape(xm.shape[0], -1)
        # b = #{ladder points < x}: x > pts[i] iff b > i
        b = jax.vmap(
            lambda a, v: jnp.searchsorted(a, v, side="left"),
            in_axes=(1, 1), out_axes=1,
        )(ptsf, xf)
        hist = jax.vmap(
            lambda col: jnp.zeros((n_pts + 1,), jnp.float32).at[col].add(1.0),
            in_axes=1, out_axes=1,
        )(b)
        rc = jnp.cumsum(hist[::-1], axis=0)[::-1]  # rc[i] = #{b >= i}
        cnt = rc[2:n_pts].reshape((n_pts - 2,) + rest)  # #{x > pts[i]}, i=1..P-2
        if extra:
            ex = jnp.stack(
                [jnp.sum((xm > e[None]).astype(jnp.float32), axis=0) for e in extra]
            )
            cnt = jnp.concatenate([cnt, ex], axis=0)
        if axis_names:
            cnt = lax.psum(cnt, axis_names)
        return cnt

    def ladder(lo_, hi_):
        # depth-r midpoint ladder: (2^r + 1, *rest) sorted boundary points
        # including lo_/hi_; each refinement interleaves adjacent midpoints
        pts = jnp.stack([lo_, hi_])
        for _ in range(depth):
            mids = (pts[:-1] + pts[1:]) * 0.5
            body = jnp.stack([pts[:-1], mids], axis=1).reshape((-1,) + rest)
            pts = jnp.concatenate([body, pts[-1:]], axis=0)
        return pts

    def subinterval(pts, cnt):
        # counts are non-increasing in the threshold, so the number of
        # probes with count > kth indexes the ladder cell holding the stat;
        # the new bounds are GATHERED ladder points (no recomputation)
        j = jnp.sum((cnt > kth).astype(jnp.int32), axis=0)[None]  # (1, *rest)
        new_lo = jnp.take_along_axis(pts, j, axis=0)[0]
        new_hi = jnp.take_along_axis(pts, j + 1, axis=0)[0]
        return new_lo, new_hi

    # round 0, peeled: carries the two window-edge validation probes (if any)
    # inside the same fused count
    pts = ladder(lo, hi)
    if window is not None:
        w_lo = jnp.broadcast_to(jnp.asarray(window[0], dt), rest)
        w_hi = jnp.broadcast_to(jnp.asarray(window[1], dt), rest)
        cnt = fused_counts(pts, extra=(w_lo, w_hi))
        new_lo, new_hi = subinterval(pts, cnt[:n_probes])
        ok = (cnt[n_probes] > kth) & (cnt[n_probes + 1] <= kth) & (w_lo < w_hi)
        lo = jnp.where(ok, jnp.maximum(w_lo, new_lo), new_lo)
        hi = jnp.where(ok, jnp.minimum(w_hi, new_hi), new_hi)
    else:
        lo, hi = subinterval(pts, fused_counts(pts))

    def round_body(_, bounds):
        lo_, hi_ = bounds
        converged = jnp.max(hi_ - lo_) <= target

        def narrow(b):
            p = ladder(b[0], b[1])
            return subinterval(p, fused_counts(p))

        return lax.cond(converged, lambda b: b, narrow, (lo_, hi_))

    lo, hi = lax.fori_loop(0, max_rounds - 1, round_body, (lo, hi))
    return hi  # upper end: guarantees #{x > hi} <= kth (capacity respected)


def bip_dual_update_threshold(
    s: jnp.ndarray,
    q0: jnp.ndarray,
    *,
    top_k: int,
    n_iters: int,
    axis_names: tuple = (),
    n_bisect: int = 26,
    fanout: int = 1,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sort-free ADMM dual update; optionally global over sharded tokens.

    Thin alias of `bip_dual_update_global` without a token mask, kept as
    the historically-named entry point for the kernel/property parity
    tests. With axis_names=() this matches `bip_dual_update` up to
    bisection resolution; with axis_names set, `s` is the device-local
    (n_local, m) shard and the expert-price step uses psum'd global
    counts, reproducing the paper's single-device semantics under data
    parallelism.
    """
    return bip_dual_update_global(
        s, q0, top_k=top_k, n_iters=n_iters,
        axis_names=axis_names, n_bisect=n_bisect, fanout=fanout,
    )


def bip_dual_update_global(
    s: jnp.ndarray,
    q0: jnp.ndarray,
    *,
    top_k: int,
    n_iters: int,
    token_mask: Optional[jnp.ndarray] = None,  # (n,) bool; False rows invisible
    axis_names: tuple = (),
    n_bisect: int = 26,
    fanout: int = 1,
    score_bounds: Optional[Tuple[float, float]] = None,
    window: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    with_stats: bool = False,
):
    """ADMM dual update over the union of real tokens across `axis_names`.

    This is the sync='global' building block (DESIGN.md §Global-sync): `s`
    is the device-local (n_local, m) score shard inside a shard_map over
    the data axes, and every collective quantity — the real-token count,
    the bisection bounds, and the per-threshold exceedance counts — is
    reduced across `axis_names`, so every device converges on the SAME
    dual vector q over the GLOBAL token batch while only ever holding its
    shard. The token-price step p is row-wise over experts and stays fully
    local. Collective cost per dual iteration: `bisect_rounds(n_bisect,
    fanout)` fused (m*fanout,)-psums, plus a pmin/pmax bound pair ONLY when
    `score_bounds` is not given (so fanout=32 + static bounds turns PR 5's
    ~n_iters*(n_bisect+2) round-trips into ~n_iters*6).

    `score_bounds` is an optional static (lo, hi) on the entries of `s`
    (softmax/sigmoid scores live in [0, 1]): since q >= 0 implies the token
    price p stays within [0, max(hi, 0)], x = s - p is bracketed by
    [lo - max(hi, 0), hi] with no data-dependent (and hence no collective)
    bound computation at all.

    `window` is an optional (w_lo, w_hi) forecast bracket per expert for
    the pre-clamp order statistic t (see the router's load forecaster); it
    is validated inside round 0 of every dual iteration's fused count and
    ignored where stale, so warm-starts are free when wrong and save
    bisection rounds when right.

    `with_stats=True` additionally returns the final iteration's pre-clamp
    order statistic t (q = max(0, t)) so callers can update forecaster
    state; the (q, p) return signature is unchanged otherwise.

    `token_mask` marks real rows (serving padding is False): masked rows
    are pushed to -1e30 so they sink out of every order statistic, and the
    capacity index floor(n_real·k/m) is computed from the global real-row
    count (traced — hence the threshold/bisection order statistic, whose
    count comparison accepts a traced kth).

    vma typing (shard_map check_vma): q0 enters replicated and the q carry
    STAYS replicated — every q_new is assembled from psum/pmin/pmax
    outputs (or static bounds) — so callers can return it under an
    out_spec of P(None) with no re-replicating pmean. The p carry inherits
    s's varying type.

    With axis_names=() and an all-True (or absent) mask this matches
    `bip_dual_update` up to bisection resolution (~6e-8).
    """
    n, m = s.shape
    axis_names = tuple(axis_names)
    if token_mask is None:
        s_m = s
        n_real = jnp.asarray(n, jnp.int32)
    else:
        # masked rows give max(0, -1e30) = 0: no token price, no count
        s_m = jnp.where(token_mask[:, None], s, jnp.asarray(-1e30, s.dtype))
        n_real = jnp.sum(token_mask).astype(jnp.int32)
    n_glob = lax.psum(n_real, axis_names) if axis_names else n_real
    cap_idx = (n_glob * top_k) // m  # traced counterpart of expert_kth_index
    slack = cap_idx >= jnp.maximum(n_glob, 1)

    if score_bounds is not None:
        s_lo, s_hi = float(score_bounds[0]), float(score_bounds[1])
        lo_b = jnp.full((m,), s_lo - max(s_hi, 0.0), s.dtype)
        hi_b = jnp.full((m,), s_hi, s.dtype)

    def body(_, carry):
        q, _p, _t = carry
        if top_k >= m:
            p = jnp.zeros((n,), s.dtype)
        else:
            p = jnp.maximum(0.0, kth_largest(s_m - q[None, :], top_k, axis=-1))
        x = s_m - p[:, None]
        if score_bounds is not None:
            lo, hi = lo_b, hi_b
        else:
            # bisection bounds from real entries only, else resolution dies
            if token_mask is None:
                lo = jnp.min(x, axis=0)
                hi = jnp.max(x, axis=0)
            else:
                lo = jnp.min(jnp.where(token_mask[:, None], x, jnp.inf), axis=0)
                hi = jnp.max(jnp.where(token_mask[:, None], x, -jnp.inf), axis=0)
            if axis_names:
                lo = lax.pmin(lo, axis_names)
                hi = lax.pmax(hi, axis_names)
        t = kth_largest_threshold(
            x, cap_idx, axis=0,
            axis_names=axis_names, n_bisect=n_bisect, lo=lo, hi=hi,
            fanout=fanout, window=window,
        )
        # slack capacity (cap index past the global real rows) -> price 0
        t = jnp.where(slack, 0.0, t)
        q_new = jnp.maximum(0.0, t)
        return (q_new, p, t)

    p0 = 0.0 * s[:, 0]  # inherit s's vma type (see bip_dual_update)
    t0 = 0.0 * q0.astype(s.dtype)  # inherit q0's replicated type likewise
    q, p, t = lax.fori_loop(0, n_iters, body, (q0.astype(s.dtype), p0, t0))
    # an all-padding invocation (idle engine step) must not move the dual
    q = jnp.where(n_glob > 0, q, q0.astype(s.dtype))
    if with_stats:
        return q, p, t
    return q, p


def bip_dual_update_masked(
    s: jnp.ndarray,
    q0: jnp.ndarray,
    mask: jnp.ndarray,  # (n,) bool; False rows are invisible to the update
    *,
    top_k: int,
    n_iters: int,
    n_bisect: int = 26,
    fanout: int = 1,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """ADMM dual update over the REAL rows only (serving-chunk padding).

    Single-device specialization of `bip_dual_update_global`: serving
    chunks carry padding rows for static shapes (DESIGN.md §Serving); at
    steady-state decode they can outnumber real tokens many-to-one, so
    letting them into the dual update would drift q toward balancing
    uniform filler instead of real traffic.
    """
    return bip_dual_update_global(
        s, q0, top_k=top_k, n_iters=n_iters,
        token_mask=mask, axis_names=(), n_bisect=n_bisect, fanout=fanout,
    )


def sanitize_duals(q: jnp.ndarray, abs_limit: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dual-health check: (q_safe, healthy) for a carried dual vector.

    `healthy` is a scalar bool — True iff every entry of q is finite and
    |q| stays under `abs_limit`. When unhealthy, q_safe is the zeros safe
    init (the warm start any fresh layer would use); when healthy, q_safe
    IS q (jnp.where on the scalar keeps healthy values bitwise unchanged).
    Used by the router watchdog (RouterConfig.guard_duals) so one poisoned
    batch cannot permanently corrupt a layer's carried prices.
    """
    healthy = jnp.all(jnp.isfinite(q) & (jnp.abs(q) <= abs_limit))
    return jnp.where(healthy, q, jnp.zeros_like(q)), healthy
