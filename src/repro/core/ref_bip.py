"""Pure-jnp reference implementation of BIP-Based Balancing (Algorithm 1 / 2).

This is the oracle. The Pallas kernel (`repro.kernels.bip_admm`) and the
distributed variants are tested against these functions.

Algorithm 1 (inner loop, per gate invocation), for score matrix s in R^{n x m}:

    for t = 1..T:
        P   = s - 1_n^T q                      # (n, m)
        p_i = max(0, (k+1)-th largest of P_i)  # row-wise selection
        Q   = s^T - 1_m^T p                    # (m, n);  Q_ji = s_ij - p_i
        q_j = max(0, (nk/m+1)-th largest of Q_j)

    g_ij = s_ij  if  s_ij - q_j in TopK({s_it - q_t}, k)  else 0

Interpretation: (p, q) are the dual prices of the relaxed assignment LP; ADMM
coordinate steps on the dual are closed-form order statistics. Gate *values*
stay the raw scores, so q carries no gradient (like Loss-Free's bias).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def expert_kth_index(n: int, k: int, m: int) -> int:
    """0-based order-statistic index for the (nk/m + 1)-th largest of n values.

    Returns floor(n*k/m); values at that index or beyond are "over capacity".
    If the index falls past the end (m >= n*k, more capacity than tokens) the
    constraint is slack and q_j must be 0 — signalled by returning -1.
    """
    idx = (n * k) // m
    return -1 if idx >= n else idx


def kth_largest(x: jnp.ndarray, kth: int, axis: int = -1) -> jnp.ndarray:
    """Value of the (kth+1)-th largest element along `axis` (0-based kth)."""
    # lax.top_k operates on the last axis.
    moved = jnp.moveaxis(x, axis, -1)
    vals = lax.top_k(moved, kth + 1)[0][..., kth]
    return vals


def bip_dual_update(
    s: jnp.ndarray,
    q0: jnp.ndarray,
    *,
    top_k: int,
    n_iters: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """T iterations of the ADMM dual update. Returns (q, p).

    s:  (n, m) routing scores for the current batch (float).
    q0: (m,) warm-start expert prices (zeros on the first batch).
    """
    n, m = s.shape
    cap_idx = expert_kth_index(n, top_k, m)

    def body(_, pq):
        q, _p = pq
        # p_i = max(0, (k+1)-th largest of s_i - q); k == m -> no (k+1)-th
        # largest exists (all experts selected), token constraint is slack.
        if top_k >= m:
            p = jnp.zeros((n,), s.dtype)
        else:
            p = jnp.maximum(0.0, kth_largest(s - q[None, :], top_k, axis=-1))
        # q_j = max(0, (nk/m + 1)-th largest of s_:j - p)
        if cap_idx < 0:
            q_new = jnp.zeros_like(q)
        else:
            q_new = jnp.maximum(0.0, kth_largest(s - p[:, None], cap_idx, axis=0))
        return (q_new, p)

    # inherit s's varying-manual-axes type (shard_map vma): inside a
    # shard_map over data axes the loop carry must be typed 'varying' from
    # iteration 0, and adding 0·s does exactly that with no semantic change
    p0 = 0.0 * s[:, 0]
    q_init = q0.astype(s.dtype) + 0.0 * s[0]
    q, p = lax.fori_loop(0, n_iters, body, (q_init, p0))
    return q, p


def bip_topk(
    s: jnp.ndarray, q: jnp.ndarray, top_k: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Select top-k experts by corrected scores s - q; gate values are raw s.

    Returns (combine_weights (n,k), expert_index (n,k) int32).
    """
    corrected = s - q[None, :]
    _, idx = lax.top_k(corrected, top_k)
    weights = jnp.take_along_axis(s, idx, axis=-1)
    return weights, idx.astype(jnp.int32)


def bip_route_reference(
    s: jnp.ndarray, q0: jnp.ndarray, *, top_k: int, n_iters: int
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full Algorithm 1 gate: dual update then biased top-k.

    Returns (combine_weights, expert_index, q_new).
    """
    q, _ = bip_dual_update(s, q0, top_k=top_k, n_iters=n_iters)
    w, idx = bip_topk(s, q, top_k)
    return w, idx, q


# ---------------------------------------------------------------------------
# Sort-free variant: order statistics via threshold binary search.
#
# This mirrors what the Pallas kernel does on TPU (compare + reduce only, no
# sort network), and is also the building block for sync='global' routing:
# the count reduction can be extended with lax.psum over data axes so the
# order statistic is computed over the *global* token set while each device
# only holds its local shard.
# ---------------------------------------------------------------------------


def _count_greater(x: jnp.ndarray, thr: jnp.ndarray, axis: int, axis_names) -> jnp.ndarray:
    cnt = jnp.sum((x > thr).astype(jnp.float32), axis=axis)
    if axis_names:
        cnt = lax.psum(cnt, axis_names)
    return cnt


def kth_largest_threshold(
    x: jnp.ndarray,
    kth: int,
    *,
    axis: int = -1,
    n_bisect: int = 26,
    axis_names: tuple = (),
    lo: Optional[jnp.ndarray] = None,
    hi: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """(kth+1)-th largest along `axis` via bisection on the value domain.

    Finds the largest threshold t such that #{x > t} <= kth; the order
    statistic lies in (t_lo, t_hi] and we return the midpoint after `n_bisect`
    halvings. With `axis_names`, counts (and bounds) are reduced across those
    mesh axes, computing a global order statistic over sharded data at the
    cost of ~n_bisect scalar collectives (fused into one psum per iteration).

    Exactness: for routing we only need the *set* {x > t} to have kth elements;
    26 bisections over a [-2, 2] range give ~6e-8 resolution, far below any
    meaningful score gap in fp32 softmax outputs.
    """
    if lo is None:
        lo = jnp.min(x, axis=axis)
        if axis_names:
            lo = lax.pmin(lo, axis_names)
    if hi is None:
        hi = jnp.max(x, axis=axis)
        if axis_names:
            hi = lax.pmax(hi, axis_names)
    lo = lo - 1e-6  # ensure the answer is strictly inside (lo, hi]

    def body(_, bounds):
        lo_, hi_ = bounds
        mid = 0.5 * (lo_ + hi_)
        cnt = _count_greater(x, jnp.expand_dims(mid, axis), axis, axis_names)
        # If more than `kth` elements exceed mid, the (kth+1)-th largest is
        # above mid; move lo up. Else it is <= mid; move hi down.
        above = cnt > kth
        lo_ = jnp.where(above, mid, lo_)
        hi_ = jnp.where(above, hi_, mid)
        return (lo_, hi_)

    lo, hi = lax.fori_loop(0, n_bisect, body, (lo, hi))
    return hi  # upper end: guarantees #{x > hi} <= kth (capacity respected)


def bip_dual_update_threshold(
    s: jnp.ndarray,
    q0: jnp.ndarray,
    *,
    top_k: int,
    n_iters: int,
    axis_names: tuple = (),
    n_bisect: int = 26,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sort-free ADMM dual update; optionally global over sharded tokens.

    Thin alias of `bip_dual_update_global` without a token mask, kept as
    the historically-named entry point for the kernel/property parity
    tests. With axis_names=() this matches `bip_dual_update` up to
    bisection resolution; with axis_names set, `s` is the device-local
    (n_local, m) shard and the expert-price step uses psum'd global
    counts, reproducing the paper's single-device semantics under data
    parallelism.
    """
    return bip_dual_update_global(
        s, q0, top_k=top_k, n_iters=n_iters,
        axis_names=axis_names, n_bisect=n_bisect,
    )


def bip_dual_update_global(
    s: jnp.ndarray,
    q0: jnp.ndarray,
    *,
    top_k: int,
    n_iters: int,
    token_mask: Optional[jnp.ndarray] = None,  # (n,) bool; False rows invisible
    axis_names: tuple = (),
    n_bisect: int = 26,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """ADMM dual update over the union of real tokens across `axis_names`.

    This is the sync='global' building block (DESIGN.md §Global-sync): `s`
    is the device-local (n_local, m) score shard inside a shard_map over
    the data axes, and every collective quantity — the real-token count,
    the bisection bounds, and the per-threshold exceedance counts — is
    reduced across `axis_names`, so every device converges on the SAME
    dual vector q over the GLOBAL token batch while only ever holding its
    shard. The token-price step p is row-wise over experts and stays fully
    local. Collective cost: one fused (m,)-psum per bisection step plus a
    pmin/pmax bound pair per dual iteration (~n_iters·(n_bisect+2) small
    collectives), traded for the step-wise global balance guarantee.

    `token_mask` marks real rows (serving padding is False): masked rows
    are pushed to -1e30 so they sink out of every order statistic, and the
    capacity index floor(n_real·k/m) is computed from the global real-row
    count (traced — hence the threshold/bisection order statistic, whose
    count comparison accepts a traced kth).

    vma typing (shard_map check_vma): q0 enters replicated and the q carry
    STAYS replicated — every q_new is assembled from psum/pmin/pmax
    outputs — so callers can return it under an out_spec of P(None) with
    no re-replicating pmean. The p carry inherits s's varying type.

    With axis_names=() and an all-True (or absent) mask this matches
    `bip_dual_update` up to bisection resolution (~6e-8).
    """
    n, m = s.shape
    axis_names = tuple(axis_names)
    if token_mask is None:
        s_m = s
        n_real = jnp.asarray(n, jnp.int32)
    else:
        # masked rows give max(0, -1e30) = 0: no token price, no count
        s_m = jnp.where(token_mask[:, None], s, jnp.asarray(-1e30, s.dtype))
        n_real = jnp.sum(token_mask).astype(jnp.int32)
    n_glob = lax.psum(n_real, axis_names) if axis_names else n_real
    cap_idx = (n_glob * top_k) // m  # traced counterpart of expert_kth_index

    def body(_, pq):
        q, _p = pq
        if top_k >= m:
            p = jnp.zeros((n,), s.dtype)
        else:
            p = jnp.maximum(0.0, kth_largest(s_m - q[None, :], top_k, axis=-1))
        x = s_m - p[:, None]
        # bisection bounds from real entries only, else resolution dies
        if token_mask is None:
            lo = jnp.min(x, axis=0)
            hi = jnp.max(x, axis=0)
        else:
            lo = jnp.min(jnp.where(token_mask[:, None], x, jnp.inf), axis=0)
            hi = jnp.max(jnp.where(token_mask[:, None], x, -jnp.inf), axis=0)
        if axis_names:
            lo = lax.pmin(lo, axis_names)
            hi = lax.pmax(hi, axis_names)
        q_new = jnp.maximum(
            0.0,
            kth_largest_threshold(
                x, cap_idx, axis=0,
                axis_names=axis_names, n_bisect=n_bisect, lo=lo, hi=hi,
            ),
        )
        # slack capacity (cap index past the global real rows) -> price 0
        q_new = jnp.where(cap_idx >= jnp.maximum(n_glob, 1), 0.0, q_new)
        return (q_new, p)

    p0 = 0.0 * s[:, 0]  # inherit s's vma type (see bip_dual_update)
    q, p = lax.fori_loop(0, n_iters, body, (q0.astype(s.dtype), p0))
    # an all-padding invocation (idle engine step) must not move the dual
    q = jnp.where(n_glob > 0, q, q0.astype(s.dtype))
    return q, p


def bip_dual_update_masked(
    s: jnp.ndarray,
    q0: jnp.ndarray,
    mask: jnp.ndarray,  # (n,) bool; False rows are invisible to the update
    *,
    top_k: int,
    n_iters: int,
    n_bisect: int = 26,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """ADMM dual update over the REAL rows only (serving-chunk padding).

    Single-device specialization of `bip_dual_update_global`: serving
    chunks carry padding rows for static shapes (DESIGN.md §Serving); at
    steady-state decode they can outnumber real tokens many-to-one, so
    letting them into the dual update would drift q toward balancing
    uniform filler instead of real traffic.
    """
    return bip_dual_update_global(
        s, q0, top_k=top_k, n_iters=n_iters,
        token_mask=mask, axis_names=(), n_bisect=n_bisect,
    )
