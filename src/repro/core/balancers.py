"""Pluggable balancer registry — the routing strategy surface.

Every load-balancing method the repo can sweep is a `Balancer` subclass
registered by name. `route()` (core/router.py) is a thin orchestrator that
resolves `cfg.strategy` here and calls the hook protocol:

    init_state(cfg)                      -> per-layer carried state dict
    score_adjust(s, state, cfg, ...)     -> (corrected scores, state updates)
                                            or (corrected, updates, telemetry)
                                            [pre-selection: dual solves,
                                             bias/multiplier application,
                                             prototype affinities; the
                                             optional telemetry dict of
                                             already-computed health scalars
                                             is folded into the metrics]
    select(s, corrected, cfg)            -> (combine_weights, expert_index)
                                            [token top-k by default;
                                             expert-choice overrides]
    aux_loss(s, idx, cfg, token_mask)    -> scalar loss (0 by default)
    update_state(s, idx, state, cfg,...) -> state updates
                                            [post-selection: sign/EMA/
                                             multiplicative corrections]
    finalize_metrics(base, s, w, idx)    -> metrics dict (coverage columns
                                            for expert-choice)

Each hook receives the full RouterConfig plus `token_mask` (masked serving
rows, DESIGN.md §Serving) and `axis_names` (the mesh data axes when
cfg.sync='global', else ()), so cross-shard dual sync and masked-serving
semantics come for free to every method: reductions over selections go
through `_global_load`-style psums and masked sums exactly once, here.

The four paper strategies (topk / aux_loss / lossfree / bip) are ports of
the historical `route()` if/elif — bit-identical by construction (the same
jnp ops in the same order; tests/test_balancers.py pins this against the
frozen legacy implementation). phi (φ-Balancing, arxiv 2605.15403), lpr
(Latent Prototype Routing, arxiv 2506.21328) and expert_choice
(core/expert_choice.py, training-only) register behind the same surface.

Adding a method = one module with a @register_balancer subclass; the
launchers, sweeps, and validation all resolve through `registered_balancers`.
"""
from __future__ import annotations

import warnings
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import ref_bip
from repro.core.metrics import balance_metrics
from repro.core.types import RouterConfig

Array = jnp.ndarray
State = Dict[str, Array]

_REGISTRY: Dict[str, "Balancer"] = {}

_warned: set = set()


def _warn_once(key: str, msg: str) -> None:
    """Emit a config-degradation warning once per process (trace-time)."""
    if key not in _warned:
        _warned.add(key)
        warnings.warn(msg, stacklevel=4)


def register_balancer(name: str):
    """Class decorator: instantiate and register a Balancer under `name`."""

    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls()
        return cls

    return deco


def registered_balancers() -> Tuple[str, ...]:
    """All registered strategy names, sorted (for error messages / sweeps)."""
    return tuple(sorted(_REGISTRY))


def get_balancer(name: str) -> "Balancer":
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown routing strategy {name!r}; registered: "
            f"{', '.join(registered_balancers())}"
        ) from None


# ---------------------------------------------------------------- protocol


def topk_select(
    s: Array, corrected: Array, cfg: RouterConfig
) -> Tuple[Array, Array]:
    """Top-k on `corrected` scores, gate values gathered from raw `s`."""
    _, idx = lax.top_k(corrected, cfg.top_k)
    w = jnp.take_along_axis(s, idx, axis=-1)
    if cfg.norm_topk_prob:
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return w, idx.astype(jnp.int32)


class Balancer:
    """Base strategy: plain token-choice top-k, no balancing, no state use.

    Subclasses override the hooks they need; the base implementations are
    exactly the 'topk' semantics (corrected = raw scores, zero aux loss,
    state carried through untouched).

    Class attributes (the per-method capability contract):
      STATE_KEYS      ordered state keys this method owns — sets the
                      dual-watchdog concatenation order (bit-compat with
                      the legacy guard) and which leaves reset on poison.
      local_avg_keys  state keys pmean-averaged across data shards by the
                      EP paths under sync='local' (the warm-start average).
      serving_ok      supports masked serving rows (token_mask) — i.e. the
                      method is causally safe for autoregressive decode.
      uses_kernel     consumes cfg.use_kernel (only bip's ADMM kernel).
      uses_sync       cfg.sync='global' changes this method's semantics
                      (for others the matrix records identical cells).
    """

    name: str = ""
    STATE_KEYS: Tuple[str, ...] = ("q",)
    local_avg_keys: Tuple[str, ...] = ("q",)
    serving_ok: bool = True
    uses_kernel: bool = False
    uses_sync: bool = False

    # -- state ------------------------------------------------------------
    def init_state(self, cfg: RouterConfig) -> State:
        """Fresh per-layer carried state ('q' kept for every method so
        checkpoints stay strategy-portable; see types.init_router_state)."""
        return {"q": jnp.zeros((cfg.n_experts,), dtype=cfg.router_dtype)}

    def guard_keys(self, state: State) -> Tuple[str, ...]:
        """State keys the dual-health watchdog covers, in concat order."""
        return tuple(k for k in self.STATE_KEYS if k in state)

    # -- config hygiene ---------------------------------------------------
    def check_config(self, cfg: RouterConfig) -> None:
        """Warn-once on knob combinations this method silently ignores."""
        if cfg.use_kernel and not self.uses_kernel:
            _warn_once(
                f"kernel-unused-{self.name}",
                f"use_kernel=True only accelerates the 'bip' ADMM dual "
                f"update; strategy {self.name!r} runs the reference path "
                f"and the flag is ignored.",
            )
        if cfg.forecast and self.name != "bip":
            _warn_once(
                f"forecast-unused-{self.name}",
                f"RouterConfig.forecast drives the bip dual forecaster; "
                f"strategy {self.name!r} carries no forecaster state and "
                f"the flag is ignored.",
            )

    # -- hooks ------------------------------------------------------------
    def score_adjust(
        self,
        s: Array,
        state: State,
        cfg: RouterConfig,
        *,
        token_mask: Optional[Array] = None,
        axis_names: tuple = (),
        local_shards: int = 1,
    ) -> Tuple[Array, State]:
        return s, {}

    def select(
        self, s: Array, corrected: Array, cfg: RouterConfig
    ) -> Tuple[Array, Array]:
        return topk_select(s, corrected, cfg)

    def aux_loss(
        self,
        s: Array,
        idx: Array,
        cfg: RouterConfig,
        token_mask: Optional[Array] = None,
    ) -> Array:
        return jnp.zeros((), dtype=cfg.router_dtype)

    def update_state(
        self,
        s: Array,
        idx: Array,
        state: State,
        cfg: RouterConfig,
        *,
        token_mask: Optional[Array] = None,
        axis_names: tuple = (),
    ) -> State:
        return {}

    def finalize_metrics(
        self,
        base: Dict[str, Array],
        s: Array,
        w: Array,
        idx: Array,
        cfg: RouterConfig,
    ) -> Dict[str, Array]:
        return base


# ------------------------------------------------------------- strategies


@register_balancer("topk")
class TopKBalancer(Balancer):
    """Vanilla softmax top-k — no balancing; the collapse-prone baseline."""


@register_balancer("aux_loss")
class AuxLossBalancer(Balancer):
    """Loss-Controlled (GShard/Switch): L_balance = α Σ_j f_j P_j.

    f_j = m/(k n) Σ_i δ_ij  (token fraction, non-differentiable -> stopped),
    P_j = 1/n Σ_i s_ij      (mean gate score, carries the gradient).
    With token_mask, both means run over the real rows only.
    """

    def aux_loss(self, s, idx, cfg, token_mask=None):
        n, m = s.shape
        onehot = jax.nn.one_hot(idx, m, dtype=s.dtype)  # (n, k, m)
        if token_mask is not None:
            w = token_mask.astype(s.dtype)
            n_eff = jnp.maximum(jnp.sum(w), 1.0)
            f = lax.stop_gradient(
                (onehot * w[:, None, None]).sum(axis=(0, 1))
            ) * (m / (cfg.top_k * n_eff))
            p_mean = jnp.sum(s * w[:, None], axis=0) / n_eff
        else:
            f = lax.stop_gradient(onehot.sum(axis=(0, 1))) * (m / (cfg.top_k * n))
            p_mean = s.mean(axis=0)
        return cfg.aux_loss_alpha * jnp.sum(f * p_mean)


def selection_load(
    idx: Array,
    m: int,
    dtype,
    token_mask: Optional[Array] = None,
    axis_names: tuple = (),
) -> Array:
    """Per-expert selection histogram (m,), masked rows excluded, psum'd
    over `axis_names` so sync='global' methods see the global batch.

    The one-hot formulation matches the legacy lossfree update bitwise
    (integer-valued float sums are exact in either order).
    """
    onehot = jax.nn.one_hot(idx, m, dtype=dtype)
    if token_mask is not None:
        onehot = onehot * token_mask.astype(dtype)[:, None, None]
    load = lax.stop_gradient(onehot.sum(axis=(0, 1)))
    if axis_names:
        load = lax.psum(load, axis_names)
    return load


@register_balancer("lossfree")
class LossFreeBalancer(Balancer):
    """Loss-Free (Wang et al. 2024): per-batch sign update of bias b.

    The carried 'q' plays the role of the bias b, ADDED to scores for
    selection; gate values stay the raw scores so b gets no gradient.
    Under sync='global' every shard psums the same selection histogram, so
    the carried bias stays bit-identical across devices.
    """

    uses_sync = True

    def score_adjust(self, s, state, cfg, *, token_mask=None, axis_names=(),
                     local_shards=1):
        return s + state["q"][None, :], {}

    def update_state(self, s, idx, state, cfg, *, token_mask=None, axis_names=()):
        m = s.shape[-1]
        load = selection_load(idx, m, cfg.router_dtype, token_mask, axis_names)
        err = load.mean() - load
        return {"q": state["q"] + cfg.lossfree_lr * jnp.sign(err)}


@register_balancer("bip")
class BIPBalancer(Balancer):
    """BIP-Based Balancing (the paper): per-gate ADMM dual update of q.

    The dual price q is SUBTRACTED from scores for selection; the dual
    solve (reference / Pallas kernel / psum-reduced global threshold
    bisection, plus the EMA forecaster window) happens pre-selection in
    score_adjust — the branch structure is the legacy route() body moved
    here verbatim (DESIGN.md §3.3 / §Global-sync).
    """

    STATE_KEYS = ("q", "q_ema", "q_err")
    uses_kernel = True
    uses_sync = True

    def init_state(self, cfg):
        state = {"q": jnp.zeros((cfg.n_experts,), dtype=cfg.router_dtype)}
        if cfg.forecast:
            state["q_ema"] = jnp.zeros((cfg.n_experts,), dtype=cfg.router_dtype)
            state["q_err"] = jnp.zeros((cfg.n_experts,), dtype=cfg.router_dtype)
        return state

    def check_config(self, cfg):
        if cfg.forecast and (cfg.sync != "global" or cfg.use_kernel):
            _warn_once(
                "forecast-inactive",
                "RouterConfig.forecast only drives the reference sync='global' "
                "bisection path; with sync='local' or use_kernel=True the "
                "forecaster state is carried but never consulted.",
            )

    def guard_keys(self, state):
        # legacy watchdog order: q first, then whichever forecaster EMAs
        # are present (they are guarded whenever carried, cfg.forecast or not)
        return ("q",) + tuple(k for k in ("q_ema", "q_err") if k in state)

    def _solve(self, s, q0, cfg):
        """Dispatch the ADMM dual update to the reference or Pallas kernel."""
        if cfg.use_kernel:
            from repro.kernels import ops as kernel_ops  # lazy: import cycle

            return kernel_ops.bip_dual_update(
                s, q0, top_k=cfg.top_k, n_iters=cfg.bip_iters
            )
        q, _ = ref_bip.bip_dual_update(
            s, q0, top_k=cfg.top_k, n_iters=cfg.bip_iters
        )
        return q

    def score_adjust(self, s, state, cfg, *, token_mask=None, axis_names=(),
                     local_shards=1):
        n, m = s.shape
        q0 = state["q"]
        updates: State = {}
        # telemetry: dual-health scalars route() folds into the metrics —
        # strictly values the solve already produced (no extra collectives)
        tel: State = {}
        if cfg.sync == "global" and cfg.use_kernel and token_mask is None:
            # collective Pallas path: the kernel's (m, n_bins) histogram
            # counts are psum'd across the data axes between the count pass
            # and the rank location (kernels/ops.py). Empty axis_names
            # degrades to the plain single-device kernel.
            from repro.kernels import ops as kernel_ops  # lazy: import cycle

            q = kernel_ops.bip_dual_update(
                lax.stop_gradient(s), q0,
                top_k=cfg.top_k, n_iters=cfg.bip_iters,
                axis_names=axis_names,
            )
            corrected = s - q[None, :]
            updates["q"] = q
        elif cfg.sync == "global" or token_mask is not None:
            # one implementation serves the mesh path (axis_names), the
            # serving path (token_mask), AND the unsharded sync='global'
            # reference (axes=()): all three share the bisection numerics,
            # so a sharded global-sync run reproduces the single-device
            # trajectory bit-for-bit at the dual level — the sort-based
            # update would instead park q exactly ON the capacity-marginal
            # token's score and make the comparison tie-degenerate.
            if cfg.use_kernel:  # only reachable with a token mask
                _warn_once(
                    "kernel-masked",
                    "use_kernel=True has no masked (serving-padding) form; "
                    "falling back to the reference masked dual update.",
                )
            # load forecaster: predict the pre-clamp order statistic t from
            # its EMA, bracket it by the EMA'd error, and let the bisection
            # validate the bracket in-band (free when stale, rounds saved
            # when right)
            use_forecast = cfg.forecast and not cfg.use_kernel and "q_ema" in state
            window = None
            if use_forecast:
                half = cfg.forecast_margin * state["q_err"] + cfg.forecast_floor
                window = (state["q_ema"] - half, state["q_ema"] + half)
            # scores are softmax/sigmoid outputs, so [0, 1] is a static
            # bracket: no data-dependent (pmin/pmax) bound collectives
            q, _, t = ref_bip.bip_dual_update_global(
                lax.stop_gradient(s), q0,
                top_k=cfg.top_k, n_iters=cfg.bip_iters,
                token_mask=token_mask, axis_names=axis_names,
                n_bisect=cfg.n_bisect, fanout=cfg.bisect_fanout,
                score_bounds=(0.0, 1.0), window=window, with_stats=True,
            )
            if use_forecast:
                d = cfg.forecast_decay
                err = jnp.abs(t - state["q_ema"])
                updates["q_ema"] = d * state["q_ema"] + (1.0 - d) * t
                updates["q_err"] = d * state["q_err"] + (1.0 - d) * err
                # instantaneous forecast quality: mean |t - prediction| and
                # the fraction of experts whose pre-clamp statistic landed
                # inside the warm-start bracket (window-hit rate)
                lo, hi = window
                tel["forecast_err"] = jnp.mean(err)
                tel["forecast_hit"] = jnp.mean(
                    ((t >= lo) & (t <= hi)).astype(jnp.float32)
                )
            corrected = s - q[None, :]
            updates["q"] = q
        elif local_shards > 1 and cfg.sync == "local":
            s_grp = lax.stop_gradient(s).reshape(local_shards, n // local_shards, m)
            q_grp = jax.vmap(lambda sg: self._solve(sg, q0, cfg))(s_grp)  # (S, m)
            corrected = (
                s.reshape(local_shards, -1, m) - q_grp[:, None, :]
            ).reshape(n, m)
            updates["q"] = q_grp.mean(axis=0)  # replicated warm start
        else:
            q = self._solve(lax.stop_gradient(s), q0, cfg)
            corrected = s - q[None, :]
            updates["q"] = q
        if not cfg.bip_warm_start:
            updates["q"] = jnp.zeros_like(q0)
        return corrected, updates, tel


@register_balancer("expert_choice")
class ExpertChoiceBalancer(Balancer):
    """Expert-Choice (Zhou et al. 2022): each EXPERT takes its top-C tokens.

    Balance is perfect by construction (C = floor(k·n/m) per expert), but
    tokens may receive fewer than k experts — slots beyond a token's
    assignments carry the sentinel index m with zero combine weight, so
    they occupy no dispatch capacity and no load. TRAINING ONLY: the
    per-expert top-C over the batch lets earlier tokens see selection
    outcomes that depend on later tokens, so autoregressive decode /
    masked serving raises (route() checks `serving_ok`; the standard
    causality caveat — see core/expert_choice.py).
    """

    serving_ok = False
    uses_sync = False

    def check_config(self, cfg):
        super().check_config(cfg)
        if cfg.sync == "global":
            _warn_once(
                "expert-choice-sync",
                "expert_choice selects each expert's top-C over the "
                "device-local token shard; sync='global' does not globalize "
                "the selection (no cross-shard top-C).",
            )

    def select(self, s, corrected, cfg):
        from repro.core.expert_choice import expert_choice_select

        return expert_choice_select(
            s, cfg.top_k, norm_topk_prob=cfg.norm_topk_prob
        )

    def finalize_metrics(self, base, s, w, idx, cfg):
        # coverage columns (benchmarks/expert_choice_compare heritage):
        # how many tokens got all k experts / no expert at all
        per_token = (idx < s.shape[-1]).sum(axis=-1)
        base = dict(base)
        base["coverage_full"] = jnp.mean(
            (per_token >= cfg.top_k).astype(jnp.float32)
        )
        base["coverage_zero"] = jnp.mean((per_token == 0).astype(jnp.float32))
        return base


def router_metrics(
    bal: Balancer,
    s: Array,
    w: Array,
    idx: Array,
    cfg: RouterConfig,
) -> Dict[str, Array]:
    """Balance metrics + the balancer's method-specific columns."""
    base = balance_metrics(idx, cfg.n_experts, cfg.top_k)
    return bal.finalize_metrics(base, s, w, idx, cfg)


# the φ-Balancing and Latent-Prototype-Routing modules self-register on
# import; importing them here makes `import repro.core.balancers` (or any
# RouterConfig construction) populate the full registry
from repro.core import lpr as _lpr  # noqa: E402,F401  (self-registering)
from repro.core import phi as _phi  # noqa: E402,F401  (self-registering)

__all__ = [
    "Balancer",
    "get_balancer",
    "register_balancer",
    "registered_balancers",
    "router_metrics",
    "selection_load",
    "topk_select",
]
