"""Shared model primitives: norms, RoPE, GQA attention (global / sliding
window / logit softcap), gated MLPs, embeddings.

All modules are functional: `init_*(key, cfg, ...) -> params pytree` and
`apply(params, x, ...) -> y`. Parameters are plain dicts of jnp arrays so
they stack cleanly under vmap for lax.scan-over-layers.

Attention is memory-tiled: queries are processed in chunks of cfg.attn_chunk
via lax.scan so the (S, S) score matrix is never materialized — per chunk the
footprint is (B, H, chunk, S), which keeps 32k-token prefill inside HBM on the
production mesh (see DESIGN.md §3).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig

Params = Dict[str, jnp.ndarray]

NEG_INF = -2.0e38  # large-negative fill that survives bf16 casts


# ------------------------------------------------------------------ norms


def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    return out.astype(dt)


# ------------------------------------------------------------------- rope


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float
) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]  # (.., S, 1, D/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- attention


def init_attention(key, cfg: ModelConfig) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = 1.0 / math.sqrt(d)
    p = {
        "wq": jax.random.normal(k1, (d, h, hd), cfg.param_dtype) * scale,
        "wk": jax.random.normal(k2, (d, kv, hd), cfg.param_dtype) * scale,
        "wv": jax.random.normal(k3, (d, kv, hd), cfg.param_dtype) * scale,
        "wo": jax.random.normal(k4, (h, hd, d), cfg.param_dtype)
        * (scale / math.sqrt(2 * cfg.n_layers)),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, cfg.param_dtype)
        p["k_norm"] = init_rmsnorm(hd, cfg.param_dtype)
    return p


def _attn_weights(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Sk, KV, D)
    mask: jnp.ndarray,  # (B, 1|H, Sq, Sk) bool
    softcap: float,
) -> jnp.ndarray:
    groups = q.shape[2] // k.shape[2]
    kq = jnp.repeat(k, groups, axis=2)  # (B, Sk, H, D)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kq).astype(jnp.float32)
    logits = logits / math.sqrt(q.shape[-1])
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    logits = jnp.where(mask, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    # fully-masked rows (can happen for padded chunks): zero them out
    w = jnp.where(mask.any(axis=-1, keepdims=True), w, 0.0)
    return w


def _attend(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    mask: jnp.ndarray,
    softcap: float,
    compute_dtype,
) -> jnp.ndarray:
    w = _attn_weights(q, k, mask, softcap)
    groups = q.shape[2] // v.shape[2]
    vq = jnp.repeat(v, groups, axis=2)
    return jnp.einsum("bhqk,bkhd->bqhd", w.astype(compute_dtype), vq)


def causal_window_mask(
    q_pos: jnp.ndarray, k_pos: jnp.ndarray, window: int
) -> jnp.ndarray:
    """(…, Sq, Sk) bool. window=0 -> plain causal; else sliding window."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    mask = diff >= 0
    if window > 0:
        mask &= diff < window
    return mask


def attention(
    params: Params,
    x: jnp.ndarray,  # (B, S, d)
    cfg: ModelConfig,
    *,
    layer_kind: str = "global",  # 'global' | 'local'
    positions: Optional[jnp.ndarray] = None,
    segments: Optional[jnp.ndarray] = None,  # (B, S) document ids
    mesh_ctx=None,
    causal: bool = True,
) -> jnp.ndarray:
    """Training / prefill attention with two memory-bounded layouts.

    `segments` (when given) restricts attention to seg_q == seg_k: packed
    multi-document sequences (data/packing.py 'pack_nocross') attend only
    within their own document, at zero cost when absent.

    * heads % model_axis == 0 (or no mesh): Megatron layout — heads shard
      over 'model'; queries are processed in chunks via lax.scan so only one
      (chunk, S) score block lives at a time.
    * otherwise: SEQUENCE-parallel layout — the query axis shards over
      'model' (K/V replicated; exact since each query row is independent).
      No scan: the sharded score block (B, H, S/model, S) is the working set.
    """
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    if positions is None:
        positions = jnp.arange(s)[None, :]
    theta = cfg.rope_theta
    window = 0
    if layer_kind == "local":
        window = cfg.window_size
        if cfg.rope_local_theta:
            theta = cfg.rope_local_theta

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(cfg.compute_dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(cfg.compute_dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(cfg.compute_dtype))
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.rms_norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.rms_norm_eps)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)

    msize = 0
    if mesh_ctx is not None and getattr(mesh_ctx, "mesh", None) is not None:
        msize = mesh_ctx.mesh.shape[mesh_ctx.model_axis] if mesh_ctx.model_axis else 0
    # Megatron layout when heads divide the model axis; otherwise SEQUENCE
    # parallelism: the positions *within each query chunk* shard over
    # 'model' (K/V replicated — exact, since query rows are independent).
    seq_parallel = msize > 1 and cfg.n_heads % msize != 0
    bspec = mesh_ctx.batch_spec if msize else None

    chunk = min(cfg.attn_chunk, s)
    if s % chunk != 0:  # pad the query axis up to a chunk multiple
        pad = chunk - s % chunk
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        qpos = jnp.pad(positions, ((0, 0), (0, pad)), constant_values=-1)
    else:
        pad = 0
        qpos = positions
    n_chunks = q.shape[1] // chunk
    qc = q.reshape(b, n_chunks, chunk, cfg.n_heads, hd)
    pc = jnp.broadcast_to(qpos, (b, qpos.shape[-1])).reshape(b, n_chunks, chunk)
    sc = None
    if segments is not None:
        segq = jnp.broadcast_to(segments, (b, s))
        if pad:  # padded query rows get a segment no key carries
            segq = jnp.pad(segq, ((0, 0), (0, pad)), constant_values=-2)
        sc = segq.reshape(b, n_chunks, chunk)
    if msize:
        if seq_parallel:
            qc = mesh_ctx.constrain(qc, bspec, None, "model", None, None)
        else:
            qc = mesh_ctx.constrain(qc, bspec, None, None, "model", None)

    def body(carry, inp):
        qi, pi = inp[0], inp[1]  # (B, chunk, H, D), (B, chunk)
        if causal:
            mask = causal_window_mask(pi, positions, window)[:, None]  # (B,1,c,S)
        else:
            mask = (pi >= 0)[:, None, :, None] & jnp.ones((1, 1, 1, s), bool)
        if segments is not None:
            si = inp[2]  # (B, chunk)
            mask = mask & (si[:, :, None] == segments[:, None, :])[:, None]
        yi = _attend(qi, k, v, mask, cfg.attn_logit_softcap, cfg.compute_dtype)
        return carry, yi

    xs = (qc.swapaxes(0, 1), pc.swapaxes(0, 1))
    if sc is not None:
        xs = xs + (sc.swapaxes(0, 1),)
    _, ys = lax.scan(body, None, xs)
    y = ys.swapaxes(0, 1).reshape(b, n_chunks * chunk, cfg.n_heads, hd)
    if pad:
        y = y[:, :s]
    return jnp.einsum("bshk,hkd->bsd", y, params["wo"].astype(cfg.compute_dtype))


def attention_chunk(
    params: Params,
    x: jnp.ndarray,  # (B, C, d)
    cache: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    *,
    layer_kind: str = "global",
    lengths: jnp.ndarray = None,  # (B,) int32, tokens valid per row (0..C)
    positions: Optional[jnp.ndarray] = None,  # (B, C) packed-mode positions
    segments: Optional[jnp.ndarray] = None,  # (B, C) ids; -1 = padding
    write_slots: Optional[jnp.ndarray] = None,  # (B, C) target cache row; -1 drops
    cache_rows: Optional[jnp.ndarray] = None,  # (B,) cache row each row reads
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Cached attention advancing each row by `lengths[i]` tokens at once.

    The chunked-prefill core (DESIGN.md §Serving): row i's first lengths[i]
    columns are real tokens starting at absolute position cache['pos'][i];
    the rest is padding. Valid K/V are written into the cache in bulk
    (out-of-bounds scatter indices drop the padded columns) and the chunk
    attends with a per-query causal mask, so rows at different sequence
    offsets — including pure decode rows with lengths[i] == 1 — share one
    traced program. Global layers attend against the updated cache; ring
    (sliding-window) layers attend against the pre-update ring concatenated
    with the in-chunk keys, because the bulk write clobbers keys still
    inside earlier in-chunk queries' windows. Padded output columns are
    garbage and must be masked by the caller.

    For local layers C <= window_size is required (asserted; the engine
    clamps chunk_size), so in-chunk writes never collide in the ring.

    Passing `segments` switches to the PACKED layout (see
    `_attention_chunk_packed`); `lengths` is ignored there and the other
    three packed operands describe per-column placement. The segments=None
    path is bit-identical to the pre-packing implementation.
    """
    if segments is not None:
        return _attention_chunk_packed(
            params,
            x,
            cache,
            cfg,
            layer_kind=layer_kind,
            positions=positions,
            segments=segments,
            write_slots=write_slots,
            cache_rows=cache_rows,
        )
    b, c, _ = x.shape
    theta = cfg.rope_theta
    window = 0
    if layer_kind == "local":
        window = cfg.window_size
        if cfg.rope_local_theta:
            theta = cfg.rope_local_theta
    if lengths is None:
        lengths = jnp.full((b,), c, jnp.int32)

    pos0 = cache["pos"]  # (B,)
    q_pos = pos0[:, None] + jnp.arange(c)[None, :]  # (B, C)
    valid = jnp.arange(c)[None, :] < lengths[:, None]  # (B, C)

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(cfg.compute_dtype))
    k_new = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(cfg.compute_dtype))
    v_new = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(cfg.compute_dtype))
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.rms_norm_eps)
        k_new = rmsnorm(params["k_norm"], k_new, cfg.rms_norm_eps)
    q = apply_rope(q, q_pos, theta)
    k_new = apply_rope(k_new, q_pos, theta)

    cap = cache["k"].shape[1]
    if window > 0:
        assert c <= cap, f"chunk {c} must fit the ring buffer (window {cap})"
        write_idx = q_pos % cap
    else:
        write_idx = q_pos
    # padded columns scatter out of bounds -> dropped
    write_idx = jnp.where(valid, write_idx, cap)
    k = jax.vmap(lambda cch, n, i: cch.at[i].set(n, mode="drop"))(
        cache["k"], k_new.astype(cache["k"].dtype), write_idx
    )
    v = jax.vmap(lambda cch, n, i: cch.at[i].set(n, mode="drop"))(
        cache["v"], v_new.astype(cache["v"].dtype), write_idx
    )

    idx = jnp.arange(cap)[None, :]  # (1, cap)
    if window > 0:
        # Ring layers must attend against the PRE-update ring plus the
        # in-chunk keys: writing position p' overwrites the key at p'-cap,
        # which is still inside the window of every earlier in-chunk query
        # p in [p'-cap+1, p'-1] — a bulk write-then-attend would clobber it.
        prev = pos0 - 1  # (B,) latest position already in the ring
        k_pos = prev[:, None] - ((prev[:, None] - idx) % cap)  # (B, cap)
        ring_ok = (
            (k_pos >= 0)[:, None, :]
            & (k_pos[:, None, :] <= q_pos[..., None])
            & (k_pos[:, None, :] > q_pos[..., None] - window)
        )  # (B, C, cap)
        chunk_ok = (
            (q_pos[:, None, :] <= q_pos[..., None])
            & (q_pos[:, None, :] > q_pos[..., None] - window)
            & valid[:, None, :]
        )  # (B, C, C)
        mask = jnp.concatenate([ring_ok, chunk_ok], axis=-1) & valid[..., None]
        k_att = jnp.concatenate(
            [cache["k"].astype(cfg.compute_dtype), k_new], axis=1
        )
        v_att = jnp.concatenate(
            [cache["v"].astype(cfg.compute_dtype), v_new], axis=1
        )
    else:
        k_pos = jnp.broadcast_to(idx, (b, cap))
        mask = (k_pos[:, None, :] <= q_pos[..., None]) & valid[..., None]
        k_att, v_att = k, v.astype(cfg.compute_dtype)
    mask = mask[:, None]  # (B, 1, C, cap[+C])

    y = _attend(q, k_att, v_att, mask, cfg.attn_logit_softcap, cfg.compute_dtype)
    out = jnp.einsum("bshk,hkd->bsd", y, params["wo"].astype(cfg.compute_dtype))
    return out, {"k": k, "v": v, "pos": pos0 + lengths}


def _attention_chunk_packed(
    params: Params,
    x: jnp.ndarray,  # (B, C, d)
    cache: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    *,
    layer_kind: str,
    positions: jnp.ndarray,  # (B, C) absolute position of every column
    segments: jnp.ndarray,  # (B, C) int32; -1 = padding
    write_slots: jnp.ndarray,  # (B, C) cache row each column writes; -1 drops
    cache_rows: Optional[jnp.ndarray],  # (B,) cache row each ROW reads
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Packed multi-request chunk: row != slot, column placement is explicit.

    Each column carries (position, segment, target cache row). Segment 0 is
    the row's RESIDENT stream — the continuation of cache row
    `cache_rows[b]` — and attends through the cache exactly like the dense
    path. Segments >= 1 are FRESH packed prompts: whole short prompts
    sharing a row, attending only their own in-chunk keys (same row, same
    segment, causal by position) — their K/V still scatter into their own
    slot's cache row via `write_slots` so the next step continues them as
    residents. Segment -1 columns are padding: never written, never
    attended, outputs garbage (same caller-masks contract as the dense
    path).

    Cross-row placement of ONE stream (a long prompt spread over several
    rows as segment 0 with a shared cache row) is sound only on GLOBAL
    layers, where write-then-attend routes every in-flight key through the
    cache; ring layers see in-chunk keys per-row only, so the engine gates
    spreading on all-global stacks.
    """
    b, c, _ = x.shape
    n_rows, cap = cache["k"].shape[0], cache["k"].shape[1]
    theta = cfg.rope_theta
    window = 0
    if layer_kind == "local":
        window = cfg.window_size
        if cfg.rope_local_theta:
            theta = cfg.rope_local_theta
    if cache_rows is None:
        cache_rows = jnp.arange(b, dtype=jnp.int32)
    valid = segments >= 0  # (B, C)
    q_pos = positions

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(cfg.compute_dtype))
    k_new = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(cfg.compute_dtype))
    v_new = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(cfg.compute_dtype))
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.rms_norm_eps)
        k_new = rmsnorm(params["k_norm"], k_new, cfg.rms_norm_eps)
    q = apply_rope(q, q_pos, theta)
    k_new = apply_rope(k_new, q_pos, theta)

    if window > 0:
        assert c <= cap, f"chunk {c} must fit the ring buffer (window {cap})"
        write_pos = q_pos % cap
    else:
        write_pos = q_pos
    # dropped columns (padding, or write_slots < 0) scatter out of bounds
    drop = ~valid | (write_slots < 0)
    ws = jnp.where(drop, n_rows, write_slots)
    wp = jnp.where(drop, cap, write_pos)
    k = cache["k"].at[ws, wp].set(k_new.astype(cache["k"].dtype), mode="drop")
    v = cache["v"].at[ws, wp].set(v_new.astype(cache["v"].dtype), mode="drop")

    resident = segments == 0  # cache-attached columns
    fresh = segments >= 1  # in-chunk packed prompts
    same_seg = segments[:, None, :] == segments[:, :, None]  # (B, C, C)
    idx = jnp.arange(cap)[None, :]  # (1, cap)
    pos0 = cache["pos"]
    if window > 0:
        # pre-update ring of the row's resident stream (same rationale as
        # the dense path); fresh segments never touch it
        prev = pos0[cache_rows] - 1  # (B,)
        k_pos = prev[:, None] - ((prev[:, None] - idx) % cap)  # (B, cap)
        ring_ok = (
            (k_pos >= 0)[:, None, :]
            & (k_pos[:, None, :] <= q_pos[..., None])
            & (k_pos[:, None, :] > q_pos[..., None] - window)
            & resident[..., None]
        )  # (B, C, cap)
        chunk_ok = (
            same_seg
            & (q_pos[:, None, :] <= q_pos[..., None])
            & (q_pos[:, None, :] > q_pos[..., None] - window)
            & valid[:, None, :]
        )  # (B, C, C)
        mask = jnp.concatenate([ring_ok, chunk_ok], axis=-1) & valid[..., None]
        k_att = jnp.concatenate(
            [cache["k"][cache_rows].astype(cfg.compute_dtype), k_new], axis=1
        )
        v_att = jnp.concatenate(
            [cache["v"][cache_rows].astype(cfg.compute_dtype), v_new], axis=1
        )
    else:
        # write-then-attend through the POST-update cache row: residents see
        # every key of their stream regardless of which row wrote it this
        # chunk (that is what makes cross-row spreading exact); fresh
        # segments attend their in-chunk keys only — their cache writes
        # land in a row this row does not read
        k_pos = jnp.broadcast_to(idx, (b, cap))
        cache_ok = (k_pos[:, None, :] <= q_pos[..., None]) & resident[..., None]
        chunk_ok = (
            same_seg
            & (q_pos[:, None, :] <= q_pos[..., None])
            & valid[:, None, :]
            & fresh[..., None]
        )
        mask = jnp.concatenate([cache_ok, chunk_ok], axis=-1) & valid[..., None]
        k_att = jnp.concatenate([k[cache_rows], k_new], axis=1)
        v_att = jnp.concatenate(
            [v[cache_rows].astype(cfg.compute_dtype), v_new], axis=1
        )
    mask = mask[:, None]  # (B, 1, C, cap+C)

    y = _attend(q, k_att, v_att, mask, cfg.attn_logit_softcap, cfg.compute_dtype)
    out = jnp.einsum("bshk,hkd->bsd", y, params["wo"].astype(cfg.compute_dtype))
    # each cache row advances by the number of valid columns written into it
    counts = jnp.zeros((n_rows,), jnp.int32).at[ws.reshape(-1)].add(
        valid.reshape(-1).astype(jnp.int32), mode="drop"
    )
    return out, {"k": k, "v": v, "pos": pos0 + counts}


def init_attention_cache(
    cfg: ModelConfig, batch: int, seq_len: int, layer_kind: str, dtype
) -> Dict[str, jnp.ndarray]:
    cap = min(cfg.window_size, seq_len) if layer_kind == "local" else seq_len
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, cap, kv, hd), dtype),
        "v": jnp.zeros((batch, cap, kv, hd), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


# -------------------------------------------------------------------- mlp


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    return {
        "w_gate": jax.random.normal(k1, (d, f), cfg.param_dtype) * s_in,
        "w_up": jax.random.normal(k2, (d, f), cfg.param_dtype) * s_in,
        "w_down": jax.random.normal(k3, (f, d), cfg.param_dtype)
        * (s_out / math.sqrt(2 * cfg.n_layers)),
    }


def mlp(params: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    g = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(cfg.compute_dtype))
    u = jnp.einsum("...d,df->...f", x, params["w_up"].astype(cfg.compute_dtype))
    return jnp.einsum(
        "...f,fd->...d", act(g) * u, params["w_down"].astype(cfg.compute_dtype)
    )


# ------------------------------------------------------------- embeddings


def init_embedding(key, cfg: ModelConfig) -> Params:
    p = {
        "tok": jax.random.normal(
            key, (cfg.vocab_size, cfg.d_model), cfg.param_dtype
        )
        * (1.0 / math.sqrt(cfg.d_model))
    }
    if not cfg.tie_embeddings:
        p["unembed"] = (
            jax.random.normal(
                jax.random.fold_in(key, 1), (cfg.d_model, cfg.vocab_size), cfg.param_dtype
            )
            / math.sqrt(cfg.d_model)
        )
    return p


def embed(params: Params, tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    return params["tok"].astype(cfg.compute_dtype)[tokens]


def unembed(params: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        logits = jnp.einsum(
            "...d,vd->...v", x, params["tok"].astype(cfg.compute_dtype)
        )
    else:
        logits = jnp.einsum(
            "...d,dv->...v", x, params["unembed"].astype(cfg.compute_dtype)
        )
    logits = logits.astype(jnp.float32)
    if cfg.final_logit_softcap > 0:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits
