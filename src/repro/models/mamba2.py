"""Mamba2 / SSD (state-space duality) block [arXiv:2405.21060], pure JAX.

The selective state-space recurrence per head h with state size N, head dim P:

    S_t = exp(dt_t·A_h) · S_{t-1} + B_t ⊗ (dt_t·x_t)      S in R^{N x P}
    y_t = C_t · S_t + D_h · x_t

with A_h < 0 learned scalar per head (the SSD restriction), B_t, C_t in R^N
shared across heads within a group, dt_t > 0 per head via softplus.

Training/prefill uses the chunked SSD algorithm: within a chunk of Q steps the
output is a masked quadratic form (attention-like, MXU-friendly); across
chunks a lax.scan carries the (H, N, P) state:

    y_t = exp(cs_t)·(C_t · S_in)                                [inter-chunk]
        + Σ_{u<=t} exp(cs_t - cs_u)·(C_t·B_u)·(dt_u x_u)        [intra-chunk]
    S_out = exp(cs_Q)·S_in + Σ_u exp(cs_Q - cs_u)·B_u ⊗ (dt_u x_u)

where cs is the within-chunk cumulative log-decay (always <= 0, so every exp
is <= 1: numerically safe in bf16/fp32).

Decode keeps S explicitly and advances one step (attention-free decode — this
is why SSM/hybrid archs run the 500k-token shape).

Block layout follows the Mamba2 reference: in_proj -> [z | x | B | C | dt],
short depthwise causal conv over (x,B,C), SSD core, gated RMSNorm, out_proj.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig

Params = Dict[str, jnp.ndarray]


def dims(cfg: ModelConfig) -> Dict[str, int]:
    d_inner = cfg.ssm.expand * cfg.d_model
    n_heads = d_inner // cfg.ssm.head_dim
    return {
        "d_inner": d_inner,
        "n_heads": n_heads,
        "head_dim": cfg.ssm.head_dim,
        "d_state": cfg.ssm.d_state,
        "n_groups": cfg.ssm.n_groups,
        "d_conv": cfg.ssm.d_conv,
        "conv_dim": d_inner + 2 * cfg.ssm.n_groups * cfg.ssm.d_state,
    }


def init_mamba(key, cfg: ModelConfig) -> Params:
    dm = dims(cfg)
    d = cfg.d_model
    di, nh = dm["d_inner"], dm["n_heads"]
    d_in_proj = 2 * di + 2 * dm["n_groups"] * dm["d_state"] + nh
    keys = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d)
    return {
        "in_proj": jax.random.normal(keys[0], (d, d_in_proj), cfg.param_dtype) * s,
        "conv_w": jax.random.normal(
            keys[1], (dm["d_conv"], dm["conv_dim"]), cfg.param_dtype
        )
        * 0.5,
        "conv_b": jnp.zeros((dm["conv_dim"],), cfg.param_dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_scale": jnp.ones((di,), cfg.param_dtype),
        "out_proj": jax.random.normal(keys[2], (di, d), cfg.param_dtype)
        * (1.0 / math.sqrt(di) / math.sqrt(2 * cfg.n_layers)),
    }


def _split_proj(zxbcdt: jnp.ndarray, dm: Dict[str, int]):
    di, ns, ng = dm["d_inner"], dm["d_state"], dm["n_groups"]
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + dm["conv_dim"] - 0]
    # conv input = [x | B | C]
    dt = zxbcdt[..., di + di + 2 * ng * ns :]
    return z, xbc, dt


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv. x: (B, S, C), w: (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k))
    return out + b


def _gated_norm(y, z, scale, eps):
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y32 = y.astype(jnp.float32)
    var = jnp.mean(y32 * y32, axis=-1, keepdims=True)
    return (y32 * lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(y.dtype)


# ---------------------------------------------------------------- SSD core


def ssd_reference(x, dt, a_log, b, c, d_skip, init_state=None):
    """Naive per-step recurrence — the oracle for tests.

    x: (B,S,H,P)  dt: (B,S,H)  a_log: (H,)  b,c: (B,S,G,N)  d_skip: (H,)
    """
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    a = -jnp.exp(a_log)
    state = (
        init_state
        if init_state is not None
        else jnp.zeros((bsz, h, n, p), jnp.float32)
    )
    bs = jnp.repeat(b, rep, axis=2).astype(jnp.float32)
    cs = jnp.repeat(c, rep, axis=2).astype(jnp.float32)
    x32, dt32 = x.astype(jnp.float32), dt.astype(jnp.float32)

    def step(st, inp):
        xt, dtt, bt, ct = inp  # (B,H,P) (B,H) (B,H,N) (B,H,N)
        decay = jnp.exp(dtt * a[None, :])[..., None, None]  # (B,H,1,1)
        st = st * decay + bt[..., None] * (dtt[..., None] * xt)[..., None, :]
        yt = jnp.einsum("bhn,bhnp->bhp", ct, st)
        return st, yt

    st, ys = lax.scan(
        step,
        state,
        (
            x32.swapaxes(0, 1),
            dt32.swapaxes(0, 1),
            bs.swapaxes(0, 1),
            cs.swapaxes(0, 1),
        ),
    )
    y = ys.swapaxes(0, 1) + x32 * d_skip[None, None, :, None]
    return y.astype(x.dtype), st


def ssd_chunked(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    a_log: jnp.ndarray,
    b: jnp.ndarray,
    c: jnp.ndarray,
    d_skip: jnp.ndarray,
    chunk: int,
    init_state: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD. Same contract as ssd_reference, O(S·Q) not O(S²)."""
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    if s % chunk:
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))  # dt=0 -> identity step
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = x.shape[1]
    nc = sp // chunk
    a = -jnp.exp(a_log.astype(jnp.float32))

    la = dt.astype(jnp.float32) * a[None, None, :]          # (B,S,H) log-decay
    xdt = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])

    xc = xdt.reshape(bsz, nc, chunk, h, p)
    lac = la.reshape(bsz, nc, chunk, h)
    bc = jnp.repeat(b, rep, axis=2).astype(jnp.float32).reshape(bsz, nc, chunk, h, n)
    cc = jnp.repeat(c, rep, axis=2).astype(jnp.float32).reshape(bsz, nc, chunk, h, n)

    csum = jnp.cumsum(lac, axis=2)       # (B,nc,Q,H)
    total = csum[:, :, -1]               # (B,nc,H)

    # intra-chunk quadratic part (same for every chunk, no carry needed)
    dmat = csum[:, :, :, None, :] - csum[:, :, None, :, :]   # (B,nc,t,u,H)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask in the LOG domain before exp: exp of the (positive) anti-causal
    # entries can overflow, and where(c, inf, 0) poisons the backward pass.
    dmat = jnp.where(causal[None, None, :, :, None], dmat, -1e30)
    dexp = jnp.exp(dmat)
    cb = jnp.einsum("bcthn,bcuhn->bctuh", cc, bc)
    y_intra = jnp.einsum("bctuh,bcuhp->bcthp", cb * dexp, xc)

    # per-chunk state increment: Σ_u exp(total - cs_u) B_u ⊗ xdt_u
    w_u = jnp.exp(total[:, :, None, :] - csum)               # (B,nc,Q,H)
    incr = jnp.einsum("bcuh,bcuhn,bcuhp->bchnp", w_u, bc, xc)

    # scan chunks: carry state, emit inter-chunk output
    state0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((bsz, h, n, p), jnp.float32)
    )

    def step(st, inp):
        cs_c, tot_c, c_c, incr_c = inp  # (B,Q,H) (B,H) (B,Q,H,N) (B,H,N,P)
        y_inter = jnp.exp(cs_c)[..., None] * jnp.einsum("bthn,bhnp->bthp", c_c, st)
        st_new = jnp.exp(tot_c)[..., None, None] * st + incr_c
        return st_new, y_inter

    st, y_inter = lax.scan(
        step,
        state0,
        (
            csum.swapaxes(0, 1),
            total.swapaxes(0, 1),
            cc.swapaxes(0, 1),
            incr.swapaxes(0, 1),
        ),
    )
    y = (y_intra + y_inter.swapaxes(0, 1)).reshape(bsz, sp, h, p)[:, :s]
    y = y + x.astype(jnp.float32)[:, :s] * d_skip[None, None, :, None]
    return y.astype(x.dtype), st


# ------------------------------------------------------------- full block


def mamba_block(
    params: Params, xres: jnp.ndarray, cfg: ModelConfig
) -> jnp.ndarray:
    """Full-sequence mamba2 mixer. xres: (B, S, d) (already normed)."""
    dm = dims(cfg)
    zxbcdt = jnp.einsum(
        "bsd,de->bse", xres, params["in_proj"].astype(cfg.compute_dtype)
    )
    z, xbc, dt = _split_proj(zxbcdt, dm)
    xbc = jax.nn.silu(
        _causal_conv(
            xbc,
            params["conv_w"].astype(cfg.compute_dtype),
            params["conv_b"].astype(cfg.compute_dtype),
        )
    )
    di, ns, ng = dm["d_inner"], dm["d_state"], dm["n_groups"]
    xs = xbc[..., :di]
    bs = xbc[..., di : di + ng * ns]
    cs = xbc[..., di + ng * ns :]
    bsz, s = xres.shape[0], xres.shape[1]
    h, p = dm["n_heads"], dm["head_dim"]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    y, _ = ssd_chunked(
        xs.reshape(bsz, s, h, p),
        dt,
        params["A_log"],
        bs.reshape(bsz, s, ng, ns),
        cs.reshape(bsz, s, ng, ns),
        params["D"],
        cfg.ssm.chunk_size,
    )
    y = _gated_norm(y.reshape(bsz, s, di), z, params["norm_scale"], cfg.rms_norm_eps)
    return jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(cfg.compute_dtype))


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jnp.ndarray]:
    dm = dims(cfg)
    return {
        "ssm": jnp.zeros(
            (batch, dm["n_heads"], dm["d_state"], dm["head_dim"]), jnp.float32
        ),
        "conv": jnp.zeros((batch, dm["d_conv"] - 1, dm["conv_dim"]), dtype),
    }


def mamba_chunk(
    params: Params,
    xres: jnp.ndarray,  # (B, C, d) (already normed)
    cache: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    *,
    lengths: jnp.ndarray = None,  # (B,) tokens valid per row (0..C)
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Advance the recurrent state by `lengths[i]` tokens per row at once.

    The single-token decode path is the C=1 case (DESIGN.md §Serving): the
    conv history and SSM state come from the cache, the chunk runs through
    the same SSD kernel as training with `init_state`, and padding is
    neutralized by forcing dt -> 0 there (decay exp(0)=1, increment dt·x=0:
    the state is frozen through padded steps, so the final state equals the
    state after exactly lengths[i] real tokens). The new conv cache gathers
    the last d_conv-1 *valid* inputs per row, skipping padding.
    """
    dm = dims(cfg)
    bsz, c, _ = xres.shape
    if lengths is None:
        lengths = jnp.full((bsz,), c, jnp.int32)
    valid = jnp.arange(c)[None, :] < lengths[:, None]  # (B, C)

    zxbcdt = jnp.einsum(
        "bsd,de->bse", xres, params["in_proj"].astype(cfg.compute_dtype)
    )
    z, xbc_new, dt = _split_proj(zxbcdt, dm)

    kw = dm["d_conv"]
    hist = jnp.concatenate(
        [cache["conv"], xbc_new.astype(cache["conv"].dtype)], axis=1
    )  # (B, kw-1+C, conv_dim); entry (kw-1)+t is the input at chunk offset t
    w = params["conv_w"].astype(cfg.compute_dtype)
    conv_out = (
        sum(hist[:, i : i + c, :].astype(cfg.compute_dtype) * w[i] for i in range(kw))
        + params["conv_b"].astype(cfg.compute_dtype)
    )
    xbc = jax.nn.silu(conv_out)  # (B, C, conv_dim)
    # last kw-1 valid inputs: chunk offsets lengths-(kw-1)..lengths-1, i.e.
    # hist indices lengths..lengths+kw-2 (lengths==0 reproduces the old cache)
    gather_idx = lengths[:, None] + jnp.arange(kw - 1)[None, :]
    new_conv = jax.vmap(lambda h, i: h[i])(hist, gather_idx)

    di, ns, ng = dm["d_inner"], dm["d_state"], dm["n_groups"]
    h, p = dm["n_heads"], dm["head_dim"]
    xs = xbc[..., :di]
    bs = xbc[..., di : di + ng * ns]
    cs = xbc[..., di + ng * ns :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    dt = jnp.where(valid[..., None], dt, 0.0)  # freeze state through padding

    y, st = ssd_chunked(
        xs.reshape(bsz, c, h, p),
        dt,
        params["A_log"],
        bs.reshape(bsz, c, ng, ns),
        cs.reshape(bsz, c, ng, ns),
        params["D"],
        chunk=min(cfg.ssm.chunk_size, c),
        init_state=cache["ssm"],
    )
    y = _gated_norm(y.reshape(bsz, c, di), z, params["norm_scale"], cfg.rms_norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(cfg.compute_dtype))
    return out, {"ssm": st, "conv": new_conv}
