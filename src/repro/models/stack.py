"""Decoder stack assembly: per-layer blocks, scan-over-layers, KV caches.

Layers are grouped by the config's layer-kind cycle (period P from
`cfg.scan_period()`): parameters for each position j < P are stacked with a
leading group axis and the stack is applied with lax.scan over groups — one
traced copy of the period body regardless of depth. A non-dividing remainder
(e.g. zamba2's 81 = 13·6 + 3) is applied once more outside the scan with the
leftover prefix of the period.

Block structure (pre-norm residual):
    attn blocks:   x += [post_norm](mixer(pre_norm(x)))
                   x += [post_norm](ffn(pre_norm2(x)))          ffn ∈ {dense, moe}
    mamba blocks:  x += mamba(pre_norm(x))
    'mamba+shared' additionally applies a weight-SHARED (attn + mlp) block
    (zamba2); shared weights live outside the scan stacks.
    encdec decoder blocks insert cross-attention between mixer and ffn.

MoE layers thread a router state {'q': (m,)} and emit (aux_loss, max_vio)
per layer; the stack returns them stacked per MoE layer so the training loop
can log per-layer AvgMaxVio exactly like the paper's Appendix A tables.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import common, mamba2, moe
from repro.core.types import init_router_state

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MeshCtx:
    """How the model is laid out on a device mesh (None => single device)."""

    mesh: Any = None
    data_axes: Tuple[str, ...] = ()
    model_axis: str = ""

    @property
    def use_ep(self) -> bool:
        return self.mesh is not None and bool(self.model_axis)

    @property
    def batch_spec(self):
        if not self.data_axes:
            return None
        return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]

    def constrain(self, x, *spec):
        """Pin an activation's sharding (no-op off-mesh). Prevents GSPMD from
        drifting to batch-replicated layouts (e.g. vocab-sharded logits with
        gathered tokens), which blows past HBM."""
        if self.mesh is None or x is None:
            return x
        from jax.sharding import NamedSharding, PartitionSpec

        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, PartitionSpec(*spec))
        )


# ----------------------------------------------------------------- layers


def init_layer(key, cfg: ModelConfig, mixer_kind: str, ffn_kind: str) -> Params:
    keys = jax.random.split(key, 8)
    p: Params = {}
    if mixer_kind in ("global", "local"):
        p["pre_norm"] = common.init_rmsnorm(cfg.d_model, cfg.param_dtype)
        p["attn"] = common.init_attention(keys[0], cfg)
        if cfg.post_block_norms:
            p["post_attn_norm"] = common.init_rmsnorm(cfg.d_model, cfg.param_dtype)
        if cfg.n_enc_layers:  # decoder of an encdec model: cross attention
            p["cross_norm"] = common.init_rmsnorm(cfg.d_model, cfg.param_dtype)
            p["cross"] = common.init_attention(keys[1], cfg)
    else:  # mamba
        p["pre_norm"] = common.init_rmsnorm(cfg.d_model, cfg.param_dtype)
        p["mamba"] = mamba2.init_mamba(keys[0], cfg)

    if ffn_kind == "dense":
        p["ffn_norm"] = common.init_rmsnorm(cfg.d_model, cfg.param_dtype)
        p["mlp"] = common.init_mlp(keys[2], cfg)
        if cfg.post_block_norms:
            p["post_ffn_norm"] = common.init_rmsnorm(cfg.d_model, cfg.param_dtype)
    elif ffn_kind == "moe":
        p["ffn_norm"] = common.init_rmsnorm(cfg.d_model, cfg.param_dtype)
        p["moe"] = moe.init_moe(keys[2], cfg)
        if cfg.dense_residual:
            p["mlp"] = common.init_mlp(keys[3], cfg)
        if cfg.n_shared_experts:
            p["shared_mlp"] = common.init_mlp(
                keys[4], cfg, d_ff=(cfg.moe_d_ff or cfg.d_ff) * cfg.n_shared_experts
            )
    return p


def init_shared_block(key, cfg: ModelConfig) -> Params:
    """zamba2: one (attn + mlp) block whose weights are shared across uses."""
    k1, k2 = jax.random.split(key)
    return {
        "pre_norm": common.init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "attn": common.init_attention(k1, cfg),
        "ffn_norm": common.init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "mlp": common.init_mlp(k2, cfg),
    }


def _maybe_post(p: Params, name: str, y: jnp.ndarray, cfg: ModelConfig):
    if cfg.post_block_norms and name in p:
        return common.rmsnorm(p[name], y, cfg.rms_norm_eps)
    return y


def apply_layer(
    p: Params,
    x: jnp.ndarray,  # (B, S, d)
    cfg: ModelConfig,
    mixer_kind: str,
    ffn_kind: str,
    router_state: Optional[Dict[str, jnp.ndarray]],
    *,
    positions: Optional[jnp.ndarray] = None,
    segments: Optional[jnp.ndarray] = None,
    enc_out: Optional[jnp.ndarray] = None,
    shared_params: Optional[Params] = None,
    mesh_ctx: MeshCtx = MeshCtx(),
    rng: Optional[jnp.ndarray] = None,  # per-layer key for dropout-style regularizers
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]], jnp.ndarray, Dict]:
    """Returns (x, new_router_state, aux_loss, metrics)."""
    del rng  # no stochastic regularizer uses it yet; plumbed for them
    aux = jnp.zeros((), jnp.float32)
    mets: Dict[str, jnp.ndarray] = {}
    b, s, d = x.shape

    base_kind = mixer_kind.replace("+shared", "")
    if base_kind in ("global", "local"):
        h = common.attention(
            p["attn"],
            common.rmsnorm(p["pre_norm"], x, cfg.rms_norm_eps),
            cfg,
            layer_kind=base_kind,
            positions=positions,
            segments=segments,
            mesh_ctx=mesh_ctx,
        )
        x = x + _maybe_post(p, "post_attn_norm", h, cfg)
        if enc_out is not None and "cross" in p:
            hc = _cross_attention(
                p["cross"],
                common.rmsnorm(p["cross_norm"], x, cfg.rms_norm_eps),
                enc_out,
                cfg,
                mesh_ctx=mesh_ctx,
            )
            x = x + hc
    else:  # mamba
        h = mamba2.mamba_block(
            p["mamba"], common.rmsnorm(p["pre_norm"], x, cfg.rms_norm_eps), cfg
        )
        x = x + h

    if ffn_kind == "dense":
        h = common.mlp(
            p["mlp"], common.rmsnorm(p["ffn_norm"], x, cfg.rms_norm_eps), cfg
        )
        x = x + _maybe_post(p, "post_ffn_norm", h, cfg)
    elif ffn_kind == "moe":
        xin = common.rmsnorm(p["ffn_norm"], x, cfg.rms_norm_eps)
        flat = xin.reshape(b * s, d)
        y, new_state, aux_moe, moe_mets = moe.moe_ffn(
            p["moe"], flat, router_state, cfg, mesh_ctx
        )
        h = y.reshape(b, s, d)
        if cfg.dense_residual and "mlp" in p:
            h = h + common.mlp(p["mlp"], xin, cfg)
        if cfg.n_shared_experts and "shared_mlp" in p:
            h = h + common.mlp(p["shared_mlp"], xin, cfg)
        x = x + h
        router_state = new_state
        aux = aux + aux_moe
        mets = {"max_vio": moe_mets["max_vio"], "load": moe_mets["load"]}
        # optional telemetry scalars (dispatch drops, dual health, bip
        # forecaster quality) ride along when the MoE path computed them;
        # the EP shard_map paths surface only the fixed 3-key dict, so
        # these are local-path-only (DESIGN.md §Observability)
        for k in (
            "dropped_frac_cap1",
            "q_abs_max",
            "forecast_err",
            "forecast_hit",
        ):
            if k in moe_mets:
                mets[k] = moe_mets[k]

    if mixer_kind.endswith("+shared") and shared_params is not None:
        h = common.attention(
            shared_params["attn"],
            common.rmsnorm(shared_params["pre_norm"], x, cfg.rms_norm_eps),
            cfg,
            layer_kind="global",
            positions=positions,
            segments=segments,
            mesh_ctx=mesh_ctx,
        )
        x = x + h
        h = common.mlp(
            shared_params["mlp"],
            common.rmsnorm(shared_params["ffn_norm"], x, cfg.rms_norm_eps),
            cfg,
        )
        x = x + h

    return x, router_state, aux, mets


def _cross_attention(p, x, enc_out, cfg: ModelConfig, *, mesh_ctx: MeshCtx = MeshCtx()):
    """Cross attention, decoder-query-chunked (same memory discipline as
    self-attention: one (chunk, S_enc) score block at a time, or the whole
    sharded block under sequence parallelism)."""
    dt = cfg.compute_dtype
    b, s, _ = x.shape
    se = enc_out.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(dt))

    msize = 0
    if mesh_ctx.mesh is not None and mesh_ctx.model_axis:
        msize = mesh_ctx.mesh.shape[mesh_ctx.model_axis]
    if msize > 1 and cfg.n_heads % msize != 0:
        q = mesh_ctx.constrain(q, mesh_ctx.batch_spec, "model", None, None)
        mask = jnp.ones((1, 1, s, se), bool)
        y = common._attend(q, k, v, mask, 0.0, dt)
        return jnp.einsum("bshk,hkd->bsd", y, p["wo"].astype(dt))

    chunk = min(cfg.attn_chunk, s)
    pad = (-s) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qc = q.reshape(b, -1, chunk, cfg.n_heads, q.shape[-1])

    def body(carry, qi):
        mask = jnp.ones((1, 1, chunk, se), bool)
        return carry, common._attend(qi, k, v, mask, 0.0, dt)

    _, ys = lax.scan(body, None, qc.swapaxes(0, 1))
    y = ys.swapaxes(0, 1).reshape(b, -1, cfg.n_heads, q.shape[-1])[:, :s]
    return jnp.einsum("bshk,hkd->bsd", y, p["wo"].astype(dt))


# ------------------------------------------------------------------ stack


def _group_layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    period = cfg.scan_period()
    n_groups = cfg.n_layers // period
    remainder = cfg.n_layers % period
    return period, n_groups, remainder


def init_stack(key, cfg: ModelConfig) -> Params:
    """Stacked per-position layer params: params['blocks'][j] has leading
    axis n_groups (+1 when j < remainder)."""
    period, n_groups, remainder = _group_layout(cfg)
    kinds = cfg.layer_kinds()
    blocks = []
    for j in range(period):
        reps = n_groups + (1 if j < remainder else 0)
        keys = jax.random.split(jax.random.fold_in(key, j), reps)
        stacked = jax.vmap(
            lambda k: init_layer(k, cfg, kinds[j][0], kinds[j][1])
        )(keys)
        blocks.append(stacked)
    p: Params = {"blocks": blocks}
    if any(mk.endswith("+shared") for mk, _ in kinds):
        p["shared"] = init_shared_block(jax.random.fold_in(key, 10_001), cfg)
    return p


def init_stack_router_states(cfg: ModelConfig) -> list:
    """Router state stacks mirroring params['blocks'] layout (None for
    non-MoE positions)."""
    period, n_groups, remainder = _group_layout(cfg)
    kinds = cfg.layer_kinds()
    rcfg = moe.router_config(cfg) if cfg.is_moe else None
    states = []
    for j in range(period):
        reps = n_groups + (1 if j < remainder else 0)
        if cfg.is_moe and kinds[j][1] == "moe":
            st = init_router_state(rcfg)
            # prepend the layer axis whatever the leaf rank: (m,) duals tile
            # to (reps, m), lpr's (m, m) prototypes to (reps, m, m)
            states.append(
                jax.tree.map(
                    lambda a: jnp.tile(a, (reps,) + (1,) * a.ndim), st
                )
            )
        else:
            states.append(None)
    return states


def apply_stack(
    params: Params,
    x: jnp.ndarray,
    router_states: list,
    cfg: ModelConfig,
    *,
    positions: Optional[jnp.ndarray] = None,
    segments: Optional[jnp.ndarray] = None,
    enc_out: Optional[jnp.ndarray] = None,
    mesh_ctx: MeshCtx = MeshCtx(),
    rng: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, list, jnp.ndarray, Dict]:
    """Run all layers. Returns (x, new_router_states, aux_total, metrics).

    metrics['max_vio_per_layer']: (n_moe_layers,) in layer order; every
    other column the MoE layers emit follows the same convention —
    'load_per_layer' (n_moe_layers, m) int32 dispatch counts,
    'dropped_frac_cap1_per_layer', 'q_abs_max_per_layer', and (bip
    forecaster) 'forecast_err_per_layer' / 'forecast_hit_per_layer'.

    `rng` (optional) is the caller's per-step PRNG key; each layer receives
    a fold of it (group index threaded through the scan, position folded
    inside), so dropout-style regularizers get resume-stable randomness.
    `segments` masks attention to within-document (packed real-text data).
    """
    period, n_groups, remainder = _group_layout(cfg)
    kinds = cfg.layer_kinds()
    shared = params.get("shared")

    def period_body(x, layer_params, layer_states, group_rng=None):
        """Apply positions j = 0..period-1 once; returns per-j aux/mets.

        Per-MoE-layer metrics come back as a dict of stacked arrays
        ({'max_vio': (n_moe,), 'load': (n_moe, m) int32, ...}) so every
        telemetry column the layers emit is threaded through the scan —
        the key set is identical across layers (same MoE path per model),
        which is what lax.scan's fixed carry/output structure needs.
        """
        x = mesh_ctx.constrain(x, mesh_ctx.batch_spec, None, None)
        new_states, auxes = [], []
        per_layer: Dict[str, list] = {}
        for j in range(period):
            x, st, aux, mets = apply_layer(
                layer_params[j],
                x,
                cfg,
                kinds[j][0],
                kinds[j][1],
                layer_states[j],
                positions=positions,
                segments=segments,
                enc_out=enc_out,
                shared_params=shared,
                mesh_ctx=mesh_ctx,
                rng=None if group_rng is None else jax.random.fold_in(group_rng, j),
            )
            new_states.append(st)
            auxes.append(aux)
            if "max_vio" in mets:
                for k, v in mets.items():
                    per_layer.setdefault(k, []).append(v)
        aux_total = sum(auxes) if auxes else jnp.zeros((), jnp.float32)
        stacked = (
            {k: jnp.stack(v) for k, v in per_layer.items()}
            if per_layer
            else {"max_vio": jnp.zeros((0,), jnp.float32)}
        )
        return x, new_states, aux_total, stacked

    # full groups via scan
    if n_groups > 0:
        full_params = [jax.tree.map(lambda a: a[:n_groups], params["blocks"][j]) for j in range(period)]
        full_states = [
            None
            if router_states[j] is None
            else jax.tree.map(lambda a: a[:n_groups], router_states[j])
            for j in range(period)
        ]

        body_fn = period_body
        if cfg.remat == "block":
            # recompute activations in backward: memory per device drops from
            # O(n_layers · tokens · d) to O(period · tokens · d) + residuals
            body_fn = jax.checkpoint(period_body)

        group_keys = (
            None if rng is None else jax.random.split(jax.random.fold_in(rng, 0), n_groups)
        )

        def scan_body(x, per_group):
            lp, ls = per_group[0], per_group[1]
            gk = per_group[2] if group_keys is not None else None
            x, new_states, aux, lmets = body_fn(x, lp, ls, gk)
            return x, (new_states, aux, lmets)

        xs = (full_params, full_states)
        if group_keys is not None:
            xs = xs + (group_keys,)
        x, (scanned_states, auxes, met_groups) = lax.scan(scan_body, x, xs)
        aux_total = jnp.sum(auxes)
        # met_groups[k]: (n_groups, n_moe_in_period, ...) stacked by the scan
    else:
        scanned_states = [None] * period
        aux_total = jnp.zeros((), jnp.float32)
        met_groups = {"max_vio": jnp.zeros((0, 0), jnp.float32)}

    # remainder layers (tail prefix of the period), applied once
    rem_states = []
    rem_mets: list = []
    if remainder:
        lp = [
            jax.tree.map(lambda a: a[n_groups], params["blocks"][j])
            for j in range(remainder)
        ]
        ls = [
            None
            if router_states[j] is None
            else jax.tree.map(lambda a: a[n_groups], router_states[j])
            for j in range(remainder)
        ]
        rem_rng = None if rng is None else jax.random.fold_in(rng, 1)
        for j in range(remainder):
            x, st, aux, mets = apply_layer(
                lp[j],
                x,
                cfg,
                kinds[j][0],
                kinds[j][1],
                ls[j],
                positions=positions,
                segments=segments,
                enc_out=enc_out,
                shared_params=shared,
                mesh_ctx=mesh_ctx,
                rng=None if rem_rng is None else jax.random.fold_in(rem_rng, j),
            )
            rem_states.append(st)
            aux_total = aux_total + aux
            if "max_vio" in mets:
                rem_mets.append(mets)

    # reassemble router-state stacks
    new_router_states = []
    for j in range(period):
        if router_states[j] is None:
            new_router_states.append(None)
            continue
        base = scanned_states[j]
        if remainder and j < remainder and rem_states[j] is not None:
            tail = jax.tree.map(lambda a: a[None], rem_states[j])
            base = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0), base, tail
            )
        new_router_states.append(base)

    # per-layer metric columns in true layer order (group-major reassembly,
    # matching how the scan visits layers); every key the layers emitted
    # becomes '<key>_per_layer' with a leading (n_moe_layers,) axis
    moe_positions = [j for j in range(period) if kinds[j][1] == "moe"]
    keys = list(rem_mets[0]) if rem_mets else list(met_groups)
    metrics: Dict[str, jnp.ndarray] = {}
    for k in keys:
        vals = []
        if n_groups > 0 and len(moe_positions) and k in met_groups:
            for g in range(n_groups):
                for i, _ in enumerate(moe_positions):
                    vals.append(met_groups[k][g, i])
        vals.extend(m[k] for m in rem_mets)
        metrics[f"{k}_per_layer"] = (
            jnp.stack(vals) if vals else jnp.zeros((0,), jnp.float32)
        )
    if "max_vio_per_layer" not in metrics:
        metrics["max_vio_per_layer"] = jnp.zeros((0,), jnp.float32)
    return x, new_router_states, aux_total, metrics
