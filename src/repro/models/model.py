"""Top-level model: embeddings + stack + head, for every assigned family.

`build_model(cfg, mesh_ctx)` returns a `Model` of pure functions:

    init(key)                      -> params
    init_router_states()           -> router state stacks (MoE only)
    forward(params, batch, states) -> (logits, new_states, aux, metrics)
    loss_fn(params, batch, states) -> (loss, (new_states, metrics))
    init_cache(batch, seq_len)     -> decode caches (+ cross-attn KV)
    prefill(params, batch, cache, states)      -> (logits_last, cache, states)
    decode_step(params, tokens, cache, states) -> (logits, cache, states)

Batch dict keys by family:
    all:    'tokens' (B, S) int32; training also 'labels' (B, S)
    vlm:    'patches' (B, frontend_tokens, frontend_dim) — SigLIP stub output
    encdec: 'frames' (B, enc_seq_len, frontend_dim)     — codec stub output
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import common, mamba2, moe, stack
from repro.models.stack import MeshCtx

Params = Dict[str, Any]


# ------------------------------------------------------------- encoder


def _init_encoder(key, cfg: ModelConfig) -> Params:
    """Bidirectional transformer encoder (audio/encdec family)."""
    enc_cfg = dataclasses.replace(
        cfg, n_layers=cfg.n_enc_layers, attn_pattern=("global",)
    )
    keys = jax.random.split(key, cfg.n_enc_layers + 1)
    layers = jax.vmap(lambda k: stack.init_layer(k, enc_cfg, "global", "dense"))(
        keys[: cfg.n_enc_layers]
    )
    return {
        "layers": layers,
        "final_norm": common.init_rmsnorm(cfg.d_model, cfg.param_dtype),
    }


def _apply_encoder(
    params: Params, x: jnp.ndarray, cfg: ModelConfig, mesh_ctx=None
) -> jnp.ndarray:
    """Non-causal self-attention encoder over frame embeddings.

    Uses the shared query-chunked attention (causal=False) so the (S, S)
    score matrix is never materialized, and remats each scanned layer under
    cfg.remat like the decoder stack."""
    enc_cfg = dataclasses.replace(
        cfg, n_layers=cfg.n_enc_layers, attn_pattern=("global",)
    )

    def body_fn(x, lp):
        h = common.attention(
            lp["attn"],
            common.rmsnorm(lp["pre_norm"], x, cfg.rms_norm_eps),
            enc_cfg,
            positions=jnp.arange(x.shape[1])[None, :],
            mesh_ctx=mesh_ctx,
            causal=False,
        )
        x = x + h
        h = common.mlp(
            lp["mlp"], common.rmsnorm(lp["ffn_norm"], x, cfg.rms_norm_eps), enc_cfg
        )
        return x + h

    if cfg.remat == "block":
        body_fn = jax.checkpoint(body_fn)

    def body(x, lp):
        return body_fn(x, lp), None

    x, _ = lax.scan(body, x, params["layers"])
    return common.rmsnorm(params["final_norm"], x, cfg.rms_norm_eps)


def _merge_load(load_total, vio_max, ld, m_load):
    """Fold one MoE layer's per-expert dispatch counts into the running
    (total load, worst per-layer MaxVio) pair. MaxVio = max/mean - 1, the
    paper's metric (same convention as core.metrics.balance_metrics).
    Counts accumulate in int32 (telemetry dtype audit); only the MaxVio
    ratio is float."""
    if ld is None:
        return load_total, vio_max
    mean = jnp.maximum(jnp.sum(ld) / m_load, 1e-9)
    return load_total + ld, jnp.maximum(vio_max, jnp.max(ld) / mean - 1.0)


# --------------------------------------------------------------- model


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    mesh_ctx: MeshCtx

    # ------------------------------------------------------------- init

    def init(self, key) -> Params:
        cfg = self.cfg
        keys = jax.random.split(key, 6)
        p: Params = {
            "embed": common.init_embedding(keys[0], cfg),
            "stack": stack.init_stack(keys[1], cfg),
            "final_norm": common.init_rmsnorm(cfg.d_model, cfg.param_dtype),
        }
        if cfg.n_enc_layers:
            p["encoder"] = _init_encoder(keys[2], cfg)
        if cfg.frontend_dim:
            p["frontend_proj"] = (
                jax.random.normal(
                    keys[3], (cfg.frontend_dim, cfg.d_model), cfg.param_dtype
                )
                / math.sqrt(cfg.frontend_dim)
            )
        return p

    def init_router_states(self) -> list:
        return stack.init_stack_router_states(self.cfg)

    # -------------------------------------------------------- embedding

    def _embed_inputs(self, params: Params, batch: Dict[str, jnp.ndarray]):
        """Token embeddings with optional modality prefix. Returns (x, n_prefix)."""
        cfg = self.cfg
        x = common.embed(params["embed"], batch["tokens"], cfg)
        if cfg.family == "vlm":
            patches = batch["patches"].astype(cfg.compute_dtype)
            proj = jnp.einsum(
                "bsf,fd->bsd", patches, params["frontend_proj"].astype(cfg.compute_dtype)
            )
            x = jnp.concatenate([proj, x], axis=1)
            return x, cfg.frontend_tokens
        return x, 0

    def _encode(self, params: Params, batch) -> Optional[jnp.ndarray]:
        cfg = self.cfg
        if not cfg.n_enc_layers:
            return None
        frames = batch["frames"].astype(cfg.compute_dtype)
        proj = jnp.einsum(
            "bsf,fd->bsd", frames, params["frontend_proj"].astype(cfg.compute_dtype)
        )
        proj = self.mesh_ctx.constrain(proj, self.mesh_ctx.batch_spec, None, None)
        return _apply_encoder(params["encoder"], proj, cfg, self.mesh_ctx)

    # ---------------------------------------------------------- forward

    def forward(
        self,
        params: Params,
        batch: Dict[str, jnp.ndarray],
        router_states: list,
        rng: Optional[jnp.ndarray] = None,
    ) -> Tuple[jnp.ndarray, list, jnp.ndarray, Dict]:
        cfg = self.cfg
        mc = self.mesh_ctx
        x, n_prefix = self._embed_inputs(params, batch)
        x = mc.constrain(x, mc.batch_spec, None, None)
        enc_out = self._encode(params, batch)
        positions = jnp.arange(x.shape[1])[None, :]
        # packed real-text batches carry per-position document ids; the
        # attention mask then stays within-document (modality-prefix models
        # never pack, so the prefix offset never meets segments)
        segments = batch.get("segments") if n_prefix == 0 else None
        if segments is not None and cfg.family in ("ssm", "hybrid"):
            # the SSM recurrence carries state across the packed boundary —
            # the mask can't cut it, so refuse rather than silently leak
            raise ValueError(
                "segment-masked packing (pack_nocross) is attention-only; "
                f"{cfg.family} architectures leak document state through the "
                "mamba recurrence — use pack_mode='pack' or 'pad'"
            )
        x, new_states, aux, mets = stack.apply_stack(
            params["stack"],
            x,
            router_states,
            cfg,
            positions=positions,
            segments=segments,
            enc_out=enc_out,
            mesh_ctx=self.mesh_ctx,
            rng=rng,
        )
        x = common.rmsnorm(params["final_norm"], x, cfg.rms_norm_eps)
        if n_prefix:
            x = x[:, n_prefix:]
        x = mc.constrain(x, mc.batch_spec, None, None)
        logits = common.unembed(params["embed"], x, cfg)
        # tokens stay batch-sharded; the vocab axis carries the model shards
        logits = mc.constrain(logits, mc.batch_spec, None, mc.model_axis or None)
        return logits, new_states, aux, mets

    def loss_fn(
        self,
        params: Params,
        batch: Dict[str, jnp.ndarray],
        router_states: list,
        rng: Optional[jnp.ndarray] = None,
    ):
        logits, new_states, aux, mets = self.forward(params, batch, router_states, rng=rng)
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        mask = (labels >= 0).astype(jnp.float32)
        nll = jnp.where(labels >= 0, nll, 0.0)
        ce = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        loss = ce + aux
        mets = dict(mets)
        mets.update(ce_loss=ce, aux_loss=aux, perplexity=jnp.exp(ce))
        return loss, (new_states, mets)

    # ---------------------------------------------------------- serving

    def init_cache(
        self, params: Params, batch: Dict[str, jnp.ndarray], seq_len: int
    ) -> Params:
        """Decode caches mirroring the stack layout; cross-attn K/V are
        precomputed from the encoder output here (static per request)."""
        return self._build_cache(
            params, batch["tokens"].shape[0], seq_len, self._encode(params, batch)
        )

    def init_slot_cache(self, params: Params, n_slots: int, max_seq_len: int) -> Params:
        """Slot-pool cache for the continuous-batching engine (DESIGN.md
        §Serving): one cache row per batch slot, no request batch needed.
        Slots are recycled across requests via `reset_slot`; per-slot 'pos'
        indices let slots at different sequence offsets share one traced
        step. Token-only families; encdec needs per-request encoder K/V."""
        assert not self.cfg.n_enc_layers, "slot cache: encdec not supported"
        return self._build_cache(params, n_slots, max_seq_len, None)

    @staticmethod
    def reset_slot(cache: Params, slot: jnp.ndarray) -> Params:
        """Zero one slot's rows across every cache leaf (K/V, positions,
        SSM/conv state) without retracing — `slot` is a traced index, so a
        single jitted reset serves the whole pool."""
        return jax.tree.map(lambda a: a.at[:, slot].set(jnp.zeros((), a.dtype)), cache)

    def _build_cache(
        self, params: Params, bsz: int, seq_len: int, enc_out
    ) -> Params:
        cfg = self.cfg
        period, n_groups, remainder = stack._group_layout(cfg)
        kinds = cfg.layer_kinds()
        kv_dtype = cfg.compute_dtype

        def one_cache(mixer_kind, layer_params=None):
            c: Dict[str, jnp.ndarray] = {}
            base = mixer_kind.replace("+shared", "")
            if base in ("global", "local"):
                c.update(common.init_attention_cache(cfg, bsz, seq_len, base, kv_dtype))
                if enc_out is not None and layer_params is not None:
                    dt = cfg.compute_dtype
                    c["ck"] = jnp.einsum(
                        "bsd,dhk->bshk", enc_out, layer_params["cross"]["wk"].astype(dt)
                    )
                    c["cv"] = jnp.einsum(
                        "bsd,dhk->bshk", enc_out, layer_params["cross"]["wv"].astype(dt)
                    )
            else:
                c.update(mamba2.init_mamba_cache(cfg, bsz, kv_dtype))
                if mixer_kind.endswith("+shared"):
                    sc = common.init_attention_cache(cfg, bsz, seq_len, "global", kv_dtype)
                    c.update({"sk": sc["k"], "sv": sc["v"], "spos": sc["pos"]})
            return c

        caches = []
        for j in range(period):
            reps = n_groups + (1 if j < remainder else 0)
            lp0 = jax.tree.map(lambda a: a[0], params["stack"]["blocks"][j])
            proto = one_cache(kinds[j][0], lp0)
            if "ck" in proto:
                # per-rep cross KV differ (different layer weights): build each
                per = [
                    one_cache(
                        kinds[j][0],
                        jax.tree.map(lambda a: a[r], params["stack"]["blocks"][j]),
                    )
                    for r in range(reps)
                ]
                caches.append(
                    jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *per)
                )
            else:
                caches.append(
                    jax.tree.map(
                        lambda a: jnp.broadcast_to(a, (reps,) + a.shape).copy(), proto
                    )
                )
        return {"blocks": caches}

    def _apply_layer_chunk(
        self, p, x, cfg, mixer_kind, ffn_kind, cache, router_state, lengths,
        packed=None,
    ):
        """One layer over a (B, C) token chunk against the slot cache.

        `lengths` is (B,) valid-token counts, or None meaning every column is
        real (the decode_step / dryrun path — keeps the MoE dispatch
        unmasked and therefore expert-parallel safe). `packed` (a dict of
        positions/segments/write_slots/cache_rows) switches attention into
        the packed multi-request layout; column validity then comes from
        segments >= 0. Returns
        (x, new_cache, new_router_state, aux, load) with load the per-expert
        dispatch counts of this layer's real tokens ((m,) or None).
        """
        base = mixer_kind.replace("+shared", "")
        new_cache = dict(cache)
        valid = None
        if packed is not None:
            valid = packed["segments"] >= 0  # (B, C)
        elif lengths is not None:
            valid = jnp.arange(x.shape[1])[None, :] < lengths[:, None]  # (B, C)
        if base in ("global", "local"):
            h, attn_cache = common.attention_chunk(
                p["attn"],
                common.rmsnorm(p["pre_norm"], x, cfg.rms_norm_eps),
                {"k": cache["k"], "v": cache["v"], "pos": cache["pos"]},
                cfg,
                layer_kind=base,
                lengths=lengths,
                **(packed or {}),
            )
            new_cache.update(attn_cache)
            x = x + stack._maybe_post(p, "post_attn_norm", h, cfg)
            if "ck" in cache:
                xq = common.rmsnorm(p["cross_norm"], x, cfg.rms_norm_eps)
                dt = cfg.compute_dtype
                q = jnp.einsum("bsd,dhk->bshk", xq, p["cross"]["wq"].astype(dt))
                se = cache["ck"].shape[1]
                if valid is None:
                    mask = jnp.ones((1, 1, x.shape[1], se), bool)
                else:
                    mask = jnp.broadcast_to(
                        valid[:, None, :, None], (x.shape[0], 1, x.shape[1], se)
                    )
                y = common._attend(q, cache["ck"], cache["cv"], mask, 0.0, dt)
                x = x + jnp.einsum(
                    "bshk,hkd->bsd", y, p["cross"]["wo"].astype(dt)
                )
        else:
            h, mcache = mamba2.mamba_chunk(
                p["mamba"],
                common.rmsnorm(p["pre_norm"], x, cfg.rms_norm_eps),
                {"ssm": cache["ssm"], "conv": cache["conv"]},
                cfg,
                lengths=lengths,
            )
            new_cache.update(mcache)
            x = x + h

        aux = jnp.zeros((), jnp.float32)
        load = None
        if ffn_kind == "dense":
            h = common.mlp(
                p["mlp"], common.rmsnorm(p["ffn_norm"], x, cfg.rms_norm_eps), cfg
            )
            x = x + stack._maybe_post(p, "post_ffn_norm", h, cfg)
        elif ffn_kind == "moe":
            xin = common.rmsnorm(p["ffn_norm"], x, cfg.rms_norm_eps)
            b, s, d = xin.shape
            if valid is None:
                flat = xin.reshape(b * s, d)
                token_mask = None
            else:
                # zero padded rows so they router-score as neutral uniform
                flat = (xin * valid[..., None].astype(xin.dtype)).reshape(b * s, d)
                token_mask = valid.reshape(b * s)
            y, router_state, aux, moe_mets = moe.moe_ffn(
                p["moe"], flat, router_state, cfg, self.mesh_ctx, token_mask=token_mask
            )
            load = moe_mets["load"]
            h = y.reshape(b, s, d)
            if cfg.dense_residual and "mlp" in p:
                h = h + common.mlp(p["mlp"], xin, cfg)
            if cfg.n_shared_experts and "shared_mlp" in p:
                h = h + common.mlp(p["shared_mlp"], xin, cfg)
            x = x + h

        if mixer_kind.endswith("+shared"):
            sp = self._shared_params
            h, sc = common.attention_chunk(
                sp["attn"],
                common.rmsnorm(sp["pre_norm"], x, cfg.rms_norm_eps),
                {"k": cache["sk"], "v": cache["sv"], "pos": cache["spos"]},
                cfg,
                layer_kind="global",
                lengths=lengths,
                **(packed or {}),
            )
            new_cache.update({"sk": sc["k"], "sv": sc["v"], "spos": sc["pos"]})
            x = x + h
            h = common.mlp(
                sp["mlp"], common.rmsnorm(sp["ffn_norm"], x, cfg.rms_norm_eps), cfg
            )
            x = x + h
        return x, new_cache, router_state, aux, load

    def prefill_chunk(
        self,
        params: Params,
        tokens: jnp.ndarray,  # (B, C) int32
        cache: Params,
        router_states: list,
        lengths: Optional[jnp.ndarray] = None,  # (B,) valid counts; None = all C
        *,
        positions: Optional[jnp.ndarray] = None,  # (B, C) packed-mode layout
        segments: Optional[jnp.ndarray] = None,  # (B, C); -1 = padding
        write_slots: Optional[jnp.ndarray] = None,  # (B, C) cache row per column
        cache_rows: Optional[jnp.ndarray] = None,  # (B,) cache row each row reads
    ) -> Tuple[jnp.ndarray, Params, list, Dict[str, jnp.ndarray]]:
        """Advance every slot by up to C tokens in ONE fused, trace-once step.

        The continuous-batching core (DESIGN.md §Serving): prefilling slots
        carry their next <=C prompt tokens, decoding slots carry 1 sampled
        token, idle slots carry 0 — all through the same program, so mixed
        prefill/decode traffic shares each MoE layer's router invocation and
        the BIP dual vector q keeps balancing across the whole batch.
        Returns (logits (B, C, vocab), cache, router_states, metrics) where
        metrics['moe_load'] is the per-expert dispatch count of real tokens
        summed over MoE layers and metrics['max_vio'] the worst per-layer
        violation. Padded logit columns are garbage; callers index
        lengths-1.

        Passing `segments` switches attention into the PACKED layout
        (common._attention_chunk_packed): rows and cache slots decouple, and
        every column carries (position, segment, write slot). Attention-only
        stacks only — SSM/conv state advances strictly left-to-right per row
        and cannot host interleaved streams.
        """
        cfg = self.cfg
        period, n_groups, remainder = stack._group_layout(cfg)
        kinds = cfg.layer_kinds()
        packed = None
        if segments is not None:
            bad = {k for k, _ in kinds if k.replace("+shared", "") not in ("global", "local")}
            if bad:
                raise ValueError(
                    f"packed prefill: attention-only stacks required, got {sorted(bad)}"
                )
            packed = {
                "positions": positions,
                "segments": segments,
                "write_slots": write_slots,
                "cache_rows": cache_rows,
            }
        self._shared_params = params["stack"].get("shared")
        x = common.embed(params["embed"], tokens, cfg)
        m_load = cfg.routing.n_experts if cfg.is_moe else 1

        def apply_period(x, lp, lc, ls):
            new_caches, new_states = [], []
            load = jnp.zeros((m_load,), jnp.int32)
            vio = jnp.zeros((), jnp.float32)
            for j in range(period):
                x, nc, st, _, ld = self._apply_layer_chunk(
                    lp[j], x, cfg, kinds[j][0], kinds[j][1], lc[j], ls[j], lengths,
                    packed,
                )
                new_caches.append(nc)
                new_states.append(st)
                load, vio = _merge_load(load, vio, ld, m_load)
            return x, new_caches, new_states, load, vio

        def scan_body(x, per_group):
            lp, lc, ls = per_group
            x, new_caches, new_states, load, vio = apply_period(x, lp, lc, ls)
            return x, (new_caches, new_states, load, vio)

        if n_groups > 0:
            lp = [
                jax.tree.map(lambda a: a[:n_groups], params["stack"]["blocks"][j])
                for j in range(period)
            ]
            lc = [
                jax.tree.map(lambda a: a[:n_groups], cache["blocks"][j])
                for j in range(period)
            ]
            ls = [
                None
                if router_states[j] is None
                else jax.tree.map(lambda a: a[:n_groups], router_states[j])
                for j in range(period)
            ]
            x, (new_caches, new_states, loads, vios) = lax.scan(
                scan_body, x, (lp, lc, ls)
            )
            load_total = jnp.sum(loads, axis=0)
            vio_max = jnp.max(vios) if n_groups else jnp.zeros((), jnp.float32)
        else:
            new_caches = [None] * period
            new_states = [None] * period
            load_total = jnp.zeros((m_load,), jnp.int32)
            vio_max = jnp.zeros((), jnp.float32)

        # remainder layers (tail prefix of the period), applied once
        rem_caches, rem_states = [], []
        for j in range(remainder):
            lp_j = jax.tree.map(lambda a: a[n_groups], params["stack"]["blocks"][j])
            lc_j = jax.tree.map(lambda a: a[n_groups], cache["blocks"][j])
            ls_j = (
                None
                if router_states[j] is None
                else jax.tree.map(lambda a: a[n_groups], router_states[j])
            )
            x, nc, st, _, ld = self._apply_layer_chunk(
                lp_j, x, cfg, kinds[j][0], kinds[j][1], lc_j, ls_j, lengths,
                packed,
            )
            rem_caches.append(nc)
            rem_states.append(st)
            load_total, vio_max = _merge_load(load_total, vio_max, ld, m_load)

        out_caches, out_states = [], []
        for j in range(period):
            c = new_caches[j]
            s = new_states[j]
            if remainder and j < remainder:
                c = jax.tree.map(
                    lambda a, b: jnp.concatenate([a, b[None]], axis=0),
                    c,
                    rem_caches[j],
                )
                if s is not None:
                    s = jax.tree.map(
                        lambda a, b: jnp.concatenate([a, b[None]], axis=0),
                        s,
                        rem_states[j],
                    )
            out_caches.append(c)
            out_states.append(s)

        x = common.rmsnorm(params["final_norm"], x, cfg.rms_norm_eps)
        logits = common.unembed(params["embed"], x, cfg)
        mets = {"moe_load": load_total, "max_vio": vio_max}
        return logits, {"blocks": out_caches}, out_states, mets

    def decode_step(
        self,
        params: Params,
        tokens: jnp.ndarray,  # (B, 1) int32
        cache: Params,
        router_states: list,
    ) -> Tuple[jnp.ndarray, Params, list]:
        """One token for every sequence in the batch (prefill_chunk, C=1)."""
        logits, cache, states, _ = self.prefill_chunk(
            params, tokens, cache, router_states
        )
        return logits, cache, states

    def prefill(
        self,
        params: Params,
        batch: Dict[str, jnp.ndarray],
        router_states: list,
        seq_len: int,
    ):
        """Prefill = forward pass + cache fill. For simplicity the cache is
        filled by scanning decode steps for short prompts; production prefill
        uses the chunked forward and writes K/V in bulk — here we only need
        the compiled-graph shape for the dry-run, so prefill == forward and
        returns last-position logits."""
        logits, new_states, aux, mets = self.forward(params, batch, router_states)
        return logits[:, -1:], new_states, mets


def build_model(cfg: ModelConfig, mesh_ctx: MeshCtx = MeshCtx()) -> Model:
    cfg.validate()
    return Model(cfg=cfg, mesh_ctx=mesh_ctx)
