"""Mixture-of-Experts FFN with BIP-balanced routing and expert parallelism.

Two execution paths, same math:

* `moe_ffn_local` — plain jnp scatter/gather on one logical array. Used on
  single-device (tests, the paper-reproduction training runs) and as the
  semantic reference for the distributed path.

* `moe_ffn_ep` — shard_map over the production mesh. Activations arrive
  sharded over the data axes and replicated over 'model'; experts are sharded
  over 'model' (expert parallelism). Each model-rank routes its replicated
  token block, gathers the tokens bound for ITS experts into a static
  (m_local, C, d) buffer, runs the expert GEMMs, and contributes its experts'
  outputs to a psum over 'model'. There is no explicit all-to-all: dispatch
  is a local gather (tokens are already present via model-axis replication)
  and combine rides the same all-reduce tensor parallelism already pays for
  the FFN block. See DESIGN.md §6.

Capacity: C = ceil(k·n/m · capacity_factor). Because BIP routing bounds
per-expert load at ~(1 + MaxVio)·k·n/m with MaxVio ≲ 0.2 from the first step,
capacity_factor 1.25 loses almost nothing — the paper's systems payoff.
Tokens beyond capacity are dropped (contribute zero), standard MoE practice.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import metrics as core_metrics
from repro.core import get_balancer, make_dispatch_plan, route
from repro.core.types import RouterConfig
from repro.telemetry.trace import named_span

Params = Dict[str, jnp.ndarray]


def _shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    """jax.shard_map when available, else the jax.experimental spelling
    (pre-0.5 jax exposes it only there, with check_vma named check_rep)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma)


def router_config(cfg: ModelConfig, data_axes: Tuple[str, ...] = ()) -> RouterConfig:
    """RouterConfig for this model — one conversion point (RoutingSpec shim)."""
    return cfg.routing.to_router_config(data_axes=data_axes)


def _state_specs(router_state):
    """Replicated PartitionSpec pytree matching the router-state dict.

    Every router-state leaf (q and the forecaster EMAs (m,), lpr's (m, m)
    prototype matrix) is replicated across the mesh, so the spec tree is
    P(None) everywhere (trailing dims pad with None) — built from the live
    state so new keys never need a hand-written spec.
    """
    return jax.tree.map(lambda _: P(None), router_state)


def expert_capacity(n_tokens: int, cfg: ModelConfig) -> int:
    r = cfg.routing
    return max(
        int(math.ceil(r.top_k * n_tokens / r.n_experts * r.capacity_factor)), 1
    )


# ------------------------------------------------------------------- init


def init_moe(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    m = cfg.routing.n_experts
    keys = jax.random.split(key, 5)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {
        "w_router": jax.random.normal(keys[0], (d, m), jnp.float32) * s_in,
        "w_gate": jax.random.normal(keys[1], (m, d, f), cfg.param_dtype) * s_in,
        "w_up": jax.random.normal(keys[2], (m, d, f), cfg.param_dtype) * s_in,
        "w_down": jax.random.normal(keys[3], (m, f, d), cfg.param_dtype)
        * (s_out / math.sqrt(2 * cfg.n_layers)),
    }
    return p


def _flat_axis_index(mesh, axes: Tuple[str, ...]):
    """Row-major flat index across several mesh axes (inside shard_map)."""
    idx = lax.axis_index(axes[0])
    for a in axes[1:]:
        idx = idx * mesh.shape[a] + lax.axis_index(a)
    return idx


# Above this many tokens per invocation, gathering activations (ep2d) costs
# more than gathering weight shards (ep); below it, ep2d wins outright —
# for decode it removes the per-layer weight gather entirely. Measured via
# the dry-run roofline (EXPERIMENTS.md §Perf).
EP2D_TOKEN_THRESHOLD = 32768


def moe_ffn(params, x, router_state, cfg, mesh_ctx, token_mask=None):
    """Dispatch to the configured implementation ('auto' picks by size).

    token_mask (n,) bool marks real tokens; False rows (serving padding)
    still receive selections (static shapes) but are excluded from
    dispatch, capacity, the router-state update, and the load metrics.
    Every path supports it: the EP impls shard the mask alongside the
    tokens and psum the real-token counts, so EP-sharded serving reports
    the same masked load histograms as the single-device engine
    (DESIGN.md §Serving).
    """
    if mesh_ctx is not None and getattr(mesh_ctx, "use_ep", False):
        impl_name = cfg.routing.moe_impl
        if impl_name == "auto":
            # selective gather wins at every scale measured (§Perf); tiny
            # token counts route through its ep2d fallback automatically
            impl_name = "ep2ds"
        impl = {"ep2d": moe_ffn_ep2d, "ep2ds": moe_ffn_ep2ds, "ep": moe_ffn_ep}[
            impl_name
        ]
        return impl(
            params,
            x,
            router_state,
            cfg,
            mesh_ctx.mesh,
            data_axes=mesh_ctx.data_axes,
            model_axis=mesh_ctx.model_axis,
            token_mask=token_mask,
        )
    return moe_ffn_local(params, x, router_state, cfg, token_mask=token_mask)


# -------------------------------------------------- dispatch bookkeeping
#
# The hot path builds a sort-based ragged plan (core.router.make_dispatch_plan):
# argsort + segment offsets, pack/combine as pure gathers. `_dispatch_plan`
# below is the historical one-hot/cumsum formulation, kept as the semantic
# oracle for the parity suite (tests/test_moe_dispatch.py), the property
# tests, and benchmarks/moe_dispatch.py's old-vs-new comparison.


def _dispatch_plan(
    expert_index: jnp.ndarray,  # (n, k) int32
    n_experts: int,
    capacity: int,
    token_mask: Optional[jnp.ndarray] = None,  # (n,) bool; False never dispatches
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Position of every (token, slot) inside its expert's capacity queue.

    Returns (pos (n, k) int32, keep (n, k) bool). Queue order is token order
    (earlier tokens win capacity), slot-major within a token. Masked tokens
    (serving padding) are excluded from the queues entirely: they neither
    occupy capacity nor displace real tokens, so a padded batch dispatches
    identically to the same real tokens alone.
    """
    n, k = expert_index.shape
    flat = expert_index.reshape(-1)  # (n*k,) — token-major, slot-minor
    onehot = jax.nn.one_hot(flat, n_experts, dtype=jnp.int32)  # (n*k, m)
    if token_mask is not None:
        onehot = onehot * jnp.repeat(token_mask, k).astype(jnp.int32)[:, None]
    pos_flat = jnp.cumsum(onehot, axis=0) - 1  # position within expert queue
    pos = jnp.take_along_axis(pos_flat, flat[:, None], axis=1)[:, 0]
    pos = pos.reshape(n, k)
    keep = pos < capacity
    if token_mask is not None:
        keep = keep & token_mask[:, None]
    return pos, keep


def _expert_ffn(
    w_gate: jnp.ndarray,  # (e, d, f)
    w_up: jnp.ndarray,
    w_down: jnp.ndarray,  # (e, f, d)
    xb: jnp.ndarray,  # (e, c, d)
    cfg: ModelConfig,
) -> jnp.ndarray:
    dt = cfg.compute_dtype
    if cfg.routing.use_kernel and cfg.act == "silu":
        from repro.kernels import ops as kernel_ops  # lazy: avoid import cycle

        return kernel_ops.expert_ffn(
            xb.astype(dt),
            w_gate.astype(dt),
            w_up.astype(dt),
            w_down.astype(dt),
        )
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    g = jnp.einsum("ecd,edf->ecf", xb, w_gate.astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xb, w_up.astype(dt))
    return jnp.einsum("ecf,efd->ecd", act(g) * u, w_down.astype(dt))


# -------------------------------------------------------- single-device


def moe_ffn_local(
    params: Params,
    x: jnp.ndarray,  # (n, d) flattened tokens
    router_state: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    token_mask: Optional[jnp.ndarray] = None,  # (n,) bool
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray], jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Reference path. Returns (y, new_router_state, aux_loss, metrics).

    The router sees the whole batch, so the duals are the paper's global
    semantics under either sync mode (data_axes=()); this is the trajectory
    the sync='global' mesh paths are parity-tested against.
    """
    n, d = x.shape
    m = cfg.routing.n_experts
    cap = expert_capacity(n, cfg)
    rcfg = router_config(cfg, data_axes=())

    logits = jnp.einsum("nd,dm->nm", x.astype(jnp.float32), params["w_router"])
    out = route(logits, router_state, rcfg, token_mask=token_mask)
    with named_span("moe/dispatch"):
        plan = make_dispatch_plan(out.expert_index, m, cap, token_mask)
        buf = plan.pack(x)  # (m, cap, d) by gather — no one-hot, no scatter
    with named_span("moe/gemm"):
        y = _expert_ffn(
            params["w_gate"], params["w_up"], params["w_down"], buf, cfg
        )
    with named_span("moe/combine"):
        y_tok = plan.combine(y, out.combine_weights)

    mets = out.metrics
    if token_mask is not None:
        # balance metrics over the real tokens only (padding routes as
        # uniform filler and would flatten the reported load); the plan's
        # segment counts already exclude masked rows. Counts stay int32
        # (telemetry dtype audit — no float round-trip).
        load = plan.counts
        mean_load = jnp.maximum(
            jnp.sum(token_mask) * cfg.routing.top_k / m, 1e-9
        )
        mets = dict(mets)
        mets.update(load=load, max_vio=jnp.max(load) / mean_load - 1.0)
    return y_tok, out.state, out.aux_loss, mets


# ------------------------------------------------------ expert parallel


def moe_ffn_ep2d(
    params: Params,
    x: jnp.ndarray,  # (n_global, d), sharded over data axes
    router_state: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    mesh,
    *,
    data_axes: Tuple[str, ...],
    model_axis: str,
    token_mask: Optional[jnp.ndarray] = None,  # (n_global,) bool
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray], jnp.ndarray, Dict[str, jnp.ndarray]]:
    """2D expert-parallel path: gather ACTIVATIONS, never gather weights.

    Expert weights stay fully sharded at rest AND at use: experts over
    'model', each expert's hidden f over the data axes. Tokens are
    all-gathered over data inside the block (every rank sees the full
    microbatch), each rank computes its (m_loc, f_loc) slice for all tokens,
    and the combine is one reduce-scatter over data + psum over model.

    vs the FSDP path (moe_ffn_ep + data-sharded weights): communication per
    layer drops from O(expert_weight_bytes) to O(token_bytes) — for
    arctic-480b decode that is 1.67 GB -> ~2 MB per layer (§Perf). Expert
    gradients become fully local (each rank owns its weight shard and holds
    all tokens), removing the gradient reduce-scatter for expert params.
    """
    m = cfg.routing.n_experts
    k = cfg.routing.top_k
    n_global, d = x.shape
    n_data_shards = int(np.prod([mesh.shape[a] for a in data_axes]))
    token_sharded = (
        n_data_shards > 1
        and n_global % n_data_shards == 0
        and n_global >= n_data_shards
    )
    ep = mesh.shape[model_axis]
    assert m % ep == 0, (m, ep)
    m_loc = m // ep
    f = cfg.moe_d_ff or cfg.d_ff
    f_shards = n_data_shards if (token_sharded and f % n_data_shards == 0) else 1
    cap = expert_capacity(n_global, cfg)
    # data_axes deliberately (): routing below sees the GATHERED token batch,
    # so the duals are paper-global by construction under either sync mode —
    # psum'ing the order statistics on top would double-count every token
    rcfg = router_config(cfg)

    x_spec = P(data_axes if token_sharded else None, None)
    wf_spec = P(model_axis, None, data_axes if f_shards > 1 else None)
    wd_spec = P(model_axis, data_axes if f_shards > 1 else None, None)

    def block(x_loc, w_router, w_gate, w_up, w_down, q_state, *mask_args):
        rank = lax.axis_index(model_axis)
        mask_loc = mask_args[0] if mask_args else None
        if token_sharded:
            x_all = lax.all_gather(x_loc, data_axes, axis=0, tiled=True)
            mask_all = (
                lax.all_gather(mask_loc, data_axes, axis=0, tiled=True)
                if mask_loc is not None
                else None
            )
        else:
            x_all = x_loc  # already replicated
            mask_all = mask_loc
        logits = jnp.einsum("nd,dm->nm", x_all.astype(jnp.float32), w_router)
        out = route(logits, q_state, rcfg, token_mask=mask_all)
        plan = make_dispatch_plan(out.expert_index, m, cap, mask_all)

        # gather THIS rank's expert segments straight out of the sort order
        buf = plan.pack(x_all, expert_offset=rank * m_loc, n_local=m_loc)

        # expert FFN on the local (m_loc, f_loc) weight shard; y is partial
        # over f, completed by the psum below
        y = _expert_ffn(w_gate, w_up, w_down, buf, cfg)

        y_tok = plan.combine(y, out.combine_weights, expert_offset=rank * m_loc)
        y_tok = lax.psum(y_tok, model_axis)
        if token_sharded:
            if f_shards > 1:
                y_tok = lax.psum_scatter(
                    y_tok, data_axes, scatter_dimension=0, tiled=True
                )
            else:
                idx = _flat_axis_index(mesh, data_axes)
                n_loc = n_global // n_data_shards
                y_tok = lax.dynamic_slice_in_dim(y_tok, idx * n_loc, n_loc, 0)

        # routing ran on the gathered tokens (global duals regardless of
        # cfg.routing.sync): identical on every data rank, but all_gather
        # outputs are typed varying-over-data — the pmeans are semantic
        # no-ops (NOT cross-shard dual averaging, every rank already holds
        # the converged global q / forecaster EMAs) that re-establish
        # replication for check_vma
        new_state = out.state
        # masked: balance over real tokens only — router_metrics counts the
        # padded rows' placeholder selections; the plan's segment counts
        # already exclude them (mirrors moe_ffn_local)
        load = plan.counts if mask_all is not None else out.metrics["load"]
        n_real = (
            jnp.sum(mask_all.astype(jnp.int32)) if mask_all is not None else None
        )
        dropped = out.metrics["dropped_frac_cap1"]
        aux = out.aux_loss
        if token_sharded:
            new_state = jax.tree.map(lambda v: lax.pmean(v, data_axes), new_state)
            # every data rank routed the same gathered batch, so the int32
            # count histograms are replicated: psum // n is the exact
            # integer identity (pmean would round-trip through float)
            load = lax.psum(load, data_axes) // n_data_shards
            dropped = lax.pmean(dropped, data_axes)
            aux = lax.pmean(aux, data_axes)
            if n_real is not None:
                n_real = lax.psum(n_real, data_axes) // n_data_shards
        if n_real is not None:
            mean_load = jnp.maximum(n_real * k / m, 1e-9)
        else:
            mean_load = (n_global * k) / m
        mets = {
            "load": load,
            "max_vio": jnp.max(load) / mean_load - 1.0,
            "dropped_frac_cap1": dropped,
        }
        return y_tok, new_state, aux, mets

    in_specs = [
        x_spec,
        P(None, None),
        wf_spec,
        wf_spec,
        wd_spec,
        _state_specs(router_state),
    ]
    args = [
        x,
        params["w_router"],
        params["w_gate"],
        params["w_up"],
        params["w_down"],
        router_state,
    ]
    if token_mask is not None:
        in_specs.append(P(data_axes if token_sharded else None))
        args.append(token_mask)
    fn = _shard_map(
        block,
        mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(
            x_spec,
            _state_specs(router_state),
            P(),
            {"load": P(), "max_vio": P(), "dropped_frac_cap1": P()},
        ),
        check_vma=True,
    )
    return fn(*args)


def moe_ffn_ep2ds(
    params: Params,
    x: jnp.ndarray,  # (n_global, d), sharded over data axes
    router_state: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    mesh,
    *,
    data_axes: Tuple[str, ...],
    model_axis: str,
    token_mask: Optional[jnp.ndarray] = None,  # (n_global,) bool
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray], jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Selective 2D expert parallelism — gather only DISPATCHED tokens.

    Weights stay fully sharded like ep2d (experts→model, f→data), but
    instead of all-gathering the raw activations, each data rank dispatches
    its local tokens into per-expert capacity buffers FIRST and the
    (m_loc, cap_local, d) buffers are what crosses the wire:

        gather bytes / layer = k·n·cf/m · m_loc · d  (≈ x_bytes · k·cf/ep)

    — ~8x less than ep2d's full-token gather at arctic's k=2, ep=16, and it
    replaces moe_ffn_ep's per-layer expert-weight gather entirely. Combine
    is one psum_scatter over data (sums f-partials AND returns each source
    rank its own slice) plus the model-axis psum shared with TP.
    See EXPERIMENTS.md §Perf for the measured before/after.
    """
    m = cfg.routing.n_experts
    k = cfg.routing.top_k
    n_global, d = x.shape
    n_data_shards = int(np.prod([mesh.shape[a] for a in data_axes]))
    token_sharded = (
        n_data_shards > 1
        and n_global % n_data_shards == 0
        and n_global >= n_data_shards
    )
    if not token_sharded:
        return moe_ffn_ep2d(
            params, x, router_state, cfg, mesh,
            data_axes=data_axes, model_axis=model_axis, token_mask=token_mask,
        )
    ep = mesh.shape[model_axis]
    assert m % ep == 0, (m, ep)
    m_loc = m // ep
    n_loc = n_global // n_data_shards
    cap = expert_capacity(n_loc, cfg)
    f = cfg.moe_d_ff or cfg.d_ff
    f_sharded = f % n_data_shards == 0
    # sync='global': route() runs the psum'd threshold dual update over the
    # data axes, so each rank routes its local shard against the SAME duals
    # the unsharded reference would compute (DESIGN.md §Global-sync)
    rcfg = router_config(
        cfg, data_axes=data_axes if cfg.routing.sync == "global" else ()
    )

    wf_spec = P(model_axis, None, data_axes if f_sharded else None)
    wd_spec = P(model_axis, data_axes if f_sharded else None, None)

    def block(x_loc, w_router, w_gate, w_up, w_down, q_state, *mask_args):
        rank = lax.axis_index(model_axis)
        mask_loc = mask_args[0] if mask_args else None
        logits = jnp.einsum("nd,dm->nm", x_loc.astype(jnp.float32), w_router)
        out = route(logits, q_state, rcfg, token_mask=mask_loc)
        plan = make_dispatch_plan(out.expert_index, m, cap, mask_loc)

        buf = plan.pack(x_loc, expert_offset=rank * m_loc, n_local=m_loc)

        # selective gather: only dispatched tokens cross the data axis
        buf_all = lax.all_gather(buf, data_axes, axis=1, tiled=True)
        # (m_loc, n_data * cap, d)

        y = _expert_ffn(w_gate, w_up, w_down, buf_all, cfg)

        if f_sharded:
            # y is partial over f: sum partials and hand every source rank
            # its own slice back in one collective
            y = lax.psum_scatter(y, data_axes, scatter_dimension=1, tiled=True)
        else:
            # weights were replicated over data: y is complete; just take
            # this rank's slice of the gathered axis
            idx = _flat_axis_index(mesh, data_axes)
            y = lax.dynamic_slice_in_dim(y, idx * cap, cap, axis=1)
        # (m_loc, cap, d), complete values for THIS rank's dispatched tokens

        y_tok = plan.combine(y, out.combine_weights, expert_offset=rank * m_loc)
        y_tok = lax.psum(y_tok, model_axis)

        # global sync: the whole state dict (q + forecaster EMAs) converged
        # identically per shard (vma-replicated, no averaging); local sync:
        # pmean each balancer-declared carried leaf (the bip warm-start q,
        # lpr's prototypes) across shards so the replicated-state invariant
        # holds — keys outside local_avg_keys (forecaster EMAs) are
        # untouched by the local path and stay replicated
        if cfg.routing.sync == "global":
            new_state = out.state
        else:
            new_state = dict(out.state)
            for key in get_balancer(cfg.routing.strategy).local_avg_keys:
                new_state[key] = lax.pmean(out.state[key], data_axes)
        if mask_loc is not None:
            # per-expert counts of real tokens only (plan excludes masked
            # rows); normalize by the psum'd real-token count
            load = lax.psum(plan.counts, data_axes)
            n_real = lax.psum(jnp.sum(mask_loc.astype(jnp.int32)), data_axes)
            mean_load = jnp.maximum(n_real * k / m, 1e-9)
        else:
            load = lax.psum(out.metrics["load"], data_axes)
            mean_load = (n_global * k) / m
        mets = {
            "load": load,
            "max_vio": jnp.max(load) / mean_load - 1.0,
            "dropped_frac_cap1": lax.pmean(
                out.metrics["dropped_frac_cap1"], data_axes
            ),
        }
        aux = lax.pmean(out.aux_loss, data_axes)
        return y_tok, new_state, aux, mets

    in_specs = [
        P(data_axes, None),
        P(None, None),
        wf_spec,
        wf_spec,
        wd_spec,
        _state_specs(router_state),
    ]
    args = [
        x,
        params["w_router"],
        params["w_gate"],
        params["w_up"],
        params["w_down"],
        router_state,
    ]
    if token_mask is not None:
        in_specs.append(P(data_axes))
        args.append(token_mask)
    fn = _shard_map(
        block,
        mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(
            P(data_axes, None),
            _state_specs(router_state),
            P(),
            {"load": P(), "max_vio": P(), "dropped_frac_cap1": P()},
        ),
        check_vma=True,
    )
    return fn(*args)


def moe_ffn_ep(
    params: Params,
    x: jnp.ndarray,  # (n_global, d), sharded over data axes
    router_state: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    mesh,
    *,
    data_axes: Tuple[str, ...],
    model_axis: str,
    token_mask: Optional[jnp.ndarray] = None,  # (n_global,) bool
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray], jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Expert-parallel path under shard_map (see module docstring)."""
    m = cfg.routing.n_experts
    k = cfg.routing.top_k
    n_global, d = x.shape
    n_data_shards = int(np.prod([mesh.shape[a] for a in data_axes]))
    if n_global % n_data_shards != 0 or n_global < n_data_shards:
        # tiny token counts (single-request decode): replicate tokens over
        # the data axes instead of sharding them.
        data_axes = ()
        n_data_shards = 1
    ep = mesh.shape[model_axis]
    assert m % ep == 0, (m, ep)
    m_loc = m // ep
    n_loc = n_global // n_data_shards
    cap = expert_capacity(n_loc, cfg)
    rcfg = router_config(cfg, data_axes=data_axes if cfg.routing.sync == "global" else ())

    def block(x_loc, w_router, w_gate, w_up, w_down, q_state, *mask_args):
        # x_loc: (n_loc, d); w_gate: (m_loc, d, f); q_state: {'q': (m,)}
        rank = lax.axis_index(model_axis)
        mask_loc = mask_args[0] if mask_args else None
        logits = jnp.einsum("nd,dm->nm", x_loc.astype(jnp.float32), w_router)
        out = route(logits, q_state, rcfg, token_mask=mask_loc)
        plan = make_dispatch_plan(out.expert_index, m, cap, mask_loc)

        # pack only the slots routed to THIS rank's experts (pure gather)
        buf = plan.pack(x_loc, expert_offset=rank * m_loc, n_local=m_loc)

        y = _expert_ffn(w_gate, w_up, w_down, buf, cfg)

        y_tok = plan.combine(y, out.combine_weights, expert_offset=rank * m_loc)
        # combine across expert-owners (rides the TP all-reduce)
        y_tok = lax.psum(y_tok, model_axis)

        # router state: sync='global' duals already converged identically on
        # every shard (psum'd order statistics inside route, vma-replicated);
        # sync='local' averages the per-shard carried leaves (q warm start,
        # lpr prototypes) into the replicated state — keys outside
        # local_avg_keys (forecaster EMAs) are untouched by the local path
        if data_axes and cfg.routing.sync != "global":
            new_state = dict(out.state)
            for key in get_balancer(cfg.routing.strategy).local_avg_keys:
                new_state[key] = lax.pmean(out.state[key], data_axes)
        else:
            new_state = out.state
        # global balance metrics: sum local loads over data shards
        load = plan.counts if mask_loc is not None else out.metrics["load"]
        n_real = (
            jnp.sum(mask_loc.astype(jnp.int32)) if mask_loc is not None else None
        )
        dropped = out.metrics["dropped_frac_cap1"]
        aux = out.aux_loss
        if data_axes:
            load = lax.psum(load, data_axes)
            dropped = lax.pmean(dropped, data_axes)
            aux = lax.pmean(aux, data_axes)
            if n_real is not None:
                n_real = lax.psum(n_real, data_axes)
        if n_real is not None:
            mean_load = jnp.maximum(n_real * k / m, 1e-9)
        else:
            mean_load = (n_global * k) / m
        mets = {
            "load": load,
            "max_vio": jnp.max(load) / mean_load - 1.0,
            "dropped_frac_cap1": dropped,
        }
        return y_tok, new_state, aux, mets

    in_specs = [
        P(data_axes if data_axes else None, None),  # x
        P(None, None),  # w_router (replicated)
        P(model_axis, None, None),  # w_gate
        P(model_axis, None, None),  # w_up
        P(model_axis, None, None),  # w_down
        _state_specs(router_state),  # router state replicated
    ]
    args = [
        x,
        params["w_router"],
        params["w_gate"],
        params["w_up"],
        params["w_down"],
        router_state,
    ]
    if token_mask is not None:
        in_specs.append(P(data_axes if data_axes else None))
        args.append(token_mask)
    f = _shard_map(
        block,
        mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(
            P(data_axes if data_axes else None, None),
            _state_specs(router_state),
            P(),
            {"load": P(), "max_vio": P(), "dropped_frac_cap1": P()},
        ),
        check_vma=True,
    )
    return f(*args)
