"""repro.models — composable model zoo (dense / moe / ssm / hybrid / encdec / vlm)."""
from repro.models.model import Model, build_model
from repro.models.stack import MeshCtx

__all__ = ["Model", "MeshCtx", "build_model"]
