"""repro.distributed — mesh-layout rules for params, optimizer, batch, caches."""
from repro.distributed.sharding import (
    batch_specs,
    cache_specs,
    make_mesh_ctx,
    param_specs,
    router_state_specs,
    shard_tree,
    train_state_specs,
)

__all__ = [
    "batch_specs",
    "cache_specs",
    "make_mesh_ctx",
    "param_specs",
    "router_state_specs",
    "shard_tree",
    "train_state_specs",
]
