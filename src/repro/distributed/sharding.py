"""Sharding rules: map every tensor in the system to a PartitionSpec.

Layout (DESIGN.md §6), mesh axes ('pod',) 'data', 'model':

* activations/batch: tokens over (pod, data); d_model replicated.
* tensor parallelism over 'model': attention heads, FFN hidden, MoE expert
  dim, mamba inner dim, vocab (embed/unembed).
* FSDP over 'data': every parameter additionally shards its largest
  non-model axis over (pod, data) — required: none of the large configs fit
  params+optimizer replicated over the data axis (e.g. deepseek-33b fp32
  Adam = 528 GB). GSPMD inserts the just-in-time all-gathers (ZeRO-3
  semantics); their cost shows up in the collective roofline term and is a
  §Perf hillclimb axis.
* optimizer state: same spec as its parameter.
* router state q: replicated (it is the per-layer dual price vector).
* KV caches: batch over (pod, data) when it divides; the cache length axis
  over 'model' when kv_heads doesn't divide the model axis, else kv_heads
  over 'model'. long_500k (batch=1) shards the cache length over every axis.

Rules are resolved per-tensor from (path, shape) with divisibility checks —
anything that doesn't divide cleanly falls back to replication on that axis
rather than relying on GSPMD padding.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.stack import MeshCtx


def make_mesh_ctx(mesh: Optional[Mesh]) -> MeshCtx:
    if mesh is None:
        return MeshCtx()
    axes = mesh.axis_names
    data_axes = tuple(a for a in axes if a in ("pod", "data"))
    return MeshCtx(mesh=mesh, data_axes=data_axes, model_axis="model")


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


# --------------------------------------------------------------- params


_MODEL_AXIS_BY_NAME = {
    # tensor-parallel axis index per parameter name (after the stack dim)
    "wq": 1,       # (d, H, hd) -> heads
    "wk": 1,
    "wv": 1,
    "wo": 0,       # (H, hd, d) -> heads
    "w_gate": -1,  # (d, f) / (m, d, f): last axis = hidden f
    "w_up": -1,
    "w_down": -2,  # (f, d) / (m, f, d): f
    "in_proj": 1,  # mamba (d, d_in_proj)
    "out_proj": 0, # mamba (d_inner, d)
    "conv_w": 1,   # (K, conv_dim)
    "conv_b": 0,
    "norm_scale": 0,  # (d_inner,)
    "tok": 0,      # (V, d) -> vocab
    "unembed": 1,  # (d, V)
}
_MOE_EXPERT_PARAMS = {"w_gate", "w_up", "w_down"}
_REPLICATED = {"scale", "A_log", "D", "dt_bias", "w_router", "frontend_proj"}


def _param_spec(path: Tuple[str, ...], shape: Tuple[int, ...], mesh: Mesh,
                data_axes: Tuple[str, ...], stacked: bool) -> P:
    name = path[-1]
    spec = [None] * len(shape)
    ndim_offset = 1 if stacked else 0  # leading scan-stack axis stays unsharded

    moe_ctx = any(p in ("moe",) for p in path)
    if name in _REPLICATED and not (moe_ctx and name == "w_router"):
        pass  # fully replicated (tiny)
    elif name == "frontend_proj" or name == "w_router":
        pass
    elif moe_ctx and name in _MOE_EXPERT_PARAMS:
        # (stack, m, d, f) expert weights: experts over 'model', and the
        # expert-hidden f over the data axes — the ep2d at-rest layout
        # (weights are used exactly as stored; no gather).
        e_ax = ndim_offset
        if shape[e_ax] % mesh.shape["model"] == 0:
            spec[e_ax] = "model"
        f_ax = len(shape) - 1 if name in ("w_gate", "w_up") else len(shape) - 2
        dsize = _axis_size(mesh, data_axes)
        if data_axes and shape[f_ax] % dsize == 0 and shape[f_ax] >= dsize:
            spec[f_ax] = data_axes if len(data_axes) > 1 else data_axes[0]
    elif name in _MODEL_AXIS_BY_NAME:
        raw = _MODEL_AXIS_BY_NAME[name]
        ax = raw + ndim_offset if raw >= 0 else len(shape) + raw
        if 0 <= ax < len(shape) and shape[ax] % mesh.shape["model"] == 0:
            spec[ax] = "model"

    # FSDP: shard the largest remaining axis over the data axes. If the
    # tensor-parallel rule found no home for 'model' (e.g. 56 heads on a
    # 16-wide model axis), fold 'model' into the FSDP axis too so big
    # tensors always shard over the full chip count (ZeRO-3 over 256/512).
    data_used = any(
        sp is not None and (sp in data_axes or (isinstance(sp, tuple) and any(a in data_axes for a in sp)))
        for sp in spec
    )
    if data_axes and not data_used and np.prod(shape) >= 1 << 16:  # skip tiny tensors
        dsize = _axis_size(mesh, data_axes)
        model_used = any(sp == "model" for sp in spec)
        # fold 'model' into the FSDP axis only when the data-only shard
        # would still be big (>=128 MiB): needed for e.g. deepseek's
        # 56-head attention weights, but folding small tensors makes GSPMD
        # replicate compute around the re-partition (3.6x flops on mamba2 —
        # dry-run finding, see EXPERIMENTS.md §Perf).
        big_after_data = (np.prod(shape) * 4 / dsize) >= (1 << 27)
        fold_model = (not model_used) and big_after_data
        fsdp_axes = tuple(data_axes) + (("model",) if fold_model else ())
        fsize = _axis_size(mesh, fsdp_axes)
        candidates = [
            (shape[i], i)
            for i in range(ndim_offset, len(shape))
            if spec[i] is None and shape[i] % fsize == 0 and shape[i] >= fsize
        ]
        if not candidates and fold_model:
            # fall back to data-only FSDP when nothing divides the combo
            fsdp_axes = tuple(data_axes)
            fsize = _axis_size(mesh, fsdp_axes)
            candidates = [
                (shape[i], i)
                for i in range(ndim_offset, len(shape))
                if spec[i] is None and shape[i] % fsize == 0 and shape[i] >= fsize
            ]
        if candidates:
            _, i = max(candidates)
            spec[i] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
    return P(*spec)


def param_specs(params: Any, cfg: ModelConfig, mesh: Mesh) -> Any:
    """PartitionSpec tree matching the params tree."""
    data_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        keys = tuple(
            p.key if hasattr(p, "key") else str(p.idx) for p in path
        )
        names = tuple(k for k in keys if not k.isdigit())
        # scan-stacked layer params carry a leading group axis
        stacked = "blocks" in keys or "layers" in keys
        specs.append(_param_spec(names, leaf.shape, mesh, data_axes, stacked))
    return jax.tree_util.tree_unflatten(treedef, specs)


# ------------------------------------------------------- everything else


def batch_specs(cfg: ModelConfig, mesh: Mesh, batch_size: int) -> Dict[str, P]:
    data_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dsize = _axis_size(mesh, data_axes)
    bspec = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)
    if batch_size % dsize != 0 or batch_size < dsize:
        bspec = None  # tiny batches (long_500k) stay replicated
    out = {"tokens": P(bspec, None), "labels": P(bspec, None), "segments": P(bspec, None)}
    if cfg.family == "vlm":
        out["patches"] = P(bspec, None, None)
    if cfg.family == "encdec":
        out["frames"] = P(bspec, None, None)
    return out


def router_state_specs(router_states: Any) -> Any:
    return jax.tree.map(lambda _: P(), router_states)


def train_state_specs(state, cfg: ModelConfig, mesh: Mesh):
    """Specs for TrainState(params, opt_state{step,mu,nu}, router_states)."""
    from repro.training.loop import TrainState

    pspec = param_specs(state.params, cfg, mesh)
    return TrainState(
        params=pspec,
        opt_state={
            "step": P(),
            "mu": pspec,
            "nu": pspec,
        },
        router_states=router_state_specs(state.router_states),
    )


def cache_specs(cache: Any, cfg: ModelConfig, mesh: Mesh, batch_size: int) -> Any:
    """Decode-cache specs. Leaves are stacked (G, B, ...) per scan group."""
    data_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dsize = _axis_size(mesh, data_axes)
    msize = mesh.shape["model"]
    bspec = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)
    batch_ok = batch_size % dsize == 0 and batch_size >= dsize

    def leaf_spec(path, leaf):
        keys = tuple(
            p.key if hasattr(p, "key") else str(p.idx) for p in path
        )
        name = keys[-1]
        shape = leaf.shape
        spec = [None] * len(shape)
        if len(shape) >= 2:
            if batch_ok:
                spec[1] = bspec  # (G, B, ...)
        if name in ("k", "v", "sk", "sv", "ck", "cv"):
            # (G, B, C, KV, hd). Never shard C when the batch is sharded:
            # the per-step dynamic-update-slice at a dynamic position on a
            # sharded axis makes GSPMD gather the whole cache (dry-run
            # finding). kv-heads over model when divisible, else head_dim
            # (attention einsums contract hd -> one small psum per step).
            if shape[3] % msize == 0:
                spec[3] = "model"
            elif len(shape) > 4 and shape[4] % msize == 0:
                spec[4] = "model"
            if not batch_ok and shape[2] % dsize == 0:
                # long-context single-request: length must shard somewhere;
                # the per-write gather transient is C_bytes/dsize — fine
                spec[2] = bspec
        elif name == "ssm":
            # (G, B, H, N, P): heads over model if divisible, else state N
            if shape[2] % msize == 0:
                spec[2] = "model"
            elif shape[3] % msize == 0:
                spec[3] = "model"
        elif name == "conv":
            # (G, B, K-1, conv_dim)
            if shape[3] % msize == 0:
                spec[3] = "model"
        elif name in ("pos", "spos"):
            pass
        return P(*spec)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        treedef, [leaf_spec(p, l) for p, l in flat]
    )


def shard_tree(tree, specs, mesh: Mesh):
    """Attach NamedShardings: works on concrete arrays and ShapeDtypeStructs."""

    def attach(x, s):
        sh = NamedSharding(mesh, s)
        if isinstance(x, jax.ShapeDtypeStruct):
            return jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=sh)
        return jax.device_put(x, sh)

    return jax.tree.map(attach, tree, specs)
