"""Training launcher.

Local (CPU / small mesh):
    PYTHONPATH=src python -m repro.launch.train --arch minimind-moe-16e \
        --steps 200 --batch 8 --seq-len 128 [--method bip|lossfree|aux_loss] \
        [--mesh 4x2] [--micro 2] [--ckpt-dir ck --ckpt-every 50 --resume]

Production (TPU pod; one process per host, standard jax.distributed):
    python -m repro.launch.train --arch llama4-scout-17b-a16e --production \
        --coordinator $COORD --num-hosts $N --host-id $ID

Both mesh paths (--production's 16x16 / 2x16x16 pod mesh and --mesh's DxM
host mesh over local devices) feed the SAME sharded train step: explicit
in/out shardings from repro.distributed.sharding, donated TrainState,
microbatch gradient accumulation (see repro.training.loop).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--method", default=None, choices=[None, "bip", "lossfree", "aux_loss", "topk"])
    ap.add_argument("--bip-iters", type=int, default=None)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--micro", type=int, default=1,
                    help="microbatches per step (gradient accumulation)")
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (smoke-scale) variant of --arch")
    ap.add_argument("--bf16", action="store_true",
                    help="bf16 compute (master params/moments stay fp32)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="save the full TrainState every N steps (0 = only final)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest checkpoint in --ckpt-dir and continue")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--out-json", default=None,
                    help="write the run summary to this JSON file")
    # mesh flags
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="host mesh over local devices, e.g. 4x2 = 4-way data x 2-way model")
    ap.add_argument("--production", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--coordinator", default=None)
    ap.add_argument("--num-hosts", type=int, default=1)
    ap.add_argument("--host-id", type=int, default=0)
    args = ap.parse_args(argv)
    if args.resume and not args.ckpt_dir:
        ap.error("--resume requires --ckpt-dir")

    if args.production and args.coordinator:
        import jax

        jax.distributed.initialize(
            coordinator_address=args.coordinator,
            num_processes=args.num_hosts,
            process_id=args.host_id,
        )

    from repro import configs
    from repro.data import make_batches
    from repro.models import build_model
    from repro.training import train_loop
    from repro.training.loop import evaluate_ppl

    cfg = configs.reduced_for_smoke(args.arch) if args.reduced else configs.get(args.arch)
    if args.method or args.bip_iters:
        routing = dataclasses.replace(
            cfg.routing,
            strategy=args.method or cfg.routing.strategy,
            bip_iters=args.bip_iters or cfg.routing.bip_iters,
        )
        cfg = dataclasses.replace(cfg, routing=routing)
    if args.bf16:
        import jax.numpy as jnp

        cfg = dataclasses.replace(cfg, compute_dtype=jnp.bfloat16)

    mesh = None
    if args.production:
        from repro.distributed import make_mesh_ctx
        from repro.launch.mesh import make_production_mesh

        mesh = make_production_mesh(multi_pod=args.multi_pod)
        model = build_model(cfg, make_mesh_ctx(mesh))
    elif args.mesh:
        from repro.distributed import make_mesh_ctx
        from repro.launch.mesh import make_host_mesh

        data, model_par = (int(v) for v in args.mesh.lower().split("x"))
        mesh = make_host_mesh(data, model_par)
        model = build_model(cfg, make_mesh_ctx(mesh))
    else:
        model = build_model(cfg)

    print(
        f"training {cfg.name} [{cfg.family}]"
        f" method={cfg.routing.strategy if cfg.is_moe else 'n/a'}"
        f" mesh={dict(mesh.shape) if mesh is not None else None}"
        f" micro={args.micro}"
    )
    batches = make_batches(cfg, args.batch, args.seq_len, args.steps)
    state, log = train_loop(
        model,
        batches,
        lr=args.lr,
        total_steps=args.steps,
        log_every=args.log_every,
        mesh=mesh,
        microbatches=args.micro,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every or (args.steps if args.ckpt_dir else 0),
        resume=args.resume,
    )
    test = make_batches(cfg, args.batch, args.seq_len, 4, split="test")
    ppl = evaluate_ppl(model, state, test)
    summary = {
        "arch": cfg.name,
        "method": cfg.routing.strategy if cfg.is_moe else None,
        "mesh": dict(mesh.shape) if mesh is not None else None,
        "microbatches": args.micro,
        **log.summary(),
        "test_ppl": ppl,
    }
    print(json.dumps(summary, indent=1, default=float))
    if args.out_json:
        with open(args.out_json, "w") as f:
            json.dump(summary, f, indent=1, default=float)

    if args.ckpt_dir:
        print(f"checkpoint -> {args.ckpt_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
