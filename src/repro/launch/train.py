"""Training launcher.

Local (CPU / small mesh):
    PYTHONPATH=src python -m repro.launch.train --arch minimind-moe-16e \
        --steps 200 --batch 8 --seq-len 128 [--method bip|lossfree|aux_loss]

Production (TPU pod; one process per host, standard jax.distributed):
    python -m repro.launch.train --arch llama4-scout-17b-a16e --production \
        --coordinator $COORD --num-hosts $N --host-id $ID

The production path builds the 16x16 (or 2x16x16 with --multi-pod) mesh and
the same sharded train step the dry-run compiles; on this CPU container it
is exercised via repro.launch.dryrun instead.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--method", default=None, choices=[None, "bip", "lossfree", "aux_loss", "topk"])
    ap.add_argument("--bip-iters", type=int, default=None)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (smoke-scale) variant of --arch")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    # production flags
    ap.add_argument("--production", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--coordinator", default=None)
    ap.add_argument("--num-hosts", type=int, default=1)
    ap.add_argument("--host-id", type=int, default=0)
    args = ap.parse_args(argv)

    if args.production and args.coordinator:
        import jax

        jax.distributed.initialize(
            coordinator_address=args.coordinator,
            num_processes=args.num_hosts,
            process_id=args.host_id,
        )

    from repro import configs
    from repro.data import make_batches
    from repro.models import build_model
    from repro.training import train_loop
    from repro.training.loop import evaluate_ppl

    cfg = configs.reduced_for_smoke(args.arch) if args.reduced else configs.get(args.arch)
    if args.method or args.bip_iters:
        routing = dataclasses.replace(
            cfg.routing,
            strategy=args.method or cfg.routing.strategy,
            bip_iters=args.bip_iters or cfg.routing.bip_iters,
        )
        cfg = dataclasses.replace(cfg, routing=routing)

    mesh_ctx = None
    if args.production:
        from repro.distributed import make_mesh_ctx
        from repro.launch.mesh import make_production_mesh

        mesh = make_production_mesh(multi_pod=args.multi_pod)
        mesh_ctx = make_mesh_ctx(mesh)
        model = build_model(cfg, mesh_ctx)
    else:
        model = build_model(cfg)

    print(f"training {cfg.name} [{cfg.family}] method={cfg.routing.strategy if cfg.is_moe else 'n/a'}")
    batches = make_batches(cfg, args.batch, args.seq_len, args.steps)
    state, log = train_loop(
        model, batches, lr=args.lr, total_steps=args.steps, log_every=args.log_every
    )
    test = make_batches(cfg, args.batch, args.seq_len, 4, split="test")
    ppl = evaluate_ppl(model, state, test)
    summary = {**log.summary(), "test_ppl": ppl}
    print(json.dumps(summary, indent=1, default=float))

    if args.ckpt_dir:
        from repro.checkpoint import CheckpointManager

        CheckpointManager(args.ckpt_dir).save(
            args.steps, {"params": state.params, "router": state.router_states}
        )
        print(f"checkpoint -> {args.ckpt_dir}/step_{args.steps}.npz")
    return 0


if __name__ == "__main__":
    sys.exit(main())
