"""Training launcher.

Local (CPU / small mesh):
    PYTHONPATH=src python -m repro.launch.train --arch minimind-moe-16e \
        --steps 200 --batch 8 --seq-len 128 [--method bip|lossfree|aux_loss] \
        [--mesh 4x2] [--micro 2] [--ckpt-dir ck --ckpt-every 50 --resume]

Real-text corpus (streaming pipeline, DESIGN.md §Data):
    PYTHONPATH=src python -m repro.launch.train --arch minimind-moe-16e \
        --data corpus_dir_or_glob --tokenizer tok.json \
        [--pack-mode pack|pack_nocross|pad] [--shuffle-buffer 64] [--prefetch 2]

    --data points at .jsonl ({"text": ...} per line) / .txt shards. The
    tokenizer at --tokenizer is loaded if present, otherwise trained on the
    corpus to the arch's vocab size and saved there (and copied into
    --ckpt-dir so the run is reproducible from its artifacts). The loader
    shards documents over hosts (jax.process_index/count), its cursor is
    checkpointed with the TrainState, and --resume seeks it in O(1) —
    bit-exact, no prefix replay. --prefetch N (0 disables) double-buffers
    host tokenize/pack/H2D against device steps.

Production (TPU pod; one process per host, standard jax.distributed):
    python -m repro.launch.train --arch llama4-scout-17b-a16e --production \
        --coordinator $COORD --num-hosts $N --host-id $ID

Both mesh paths (--production's 16x16 / 2x16x16 pod mesh and --mesh's DxM
host mesh over local devices) feed the SAME sharded train step: explicit
in/out shardings from repro.distributed.sharding, donated TrainState,
microbatch gradient accumulation (see repro.training.loop).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys


def _build_data_stream(cfg, args, faults=None):
    """Resolve shards + tokenizer, return (BatchStream, tokenizer).

    The tokenizer is loaded from --tokenizer when the file exists, else
    trained on the corpus to cfg.vocab_size and saved there; a copy also
    lands in --ckpt-dir so checkpoints are self-describing."""
    import os
    import shutil

    import jax

    from repro.data import (
        ByteBPETokenizer,
        Prefetcher,
        ShardedTextLoader,
        resolve_shards,
        train_tokenizer_from_files,
    )

    shards = resolve_shards(args.data)
    tok_path = args.tokenizer or (
        os.path.join(args.ckpt_dir, "tokenizer.json") if args.ckpt_dir else None
    )
    if tok_path and os.path.exists(tok_path):
        tokenizer = ByteBPETokenizer.load(tok_path)
        print(f"tokenizer <- {tok_path} (vocab {tokenizer.vocab_size})")
    else:
        tokenizer = train_tokenizer_from_files(shards, vocab_size=cfg.vocab_size)
        print(
            f"tokenizer trained on {len(shards)} shard(s): "
            f"{len(tokenizer.merges)} merges, vocab {tokenizer.vocab_size}"
        )
        if tok_path:
            tokenizer.save(tok_path)
            print(f"tokenizer -> {tok_path}")
    assert tokenizer.vocab_size <= cfg.vocab_size, (
        f"tokenizer vocab {tokenizer.vocab_size} exceeds model vocab {cfg.vocab_size}"
    )
    if args.ckpt_dir and tok_path != os.path.join(args.ckpt_dir, "tokenizer.json"):
        os.makedirs(args.ckpt_dir, exist_ok=True)
        if tok_path:
            shutil.copy(tok_path, os.path.join(args.ckpt_dir, "tokenizer.json"))
        else:
            tokenizer.save(os.path.join(args.ckpt_dir, "tokenizer.json"))

    stream = ShardedTextLoader(
        shards,
        tokenizer,
        batch_size=args.batch,
        seq_len=args.seq_len,
        pack_mode=args.pack_mode,
        rank=jax.process_index(),
        world_size=jax.process_count(),
        shuffle_buffer=args.shuffle_buffer,
        seed=args.data_seed,
        io_retries=args.io_retries,
        open_fn=faults.open_fn() if faults is not None else None,
    )
    if faults is not None:
        stream = faults.wrap_stream(stream)  # flaky_stream / stall_prefetch
    if args.prefetch > 0:
        # one retry per injected/transient stream crash, plus headroom
        stream = Prefetcher(stream, depth=args.prefetch, retries=args.io_retries)
    return stream, tokenizer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--strategy", "--method", dest="strategy", default=None,
                    help="routing strategy override; any name in the "
                         "balancer registry (repro.core.registered_balancers; "
                         "--method is the legacy alias)")
    ap.add_argument("--bip-iters", type=int, default=None)
    ap.add_argument("--sync", default=None, choices=["local", "global"],
                    help="BIP dual sync across data shards on a mesh: 'local' "
                         "solves per-shard duals and averages the warm start, "
                         "'global' psums the dual order statistics so every "
                         "device holds the single-device duals (DESIGN.md "
                         "§Global-sync). Without --mesh/--production, "
                         "'global' still switches the single-device dual "
                         "solver to the threshold/bisection form (the mesh "
                         "reference numerics, bypassing use_kernel)")
    ap.add_argument("--n-bisect", type=int, default=None,
                    help="bits of bisection resolution for the sync='global' "
                         "dual order statistic (default 26)")
    ap.add_argument("--bisect-fanout", type=int, default=None,
                    help="thresholds probed per fused bisection round; one "
                         "collective per round shrinks the bracket "
                         "(fanout+1)x (default 32 -> 6 rounds)")
    ap.add_argument("--forecast", action="store_true",
                    help="carry the dual forecaster (EMA of the order "
                         "statistic) in router state and warm-start each "
                         "bisection with its predicted bracket")
    ap.add_argument("--forecast-decay", type=float, default=None)
    ap.add_argument("--forecast-margin", type=float, default=None)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--micro", type=int, default=1,
                    help="microbatches per step (gradient accumulation)")
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (smoke-scale) variant of --arch")
    ap.add_argument("--bf16", action="store_true",
                    help="bf16 compute (master params/moments stay fp32)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="save the full TrainState every N steps (0 = only final)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest checkpoint in --ckpt-dir and continue")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--out-json", default=None,
                    help="write the run summary to this JSON file")
    # telemetry flags (DESIGN.md §Observability)
    ap.add_argument("--telemetry", default=None, metavar="PATH",
                    help="stream per-step metric records (per-layer expert "
                         "load histograms, MaxVio, dual health, guard "
                         "events) to this .jsonl/.csv file; summarize with "
                         "`python -m repro.telemetry.metrics_report PATH`")
    ap.add_argument("--flush-every", type=int, default=10,
                    help="telemetry ring-buffer window: steps buffered on "
                         "device between asynchronous host drains")
    ap.add_argument("--profile", default=None, metavar="N:M",
                    help="capture a jax.profiler trace of train steps "
                         "[N, M] into ./profile (view with TensorBoard)")
    # real-text data pipeline flags
    ap.add_argument("--data", default=None,
                    help="corpus dir / glob / file of .jsonl|.txt shards "
                         "(default: synthetic stream)")
    ap.add_argument("--tokenizer", default=None,
                    help="tokenizer JSON path; trained on --data and saved "
                         "here if missing (default: <ckpt-dir>/tokenizer.json)")
    ap.add_argument("--pack-mode", default="pack",
                    choices=["pack", "pack_nocross", "pad"],
                    help="document packing: 'pack' = EOS-joined stream, "
                         "'pack_nocross' adds within-document attention/loss "
                         "masking, 'pad' = one document per sequence")
    ap.add_argument("--shuffle-buffer", type=int, default=64,
                    help="documents held in the loader's shuffle buffer")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="prefetch queue depth (0 = tokenize/pack inline)")
    ap.add_argument("--data-seed", type=int, default=0,
                    help="loader shuffle seed")
    # robustness flags (DESIGN.md §Robustness)
    ap.add_argument("--guard", default=None, choices=["skip", "rollback", "raise"],
                    help="anomaly policy for non-finite loss/grads: 'skip' "
                         "keeps the pre-step state (escalating to LR drops "
                         "and rollback if persistent), 'rollback' restores "
                         "the newest valid checkpoint and replays, 'raise' "
                         "fails fast")
    ap.add_argument("--spike-factor", type=float, default=0.0,
                    help="loss-spike anomaly threshold as a multiple of the "
                         "recent median (0 disables; implies --guard skip "
                         "when no policy is given)")
    ap.add_argument("--spike-window", type=int, default=8,
                    help="finite losses in the spike reference window")
    ap.add_argument("--guard-duals", action="store_true",
                    help="router dual-health watchdog: reset a layer's "
                         "carried q / forecaster EMAs to safe init when "
                         "non-finite or runaway")
    ap.add_argument("--inject", action="append", default=None, metavar="SPEC",
                    help="fault injection, repeatable: 'nan_grad@step=3', "
                         "'ckpt_corrupt@step=0,mode=bitflip', "
                         "'flaky_open@p=0.3,p_read=0.1', 'flaky_stream@at=2'; "
                         "see repro.robustness.faults")
    ap.add_argument("--io-retries", type=int, default=3,
                    help="consecutive shard open/read failures retried with "
                         "backoff before the loader raises")
    # mesh flags
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="host mesh over local devices, e.g. 4x2 = 4-way data x 2-way model")
    ap.add_argument("--production", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--coordinator", default=None)
    ap.add_argument("--num-hosts", type=int, default=1)
    ap.add_argument("--host-id", type=int, default=0)
    args = ap.parse_args(argv)
    if args.resume and not args.ckpt_dir:
        ap.error("--resume requires --ckpt-dir")

    if args.production and args.coordinator:
        import jax

        jax.distributed.initialize(
            coordinator_address=args.coordinator,
            num_processes=args.num_hosts,
            process_id=args.host_id,
        )

    from repro import configs
    from repro.data import make_batches
    from repro.data.synthetic import SyntheticBatchStream
    from repro.models import build_model
    from repro.training import train_loop
    from repro.training.loop import evaluate_ppl

    if args.strategy is not None:
        # resolve through the balancer registry so unknown names fail here
        # with the registered list, not deep inside config construction
        from repro.core import get_balancer

        try:
            get_balancer(args.strategy)
        except ValueError as e:
            ap.error(str(e))

    cfg = configs.reduced_for_smoke(args.arch) if args.reduced else configs.get(args.arch)
    if (
        args.strategy or args.bip_iters or args.sync or args.n_bisect
        or args.bisect_fanout or args.forecast or args.guard_duals
        or args.forecast_decay is not None or args.forecast_margin is not None
    ):
        routing = dataclasses.replace(
            cfg.routing,
            strategy=args.strategy or cfg.routing.strategy,
            bip_iters=args.bip_iters or cfg.routing.bip_iters,
            sync=args.sync or cfg.routing.sync,
            n_bisect=args.n_bisect or cfg.routing.n_bisect,
            bisect_fanout=args.bisect_fanout or cfg.routing.bisect_fanout,
            forecast=args.forecast or cfg.routing.forecast,
            forecast_decay=(
                cfg.routing.forecast_decay
                if args.forecast_decay is None else args.forecast_decay
            ),
            forecast_margin=(
                cfg.routing.forecast_margin
                if args.forecast_margin is None else args.forecast_margin
            ),
            guard_duals=args.guard_duals or cfg.routing.guard_duals,
        )
        cfg = dataclasses.replace(cfg, routing=routing)
    if args.bf16:
        import jax.numpy as jnp

        cfg = dataclasses.replace(cfg, compute_dtype=jnp.bfloat16)

    mesh = None
    if args.production:
        from repro.distributed import make_mesh_ctx
        from repro.launch.mesh import make_production_mesh

        mesh = make_production_mesh(multi_pod=args.multi_pod)
        model = build_model(cfg, make_mesh_ctx(mesh))
    elif args.mesh:
        from repro.distributed import make_mesh_ctx
        from repro.launch.mesh import make_host_mesh

        data, model_par = (int(v) for v in args.mesh.lower().split("x"))
        mesh = make_host_mesh(data, model_par)
        model = build_model(cfg, make_mesh_ctx(mesh))
    else:
        model = build_model(cfg)

    print(
        f"training {cfg.name} [{cfg.family}]"
        f" method={cfg.routing.strategy if cfg.is_moe else 'n/a'}"
        f" sync={cfg.routing.sync if cfg.is_moe else 'n/a'}"
        f" mesh={dict(mesh.shape) if mesh is not None else None}"
        f" micro={args.micro}"
        f" data={args.data or 'synthetic'}"
    )
    faults = None
    if args.inject:
        from repro.robustness import FaultPlan

        faults = FaultPlan.from_specs(args.inject)
        print("injecting: " + "; ".join(f.describe() for f in faults.faults))
    guard = None
    if args.guard or args.spike_factor:
        from repro.robustness import GuardConfig

        guard = GuardConfig(
            policy=args.guard or "skip",
            spike_factor=args.spike_factor,
            spike_window=args.spike_window,
        )
    if args.data:
        batches, tokenizer = _build_data_stream(cfg, args, faults)
    else:
        batches = SyntheticBatchStream(cfg, args.batch, args.seq_len, args.steps)
        if faults is not None:
            batches = faults.wrap_stream(batches)
    telemetry = sink = None
    if args.telemetry or args.profile:
        from repro.telemetry import (
            Profiler,
            TrainTelemetry,
            open_sink,
            profile_window,
        )

        sink = open_sink(args.telemetry)
        telemetry = TrainTelemetry(
            sink=sink,
            flush_every=args.flush_every,
            run_meta={
                "arch": cfg.name,
                "strategy": cfg.routing.strategy if cfg.is_moe else None,
                "sync": cfg.routing.sync if cfg.is_moe else None,
                "steps": args.steps,
                "flush_every": args.flush_every,
            },
            profiler=(
                Profiler(profile_window(args.profile)) if args.profile else None
            ),
        )
    try:
        state, log = train_loop(
            model,
            batches,
            lr=args.lr,
            total_steps=args.steps,
            log_every=args.log_every,
            mesh=mesh,
            microbatches=args.micro,
            ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every or (args.steps if args.ckpt_dir else 0),
            resume=args.resume,
            guard=guard,
            faults=faults,
            telemetry=telemetry,
        )
    finally:
        if sink is not None:
            sink.close()
            print(f"telemetry -> {args.telemetry}")
    if args.data:
        # in-sample by construction: same shards as training (only the
        # shuffle seed differs) — reported as train_corpus_ppl, not test_ppl
        import itertools

        from repro.data import ShardedTextLoader, resolve_shards

        test = itertools.islice(
            ShardedTextLoader(
                resolve_shards(args.data), tokenizer,
                batch_size=args.batch, seq_len=args.seq_len,
                pack_mode=args.pack_mode, seed=args.data_seed + 1, epochs=1,
            ),
            4,
        )
    else:
        test = make_batches(cfg, args.batch, args.seq_len, 4, split="test")
    ppl = evaluate_ppl(model, state, test)
    summary = {
        "arch": cfg.name,
        "method": cfg.routing.strategy if cfg.is_moe else None,
        "sync": cfg.routing.sync if cfg.is_moe else None,
        "mesh": dict(mesh.shape) if mesh is not None else None,
        "microbatches": args.micro,
        "data": args.data,
        "pack_mode": args.pack_mode if args.data else None,
        **log.summary(),
        # a real --data corpus has no held-out split here: the eval pass
        # re-reads the training shards, so label it honestly
        ("train_corpus_ppl" if args.data else "test_ppl"): ppl,
    }
    print(json.dumps(summary, indent=1, default=float))
    if args.out_json:
        with open(args.out_json, "w") as f:
            json.dump(summary, f, indent=1, default=float)

    if args.ckpt_dir:
        print(f"checkpoint -> {args.ckpt_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
