"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

Proves the distribution config is coherent without TPU hardware: jax builds
the 256-chip (single-pod) and 512-chip (multi-pod) meshes from placeholder
host devices, GSPMD partitions the full train/prefill/decode programs, and
the compiled artifact yields memory_analysis() (fits/doesn't fit) and
cost_analysis() (FLOPs/bytes for the roofline).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm-1.6b \
        --shape train_4k [--multi-pod] [--micro N] [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
# The placeholder-device flag MUST precede any jax initialization — jax locks
# the device count on first init. Do NOT set this in conftest/pyproject.
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import dataclasses
import json
import re
import sys
import time
from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.data.synthetic import INPUT_SHAPES, InputShape, input_specs
from repro.distributed import (
    batch_specs,
    cache_specs,
    make_mesh_ctx,
    param_specs,
    router_state_specs,
)
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.optim import adamw as _adamw
from repro.optim.schedules import constant
from repro.training.loop import compile_train_step, init_train_state

# -------------------------------------------------------- applicability

# long_500k needs sub-quadratic attention / bounded state (DESIGN.md §Skips)
LONG_CONTEXT_ARCHS = {"mamba2_130m", "zamba2_7b", "llama4_scout_17b_a16e", "gemma2_27b"}


def shape_applicable(arch: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True


def valid_pairs():
    for arch in configs.ARCH_IDS[:10]:  # the 10 assigned archs
        for shape_name in INPUT_SHAPES:
            if shape_applicable(arch, shape_name):
                yield arch, shape_name


# ------------------------------------------------------------- programs


def _sds(tree):
    """eval_shape on a thunk returning the tree (no allocation)."""
    return jax.eval_shape(lambda: tree) if not callable(tree) else jax.eval_shape(tree)


def _attach(tree, specs, mesh):
    return jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=NamedSharding(mesh, s)),
        tree,
        specs,
    )


# ------------------------------------------------------------ dry runs


def lower_one(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    microbatches: Optional[int] = None,
    mesh=None,
    extra_cfg: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    """Lower + compile one (arch, shape, mesh). Returns analysis record."""
    cfg = configs.get(arch)
    shape = INPUT_SHAPES[shape_name]
    cfg = dataclasses.replace(cfg, remat="block", **(extra_cfg or {}))
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_ctx = make_mesh_ctx(mesh)
    model = build_model(cfg, mesh_ctx)
    opt_cfg = _adamw.from_model_config(cfg)

    n_chips = int(np.prod(list(mesh.shape.values())))
    if microbatches is None:
        microbatches = 1
        if shape.kind == "train":
            # size microbatches so the remat residual stack fits comfortably:
            # residuals/device = tokens_dev_micro * d_model * 2B * n_layers
            data_sh = n_chips // mesh.shape["model"]
            seq_total = shape.seq_len + cfg.enc_seq_len  # encdec: enc tokens too
            tokens_dev = seq_total * shape.global_batch // data_sh
            # encdec pays cross-attention + encoder transients per microbatch
            budget = (1 if cfg.n_enc_layers else 2) * 2**30
            per_tok = cfg.d_model * 2 * max(cfg.n_layers + cfg.n_enc_layers, 1)
            want = max(1, int(np.ceil(tokens_dev * per_tok / budget)))
            seqs_dev = max(shape.global_batch // data_sh, 1)
            # round up to a divisor of the per-device sequence count
            microbatches = next(
                m for m in range(want, seqs_dev + 1) if seqs_dev % m == 0
            ) if want <= seqs_dev else seqs_dev

    t0 = time.time()
    specs_in = input_specs(cfg, shape)

    with mesh:
        if shape.kind == "train":
            state_sds = jax.eval_shape(
                lambda: init_train_state(model, jax.random.PRNGKey(0), opt_cfg)
            )
            # the production harness step: one implementation, dry-run and
            # real training compile the same sharded/donated program
            fn = compile_train_step(
                model, opt_cfg, constant(3e-4), state_sds, specs_in,
                mesh=mesh, microbatches=microbatches,
            )
            lowered = fn.lower(state_sds, specs_in)
        elif shape.kind == "prefill":
            params_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
            router_sds = jax.eval_shape(model.init_router_states)
            p_specs = param_specs(params_sds, cfg, mesh)
            b_specs = batch_specs(cfg, mesh, shape.global_batch)
            b_specs = {k: b_specs[k] for k in specs_in}

            def prefill(params, batch, router):
                logits, new_states, mets = model.prefill(
                    params, batch, router, shape.seq_len
                )
                return logits, new_states

            fn = jax.jit(
                prefill,
                in_shardings=(
                    jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs),
                    {k: NamedSharding(mesh, v) for k, v in b_specs.items()},
                    jax.tree.map(
                        lambda s: NamedSharding(mesh, s),
                        router_state_specs(router_sds),
                    ),
                ),
            )
            lowered = fn.lower(params_sds, specs_in, router_sds)
        else:  # decode
            params_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
            router_sds = jax.eval_shape(model.init_router_states)
            p_specs = param_specs(params_sds, cfg, mesh)
            cache_batch = dict(specs_in)
            cache_sds = jax.eval_shape(
                lambda p, b: model.init_cache(p, b, shape.seq_len),
                params_sds,
                cache_batch,
            )
            c_specs = cache_specs(cache_sds, cfg, mesh, shape.global_batch)
            b_sp = batch_specs(cfg, mesh, shape.global_batch)["tokens"]

            def decode(params, tokens, cache, router):
                return model.decode_step(params, tokens, cache, router)

            fn = jax.jit(
                decode,
                in_shardings=(
                    jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs),
                    NamedSharding(mesh, b_sp),
                    jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs),
                    jax.tree.map(
                        lambda s: NamedSharding(mesh, s),
                        router_state_specs(router_sds),
                    ),
                ),
                out_shardings=(
                    None,
                    jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs),
                    jax.tree.map(
                        lambda s: NamedSharding(mesh, s),
                        router_state_specs(router_sds),
                    ),
                ),
                donate_argnums=(2,),
            )
            lowered = fn.lower(
                params_sds, specs_in["tokens"], cache_sds, router_sds
            )

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    # Loop-aware per-device costs (XLA's cost_analysis counts while bodies
    # once — see repro.launch.hlo_cost).
    from repro.launch.hlo_cost import (
        analyze_compiled,
        cpu_bf16_upcast_bytes,
        cpu_bf16_upcast_carried_bytes,
        xla_cost_analysis,
    )

    xla_cost = xla_cost_analysis(compiled)

    t0 = time.time()
    hlo_txt = compiled.as_text()
    cost = analyze_compiled(compiled)
    upcast = cpu_bf16_upcast_bytes(hlo_txt) + cpu_bf16_upcast_carried_bytes(hlo_txt)
    t_analyze = time.time() - t0
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "microbatches": microbatches,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "analyze_s": round(t_analyze, 1),
        "flops": cost.flops,
        "traffic_bytes": cost.traffic,
        "collective_bytes": {**cost.collectives, "total": cost.collective_total},
        "xla_flops_looponce": xla_cost.get("flops", float("nan")),
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "peak_bytes": (
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)
        ),
        # CPU-backend artifact: f32 copies inserted to legalize bf16 dots
        # (hoisted whole-stack converts). TPU executes bf16 dots natively;
        # peak_bytes_tpu removes them (see hlo_cost.cpu_bf16_upcast_bytes).
        "cpu_upcast_bytes": upcast,
        # clamped below by argument bytes: the upcast detector can overlap
        # with buffers XLA aliased away
        "peak_bytes_tpu": max(
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)
            - upcast,
            getattr(mem, "argument_size_in_bytes", 0),
        ),
    }
    return rec


# ----------------------------------------------------------------- main


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default="train_4k", choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--micro", type=int, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args(argv)

    results = []
    if args.all:
        pairs = list(valid_pairs())
    else:
        assert args.arch, "--arch required unless --all"
        pairs = [(args.arch, args.shape)]

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    for arch, shape_name in pairs:
        print(f"== dryrun {arch} x {shape_name} "
              f"({'2x16x16' if args.multi_pod else '16x16'}) ==", flush=True)
        try:
            rec = lower_one(
                arch, shape_name,
                multi_pod=args.multi_pod, microbatches=args.micro, mesh=mesh,
            )
            rec["status"] = "ok"
            print(json.dumps(rec), flush=True)
        except Exception as e:  # noqa: BLE001 — a failure IS the result
            rec = {
                "arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if args.multi_pod else "16x16",
                "status": f"FAIL: {type(e).__name__}: {str(e)[:400]}",
            }
            print(json.dumps(rec), flush=True)
        results.append(rec)

    if args.out:
        mode = "a" if os.path.exists(args.out) else "w"
        with open(args.out, mode) as f:
            for r in results:
                f.write(json.dumps(r) + "\n")
    n_ok = sum(1 for r in results if r.get("status") == "ok")
    print(f"\n{n_ok}/{len(results)} combinations compiled", flush=True)
    return 0 if n_ok == len(results) else 1


if __name__ == "__main__":
    sys.exit(main())
