"""repro.launch — mesh construction, dry-run compiler, train/serve drivers."""
