"""Loop-aware HLO cost model (the dry-run "profiler").

XLA's built-in compiled.cost_analysis() counts while-loop bodies ONCE —
useless for scan-over-layers / microbatch-accumulation programs where >99%
of the work sits inside loops. This module parses compiled.as_text()
(post-SPMD optimized HLO, i.e. exactly what each device executes) into a
call graph and accumulates costs with loop trip counts taken from XLA's own
`backend_config={"known_trip_count":{"n":...}}` annotations:

  flops             2·prod(out_shape)·K for every dot (K = contracted size),
                    recursively through fusions/calls/while bodies.
  traffic_bytes     HBM traffic model: Σ over *top-level* ops per executed
                    computation of (operand bytes + result bytes) for
                    fusion / dot / copy / dynamic-update-slice / gather /
                    scatter kernels — one read per input, one write per
                    output per kernel launch, the standard fusion-boundary
                    traffic model.
  collectives       result bytes per collective kind (all-gather,
                    all-reduce, reduce-scatter, all-to-all,
                    collective-permute), trip-count multiplied.

Validated against XLA's own numbers on loop-free programs (tests).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[a-z0-9].*?)\s([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_PARAM_RE = re.compile(r"^\s*%?([\w.\-]+)\s*=\s*(\(?[a-z0-9][^\s]*)\sparameter\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _first_shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    symbols: Dict[str, str]  # name -> type_str (includes parameters)


def parse_hlo(txt: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in txt.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR_RE.match(line)
        if hdr and line.endswith("{"):
            cur = Computation(hdr.group(1), [], {})
            comps[cur.name] = cur
            if line.lstrip().startswith("ENTRY"):
                comps["__entry__"] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        pm = _PARAM_RE.match(line)
        if pm:
            cur.symbols[pm.group(1)] = pm.group(2)
            continue
        dm = _DEF_RE.match(line)
        if dm:
            name, type_str, opcode = dm.group(1), dm.group(2), dm.group(3)
            cur.symbols[name] = type_str
            cur.ops.append(Op(name, type_str, opcode, line))
    return comps


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = 1
    dims = _first_shape_dims(op.type_str)
    for d in dims:
        out_elems *= d
    # contracted size: product of lhs contracting dims
    cm = _CONTRACT_RE.search(op.line)
    k = 1
    if cm is not None:
        operands = _operand_names(op)
        if operands:
            lhs_type = comp.symbols.get(operands[0], "")
            lhs_dims = _first_shape_dims(lhs_type)
            for idx in cm.group(1).split(","):
                if idx and int(idx) < len(lhs_dims):
                    k *= lhs_dims[int(idx)]
    return 2.0 * out_elems * k


def _operand_region(op: Op) -> str:
    """The text inside the op's balanced operand parens. Operand types may
    themselves contain parens (tuple types), so track depth."""
    m = re.search(re.escape(op.opcode) + r"\(", op.line)
    if not m:
        return ""
    start, depth = m.end(), 1
    for i in range(start, len(op.line)):
        ch = op.line[i]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return op.line[start:i]
    return op.line[start:]


def _operand_names(op: Op) -> List[str]:
    """Operand value names. Handles both HLO spellings: bare `%name` and the
    typed `f32[512,512]{1,0} %name` of newer XLA — each operand carries
    exactly one %-sigiled identifier either way."""
    region = _operand_region(op)
    names = re.findall(r"%([\w.\-]+)", region)
    if names or not region:
        return names
    # sigil-less dumps: bare comma-separated names (no type annotations)
    return [t.strip() for t in region.split(",") if t.strip() and "[" not in t]


_TRAFFIC_OPS = {
    "fusion", "dot", "copy", "dynamic-update-slice", "gather", "scatter",
    "convolution", "transpose", "reduce", "broadcast", "iota", "concatenate",
    "slice", "dynamic-slice", "pad", "reshape", "bitcast", "select",
    "custom-call", "rng-bit-generator", "sort", "convert", "compare",
    "add", "multiply", "subtract", "divide", "exponential", "tanh", "log",
    "maximum", "minimum", "cholesky", "triangular-solve",
}
# ops whose cost is attributed elsewhere or zero
_SKIP_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "while",
    "conditional", "call", "bitcast", "reshape", "after-all",
    "partition-id", "replica-id",
}


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    traffic: float = 0.0
    collectives: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __add__(self, o: "Cost") -> "Cost":
        coll = dict(self.collectives)
        for k, v in o.collectives.items():
            coll[k] = coll.get(k, 0.0) + v
        return Cost(self.flops + o.flops, self.traffic + o.traffic, coll)

    def __mul__(self, f: float) -> "Cost":
        return Cost(
            self.flops * f,
            self.traffic * f,
            {k: v * f for k, v in self.collectives.items()},
        )

    @property
    def collective_total(self) -> float:
        return sum(self.collectives.values())


def analyze(txt: str) -> Cost:
    comps = parse_hlo(txt)
    memo: Dict[str, Cost] = {}
    fusion_flops_memo: Dict[str, float] = {}

    def fusion_flops(comp_name: str) -> float:
        """dots hiding inside fusion bodies (flops only; traffic is at the
        fusion boundary)."""
        if comp_name in fusion_flops_memo:
            return fusion_flops_memo[comp_name]
        comp = comps.get(comp_name)
        total = 0.0
        if comp:
            for op in comp.ops:
                if op.opcode in ("dot", "convolution"):
                    total += _dot_flops(op, comp)
                cm = _CALLS_RE.search(op.line)
                if cm and op.opcode == "fusion":
                    total += fusion_flops(cm.group(1))
        fusion_flops_memo[comp_name] = total
        return total

    def comp_cost(comp_name: str) -> Cost:
        if comp_name in memo:
            return memo[comp_name]
        comp = comps.get(comp_name)
        if comp is None:
            return Cost()
        total = Cost()
        for op in comp.ops:
            base = op.opcode
            if base.endswith("-start"):
                base = base[: -len("-start")]
            if base in COLLECTIVE_OPS:
                nbytes = float(_shape_bytes(op.type_str))
                total = total + Cost(collectives={base: nbytes}, traffic=nbytes)
                continue
            if op.opcode.endswith("-done"):
                continue
            if op.opcode == "while":
                cb = _COND_BODY_RE.search(op.line)
                tm = _TRIP_RE.search(op.line)
                trips = float(tm.group(1)) if tm else 1.0
                if cb:
                    total = total + comp_cost(cb.group(2)) * trips
                    total = total + comp_cost(cb.group(1)) * (trips + 1)
                continue
            if op.opcode in ("call", "conditional", "async-start"):
                for cname in _CALLS_RE.findall(op.line):
                    total = total + comp_cost(cname)
                continue
            if op.opcode == "fusion":
                nbytes = float(_shape_bytes(op.type_str))
                for operand in _operand_names(op):
                    nbytes += float(_shape_bytes(comp.symbols.get(operand, "")))
                fl = 0.0
                cm = _CALLS_RE.search(op.line)
                if cm:
                    fl = fusion_flops(cm.group(1))
                total = total + Cost(flops=fl, traffic=nbytes)
                continue
            if op.opcode in ("dot", "convolution"):
                nbytes = float(_shape_bytes(op.type_str))
                for operand in _operand_names(op):
                    nbytes += float(_shape_bytes(comp.symbols.get(operand, "")))
                total = total + Cost(flops=_dot_flops(op, comp), traffic=nbytes)
                continue
            if op.opcode in _SKIP_TRAFFIC:
                continue
            if op.opcode in _TRAFFIC_OPS:
                nbytes = float(_shape_bytes(op.type_str))
                for operand in _operand_names(op):
                    nbytes += float(_shape_bytes(comp.symbols.get(operand, "")))
                total = total + Cost(traffic=nbytes)
        memo[comp_name] = total
        return total

    return comp_cost(comps["__entry__"].name if "__entry__" in comps else next(iter(comps)))


def analyze_compiled(compiled) -> Cost:
    return analyze(compiled.as_text())


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """compiled.cost_analysis() normalized to a flat dict.

    jax returned a one-element list of property dicts through 0.4.x and a
    plain dict from 0.5; accept both so the dry-run and tests run on either.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        out: Dict[str, float] = {}
        for entry in cost:
            out.update(entry)
        return out
    return dict(cost)


def cpu_bf16_upcast_bytes(txt: str, min_bytes: int = 1 << 25) -> float:
    """Bytes of f32 copies the CPU backend materializes to legalize bf16 dots.

    XLA:CPU has no native bf16 dot: it inserts convert(bf16->f32) on the
    operands, and loop-invariant-code-motion hoists the conversion of whole
    scan-stacked weight/KV tensors out of the layer loop — ballooning the
    temp allocation by ~2x of every bf16 tensor touched by a matmul. TPU
    executes bf16 dots natively and never materializes these buffers, so the
    dry-run memory analysis reports peak both raw and with these (entry-
    level, >=32 MiB) conversion buffers removed. Methodology documented in
    EXPERIMENTS.md §Dry-run.
    """
    comps = parse_hlo(txt)
    entry = comps.get("__entry__")
    if entry is None:
        return 0.0
    total = 0.0
    for op in entry.ops:
        if not op.type_str.startswith("f32["):
            continue
        is_convert = op.opcode == "convert" or (
            op.opcode == "fusion" and "wrapped_convert" in op.line
        )
        if not is_convert:
            continue
        nbytes = _shape_bytes(op.type_str)
        if nbytes < min_bytes:
            continue
        operands = _operand_names(op)
        if operands:
            src_type = entry.symbols.get(operands[0], "")
            if src_type.startswith("bf16[") and _first_shape_dims(
                src_type
            ) == _first_shape_dims(op.type_str):
                total += nbytes
    return total


def cpu_bf16_upcast_carried_bytes(txt: str, min_bytes: int = 1 << 25) -> float:
    """Extension of cpu_bf16_upcast_bytes: f32 while-loop carries whose dims
    exactly match a bf16 ENTRY PARAMETER (weights converted once and carried
    through the layer/microbatch loops). Only applies to bf16-at-rest
    models; on TPU these conversions never materialize."""
    comps = parse_hlo(txt)
    entry = comps.get("__entry__")
    if entry is None:
        return 0.0
    bf16_param_dims = set()
    for name, t in entry.symbols.items():
        if t.startswith("bf16["):
            dims = tuple(_first_shape_dims(t))
            if dims:
                bf16_param_dims.add(dims)
    # distinct physical buffers: one converted copy for the forward loop and
    # one for the backward loop (verified against the buffer-assignment dump
    # for arctic-480b); further while ops share those buffers, so cap the
    # count per shape at 2.
    counts = {}
    total = 0.0
    for op in entry.ops:
        if op.opcode != "while":
            continue
        seen_this_while = set()
        for m in _SHAPE_RE.finditer(op.type_str):
            if m.group(1) != "f32" or not m.group(2):
                continue
            dims = tuple(int(d) for d in m.group(2).split(",") if d)
            n = 1
            for d in dims:
                n *= d
            nbytes = n * 4
            if nbytes < min_bytes or dims not in bf16_param_dims:
                continue
            if dims in seen_this_while:
                continue
            seen_this_while.add(dims)
            if counts.get(dims, 0) < 2:
                counts[dims] = counts.get(dims, 0) + 1
                total += nbytes
    return total
