"""Production mesh definitions (TPU v5e pods).

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run sets XLA_FLAGS before any jax initialization and only
then calls make_production_mesh().
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips (one v5e pod) or 2x16x16 = 512 chips (two pods).

    Axes: 'pod' spans the inter-pod DCN/ICI boundary, 'data' carries batch
    (+ FSDP weight shards), 'model' carries tensor/expert parallelism.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    import numpy as np
    from jax.sharding import Mesh

    devs = np.array(jax.devices()[: data * model]).reshape(data, model)
    return Mesh(devs, ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis (benchmarks/).
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (~per chip per direction)
HBM_BYTES = 16 * 1024**3      # 16 GiB per chip
