"""Serving launcher: load a checkpoint (or init fresh), serve batched
greedy/temperature decoding.

    PYTHONPATH=src python -m repro.launch.serve --arch minimind-moe-16e \
        --reduced --batch 8 --gen 32 [--ckpt /path/step_N.npz]
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.models import build_model
    from repro.serving import ServeEngine

    cfg = configs.reduced_for_smoke(args.arch) if args.reduced else configs.get(args.arch)
    model = build_model(cfg)
    if args.ckpt:
        from repro.checkpoint import load_pytree

        tree = load_pytree(args.ckpt)
        params = tree["params"] if "params" in tree else tree
    else:
        params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.frontend_tokens, cfg.frontend_dim)),
            jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.enc_seq_len, cfg.frontend_dim)),
            jnp.float32)

    eng = ServeEngine(model, params, max_seq_len=args.prompt_len + args.gen + 1)
    cache, states = eng.start(batch)
    logits, cache, states = eng.prefill(prompts, cache, states)
    toks, _, _ = eng.decode(
        logits, cache, states, args.gen,
        temperature=args.temperature, key=jax.random.PRNGKey(1),
    )
    for i in range(min(args.batch, 4)):
        print(f"seq {i}: {np.asarray(toks[i]).tolist()}")
    print(f"served {args.batch} sequences x {args.gen} tokens")
    return 0


if __name__ == "__main__":
    sys.exit(main())
