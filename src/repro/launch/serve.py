"""Serving launcher: load a checkpoint (or init fresh), serve a request
stream through the continuous-batching engine (DESIGN.md §Serving).

    PYTHONPATH=src python -m repro.launch.serve --arch minimind-moe-16e \
        --reduced --requests 16 --n-slots 8 --chunk 32 [--ckpt /path/step_N.npz]
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--n-slots", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--max-seq-len", type=int, default=0, help="0 = auto")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="serve on a (data D x model M) device mesh: params/"
                         "cache take the training shardings and MoE layers "
                         "run the expert-parallel dispatch paths")
    # robustness flags (DESIGN.md §Robustness)
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request latency budget; overdue requests are "
                         "dropped ('expired') or evicted ('deadline')")
    ap.add_argument("--queue-timeout-ms", type=float, default=None,
                    help="max time a request may wait for admission")
    ap.add_argument("--shed-on-full", action="store_true",
                    help="under overload, shed the oldest waiting request "
                         "instead of refusing new submissions")
    ap.add_argument("--inject", action="append", default=None, metavar="SPEC",
                    help="fault injection, e.g. 'slow_step@ms=50' (decode "
                         "slowdown driving deadline misses)")
    # telemetry flags (DESIGN.md §Observability)
    ap.add_argument("--telemetry", default=None, metavar="PATH",
                    help="stream per-request lifecycle records + the final "
                         "SLO summary (TTFT/ITL histograms, queue depth, "
                         "live expert load) to this .jsonl/.csv file")
    ap.add_argument("--profile", default=None, metavar="N:M",
                    help="capture a jax.profiler trace of serve steps "
                         "[N, M] into ./profile")
    args = ap.parse_args(argv)

    import jax

    from repro import configs
    from repro.models import build_model
    from repro.serving import ContinuousBatchingEngine

    cfg = configs.reduced_for_smoke(args.arch) if args.reduced else configs.get(args.arch)
    model = build_model(cfg)
    if args.ckpt:
        from repro.checkpoint import load_pytree

        tree = load_pytree(args.ckpt)
        params = tree["params"] if "params" in tree else tree
    else:
        params = model.init(jax.random.PRNGKey(0))

    step_delay = 0.0
    if args.inject:
        from repro.robustness import FaultPlan

        faults = FaultPlan.from_specs(args.inject)
        step_delay = faults.step_delay()
        print("injecting: " + "; ".join(f.describe() for f in faults.faults))

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_host_mesh

        d, m = (int(v) for v in args.mesh.lower().split("x"))
        mesh = make_host_mesh(d, m)
        print(f"serving on a {d}x{m} mesh ({mesh.size} devices)")

    from repro.telemetry import open_sink, profile_window

    sink = open_sink(args.telemetry)
    max_seq_len = args.max_seq_len or (args.prompt_len + args.gen + 1)
    eng = ContinuousBatchingEngine(
        model,
        params,
        n_slots=args.n_slots,
        chunk_size=args.chunk,
        max_seq_len=max_seq_len,
        temperature=args.temperature,
        eos_id=args.eos_id,
        default_deadline=args.deadline_ms / 1e3 if args.deadline_ms else None,
        queue_timeout=(
            args.queue_timeout_ms / 1e3 if args.queue_timeout_ms else None
        ),
        shed_on_full=args.shed_on_full,
        step_delay=step_delay,
        sink=sink,
        profile=profile_window(args.profile) if args.profile else None,
        mesh=mesh,
    )
    rng = np.random.default_rng(0)
    reqs = []
    for _ in range(args.requests):
        plen = int(rng.integers(max(args.prompt_len // 2, 1), args.prompt_len + 1))
        prompt = rng.integers(0, cfg.vocab_size, (plen,))
        while True:
            r = eng.submit(prompt, args.gen, ignore_eos=args.eos_id is None)
            if r is not None:
                break
            eng.step()  # waiting queue full: drain a step, then retry
        reqs.append(r)
    eng.run()

    for r in reqs[:4]:
        print(f"req {r.req_id}: prompt[{len(r.prompt)}] -> {r.output} ({r.finish_reason})")
    total = eng.prefill_tokens + eng.decode_tokens
    print(
        f"served {len(reqs)} requests over {eng.n_slots} slots in {eng.n_steps} "
        f"steps ({total} tokens: {eng.prefill_tokens} prefill / {eng.decode_tokens} decode)"
    )
    if eng.n_deadline_missed or eng.n_shed:
        print(
            f"deadline misses: {eng.n_deadline_missed} "
            f"({eng.n_deadline_missed / max(len(reqs), 1):.1%}), "
            f"shed/timeout: {eng.n_shed}"
        )
    if cfg.is_moe:
        load = eng.expert_load
        mean = max(load.mean(), 1e-9)
        print(f"per-expert load: {load.astype(int).tolist()} (MaxVio {load.max()/mean - 1.0:.3f})")
    slo = eng.telemetry.emit_summary()
    print(
        f"SLO: ttft p50 {1e3 * slo['ttft']['p50']:.1f} ms / "
        f"p99 {1e3 * slo['ttft']['p99']:.1f} ms, "
        f"itl p50 {1e3 * slo['itl']['p50']:.1f} ms / "
        f"p99 {1e3 * slo['itl']['p99']:.1f} ms, "
        f"queue depth max {slo['queue_depth_max']}"
    )
    eng.close()
    if sink is not None:
        sink.close()
        print(f"telemetry -> {args.telemetry}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
