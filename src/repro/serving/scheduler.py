"""Request scheduler for continuous batching (DESIGN.md §Serving).

Host-side and model-free: the scheduler owns the request lifecycle
(waiting → prefill → decode → done) and the mapping of requests onto a fixed
pool of batch slots; the engine owns the device state (slot caches, router
duals) and asks the scheduler what each slot should do next step.

Policies, kept deliberately simple and observable:
  * admission is FIFO from a bounded waiting queue (`submit` returns False
    when the queue is full — callers must back off, not silently drop;
    with `shed_on_full` the OLDEST waiting request is shed instead, so
    overload degrades gracefully rather than stalling fresh traffic);
  * a request holds exactly one slot from admission to completion;
  * eviction happens on EOS, on max_new_tokens, or when the slot's cache
    rows run out (prompt + generated == max_seq_len).

Robustness (DESIGN.md §Robustness): requests may carry an absolute
`deadline`; `expire(now)` sweeps both the waiting queue and the active
slots, finishing overdue requests with reason 'expired' (never admitted)
or 'deadline' (evicted mid-generation), and enforces `queue_timeout` on
waiting time (reason 'timeout'). Every dropped request still flows back
to the caller — through `finish`'s return or the `take_dropped()` buffer
— with its `finish_reason` telling the client exactly what happened.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Deque, Iterator, List, Optional, Sequence, Tuple

WAITING = "waiting"
PREFILL = "prefill"
DECODE = "decode"
DONE = "done"


@dataclasses.dataclass
class Request:
    """One generation request and its accumulated results."""

    prompt: List[int]
    max_new_tokens: int
    req_id: int = -1
    arrival_time: float = 0.0
    eos_id: Optional[int] = None  # overrides the engine default; None = engine's
    ignore_eos: bool = False
    deadline: Optional[float] = None  # ABSOLUTE clock time; None = no deadline

    # lifecycle (scheduler/engine-owned)
    phase: str = WAITING
    output: List[int] = dataclasses.field(default_factory=list)
    # 'eos' | 'max_new_tokens' | 'length' — or a robustness outcome:
    # 'expired' (deadline passed while waiting), 'deadline' (evicted
    # mid-generation), 'timeout' (waited past queue_timeout), 'shed'
    # (dropped to admit fresh traffic under overload)
    finish_reason: Optional[str] = None
    t_submitted: float = 0.0
    t_admitted: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None

    def __post_init__(self):
        self.prompt = [int(t) for t in self.prompt]
        assert len(self.prompt) >= 1, "empty prompt"
        assert self.max_new_tokens >= 1


@dataclasses.dataclass
class Slot:
    """Host mirror of one device batch slot."""

    request: Request
    n_prefilled: int = 0  # prompt tokens already fed to the model

    @property
    def pos(self) -> int:
        """Next absolute cache position for this slot."""
        return self.n_prefilled + len(self.request.output)

    @property
    def prompt_done(self) -> bool:
        return self.n_prefilled >= len(self.request.prompt)


class Scheduler:
    """FIFO admission into a fixed pool of `n_slots` batch slots."""

    def __init__(
        self,
        n_slots: int,
        max_waiting: int = 256,
        queue_timeout: Optional[float] = None,
        shed_on_full: bool = False,
    ):
        assert n_slots >= 1
        self.n_slots = n_slots
        self.max_waiting = max_waiting
        self.queue_timeout = queue_timeout
        self.shed_on_full = shed_on_full
        self.waiting: Deque[Request] = deque()
        self.slots: List[Optional[Slot]] = [None] * n_slots
        self.n_completed = 0  # finished requests are returned, not retained
        self._ids = itertools.count()
        self._dropped: List[Request] = []  # expired/timed-out/shed, undrained

    # ------------------------------------------------------------ admission

    def submit(self, request: Request, now: float = 0.0) -> bool:
        """Queue a request; False = backpressure (waiting queue full).
        With `shed_on_full` the oldest WAITING request is shed to make room
        (graceful overload degradation: old queued work is the least likely
        to still meet its deadline) and submit always succeeds."""
        if len(self.waiting) >= self.max_waiting:
            if not self.shed_on_full:
                return False
            shed = self.waiting.popleft()
            self._drop(shed, "shed", now)
            self._dropped.append(shed)  # surfaced via take_dropped()
        if request.req_id < 0:
            request.req_id = next(self._ids)
        request.phase = WAITING
        request.t_submitted = now
        self.waiting.append(request)
        return True

    def _drop(self, req: Request, reason: str, now: float) -> None:
        req.phase = DONE
        req.finish_reason = reason
        req.t_done = now
        self.n_completed += 1

    def expire(self, now: float) -> List[Request]:
        """Sweep deadlines and queue timeouts. Evicts overdue ACTIVE slots
        (reason 'deadline'), drops overdue waiting requests ('expired') and
        ones queued past `queue_timeout` ('timeout'). Returns everything
        dropped by this sweep; evicted slots are free for re-admission."""
        out: List[Request] = []
        survivors: Deque[Request] = deque()
        for req in self.waiting:
            if req.deadline is not None and now >= req.deadline:
                self._drop(req, "expired", now)
                out.append(req)
            elif (
                self.queue_timeout is not None
                and now - req.t_submitted >= self.queue_timeout
            ):
                self._drop(req, "timeout", now)
                out.append(req)
            else:
                survivors.append(req)
        self.waiting = survivors
        for i, slot in list(self.active()):
            req = slot.request
            if req.deadline is not None and now >= req.deadline:
                out.append(self.finish(i, "deadline", now))
        return out

    def take_dropped(self) -> List[Request]:
        """Drain requests dropped outside an expire() call (shed on submit),
        so the engine can report every request's outcome exactly once."""
        out, self._dropped = self._dropped, []
        return out

    def admit(self, now: float = 0.0) -> List[Tuple[int, Request]]:
        """Move waiting requests into free slots, FIFO. Returns the newly
        occupied (slot_idx, request) pairs; the engine must reset those
        slots' cache rows before the next step."""
        admitted = []
        for i in range(self.n_slots):
            if self.slots[i] is None and self.waiting:
                req = self.waiting.popleft()
                req.phase = PREFILL
                req.t_admitted = now
                self.slots[i] = Slot(request=req)
                admitted.append((i, req))
        return admitted

    # ------------------------------------------------------------ lifecycle

    def active(self) -> Iterator[Tuple[int, Slot]]:
        for i, s in enumerate(self.slots):
            if s is not None:
                yield i, s

    def finish(self, slot_idx: int, reason: str, now: float = 0.0) -> Request:
        """Evict a slot's request (EOS / max-len): the slot frees for the
        next admission; the cache row is stale until the engine resets it.
        The finished request is returned to the caller, not retained (a
        long-running engine would otherwise grow without bound)."""
        slot = self.slots[slot_idx]
        assert slot is not None, f"slot {slot_idx} is empty"
        req = slot.request
        req.phase = DONE
        req.finish_reason = reason
        req.t_done = now
        self.slots[slot_idx] = None
        self.n_completed += 1
        return req

    # ------------------------------------------------------------- queries

    @property
    def n_free_slots(self) -> int:
        return sum(1 for s in self.slots if s is None)

    @property
    def n_active(self) -> int:
        return self.n_slots - self.n_free_slots

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or self.n_active > 0

    def __repr__(self) -> str:  # debugging aid
        occ = "".join("." if s is None else ("P" if not s.prompt_done else "D")
                      for s in self.slots)
        return (f"Scheduler(slots=[{occ}], waiting={len(self.waiting)}, "
                f"done={self.n_completed})")
