"""repro.serving — continuous-batching engine + request scheduler."""
from repro.serving.engine import ContinuousBatchingEngine, greedy_generate
from repro.serving.scheduler import Request, Scheduler

__all__ = ["ContinuousBatchingEngine", "Scheduler", "Request", "greedy_generate"]
