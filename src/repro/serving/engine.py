"""Continuous-batching serving engine (DESIGN.md §Serving).

Replaces the token-at-a-time ServeEngine: requests are admitted from a FIFO
queue into a fixed pool of batch slots, every slot advances by up to
`chunk_size` tokens per step through ONE jit'd `serve_step` — prefilling
slots consume their next prompt chunk, decoding slots their last sampled
token, idle slots are masked out. Static shapes (n_slots, chunk_size) mean
the whole engine runs trace-once; per-slot cache positions let sequences at
different offsets coexist; the BIP router's dual vector q threads through
every step, so expert loads stay balanced under mixed prefill/decode
traffic — the paper's systems payoff at inference time.

Two extensions ride on the same slot pool:

* `mesh=` puts the whole engine on a device mesh: params/cache/router
  state are laid out with the training shardings (distributed/sharding.py)
  and both jit'd step programs carry explicit in/out shardings, so MoE
  layers run the expert-parallel dispatch paths (ep/ep2d/ep2ds) with the
  masked global-sync duals — serving and training share one routing
  implementation.
* PACKED prefill decouples batch rows from cache slots: when a prompt is
  longer than one chunk and other rows would idle, its tail chunks spread
  across free rows (all-global stacks: write-then-attend makes this
  exact), and short fresh prompts tuck into other rows' padding columns as
  extra segments to free more rows. The packed step is only dispatched
  when it strictly reduces step count; otherwise the legacy single-layout
  program runs unchanged.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.serving.scheduler import DECODE, PREFILL, Request, Scheduler
from repro.telemetry.slo import ServingTelemetry
from repro.telemetry.trace import Profiler, trace_span


class ContinuousBatchingEngine:
    """Slot-pooled serving with chunked prefill fused into the decode step."""

    def __init__(
        self,
        model: Model,
        params: Any,
        *,
        n_slots: int = 8,
        chunk_size: int = 32,
        max_seq_len: int = 2048,
        eos_id: Optional[int] = None,
        temperature: float = 0.0,
        max_waiting: int = 256,
        use_kernel: Optional[bool] = None,
        seed: int = 0,
        default_deadline: Optional[float] = None,
        queue_timeout: Optional[float] = None,
        shed_on_full: bool = False,
        step_delay: float = 0.0,
        clock=time.perf_counter,
        sink=None,
        profile=None,
        profile_dir: str = "profile",
        mesh=None,
    ):
        cfg = model.cfg
        if (
            use_kernel is not None
            and cfg.is_moe
            and use_kernel != cfg.routing.use_kernel
        ):
            # serving-side override: flip the Pallas kernels (grouped expert
            # FFN + ADMM dual update) on/off without editing the config file.
            # Same parameter shapes, so the caller's params stay valid — the
            # serve path dispatches via moe._expert_ffn on the same masked
            # sort-based dispatch plan either way.
            from repro.models import build_model

            cfg = dataclasses.replace(
                cfg, routing=dataclasses.replace(cfg.routing, use_kernel=use_kernel)
            )
            model = build_model(cfg, model.mesh_ctx)
        if mesh is not None:
            # rebuild on the mesh: moe_ffn dispatches the expert-parallel
            # shard_map paths, attention/MLP get the training constraints
            from repro.distributed.sharding import make_mesh_ctx
            from repro.models import build_model

            model = build_model(cfg, make_mesh_ctx(mesh))
        assert not cfg.n_enc_layers and not cfg.frontend_dim, (
            "continuous batching serves token-only families; use "
            "greedy_generate's legacy path for encdec/vlm"
        )
        if cfg.is_moe:
            from repro.core import get_balancer

            if not get_balancer(cfg.routing.strategy).serving_ok:
                # fail at construction, not deep inside the first jit trace:
                # e.g. expert_choice selects each expert's top-C over the
                # batch, so a token's routing depends on later tokens —
                # incompatible with autoregressive decode
                raise NotImplementedError(
                    f"routing strategy {cfg.routing.strategy!r} is "
                    "training-only (batch-dependent selection breaks decode "
                    "causality); serve with a token-choice strategy instead"
                )
        if cfg.window_size and any(k == "local" for k, _ in cfg.layer_kinds()):
            # a chunk must fit the sliding-window ring buffer, whose capacity
            # is min(window, max_seq_len) (common.init_attention_cache)
            chunk_size = min(chunk_size, cfg.window_size, max_seq_len)
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.chunk_size = chunk_size
        self.max_seq_len = max_seq_len
        self.eos_id = eos_id
        # robustness knobs (DESIGN.md §Robustness): `default_deadline` is a
        # RELATIVE per-request latency budget applied at submit (absolute
        # deadline = clock() + budget); `clock` is injectable so deadline /
        # timeout behavior is testable deterministically with a fake clock;
        # `step_delay` is the slow_step fault-injection hook (seconds slept
        # per step, simulating decode slowdown).
        self.default_deadline = default_deadline
        self.step_delay = step_delay
        self.clock = clock
        self.scheduler = Scheduler(
            n_slots,
            max_waiting=max_waiting,
            queue_timeout=queue_timeout,
            shed_on_full=shed_on_full,
        )

        self.mesh = mesh
        self.cache = model.init_slot_cache(params, n_slots, max_seq_len)
        self.router_states = model.init_router_states()
        self._rng = jax.random.PRNGKey(seed)

        # packed-prefill capability gates: packing needs segment-aware
        # attention (no SSM/conv state — it advances strictly left-to-right
        # per row); spreading one stream across rows additionally needs the
        # write-then-attend cache on EVERY layer (no sliding-window rings)
        kinds = [k.replace("+shared", "") for k, _ in cfg.layer_kinds()]
        self._can_pack = all(k in ("global", "local") for k in kinds)
        self._can_spread = self._can_pack and all(k == "global" for k in kinds)

        def serve_step(params, cache, states, tokens, lengths, rng):
            logits, cache, states, mets = model.prefill_chunk(
                params, tokens, cache, states, lengths
            )
            idx = jnp.maximum(lengths - 1, 0)
            last = jnp.take_along_axis(logits, idx[:, None, None], axis=1)[:, 0]
            if temperature > 0.0:
                nxt = jax.random.categorical(rng, last / temperature, axis=-1)
            else:
                nxt = jnp.argmax(last, axis=-1)
            return nxt.astype(jnp.int32), cache, states, mets

        def serve_step_packed(
            params, cache, states, tokens, positions, segments,
            write_slots, cache_rows, gather_rows, gather_cols, rng,
        ):
            logits, cache, states, mets = model.prefill_chunk(
                params, tokens, cache, states,
                positions=positions, segments=segments,
                write_slots=write_slots, cache_rows=cache_rows,
            )
            # per-SLOT sample: gather_* point at each slot's last real
            # column in the packed grid (garbage rows are never consumed)
            last = logits[gather_rows, gather_cols]  # (n_slots, vocab)
            if temperature > 0.0:
                nxt = jax.random.categorical(rng, last / temperature, axis=-1)
            else:
                nxt = jnp.argmax(last, axis=-1)
            return nxt.astype(jnp.int32), cache, states, mets

        if mesh is None:
            self._reset = jax.jit(model.reset_slot)
            self._serve_step = jax.jit(serve_step)
            self._serve_step_packed = jax.jit(serve_step_packed)
        else:
            # explicit shardings: params/cache/router state keep the
            # training layouts across every step; everything small (tokens,
            # sampled ids, metrics) is replicated
            from jax.sharding import NamedSharding, PartitionSpec as P

            from repro.distributed.sharding import (
                cache_specs, param_specs, router_state_specs, shard_tree,
            )

            def named(specs):
                return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)

            repl = NamedSharding(mesh, P())
            pshard = named(param_specs(params, cfg, mesh))
            cshard = named(cache_specs(self.cache, cfg, mesh, n_slots))
            sshard = named(router_state_specs(self.router_states))
            mshard = {"moe_load": repl, "max_vio": repl}
            self.params = shard_tree(params, param_specs(params, cfg, mesh), mesh)
            self.cache = shard_tree(
                self.cache, cache_specs(self.cache, cfg, mesh, n_slots), mesh
            )
            self.router_states = jax.tree.map(
                lambda x, s: jax.device_put(x, s), self.router_states, sshard
            )
            self._reset = jax.jit(
                model.reset_slot,
                in_shardings=(cshard, repl),
                out_shardings=cshard,
            )
            self._serve_step = jax.jit(
                serve_step,
                in_shardings=(pshard, cshard, sshard, repl, repl, repl),
                out_shardings=(repl, cshard, sshard, mshard),
            )
            self._serve_step_packed = jax.jit(
                serve_step_packed,
                in_shardings=(pshard, cshard, sshard) + (repl,) * 8,
                out_shardings=(repl, cshard, sshard, mshard),
            )

        # telemetry: counters, per-expert load, and SLO histograms live in
        # one reset-able ServingTelemetry; `sink` streams per-request
        # lifecycle records + the final summary (telemetry/slo.py). The
        # legacy counter attributes below are read-only views.
        self.telemetry = ServingTelemetry(
            cfg.routing.n_experts if cfg.is_moe else 1, sink=sink
        )
        # `profile` = (lo, hi) serve-step window captured with jax.profiler
        self._profiler = (
            Profiler(profile, log_dir=profile_dir) if profile is not None else None
        )

    # ------------------------------------------- legacy telemetry views

    @property
    def n_steps(self) -> int:
        return self.telemetry.n_steps

    @property
    def prefill_tokens(self) -> int:
        return self.telemetry.prefill_tokens

    @property
    def decode_tokens(self) -> int:
        return self.telemetry.decode_tokens

    @property
    def expert_load(self) -> np.ndarray:
        return self.telemetry.expert_load

    @property
    def max_vio_per_step(self) -> List[float]:
        return self.telemetry.max_vio_per_step

    @property
    def n_deadline_missed(self) -> int:
        return self.telemetry.n_deadline_missed

    @property
    def n_shed(self) -> int:
        return self.telemetry.n_shed

    def close(self) -> None:
        """Stop an in-flight profiler capture (sink closing is the caller's)."""
        if self._profiler is not None:
            self._profiler.close()

    # -------------------------------------------------------------- intake

    def submit(
        self,
        prompt: Sequence[int],
        max_new_tokens: int,
        *,
        eos_id: Optional[int] = None,
        ignore_eos: bool = False,
        arrival_time: float = 0.0,
        deadline: Optional[float] = None,
    ) -> Optional[Request]:
        """Queue one request. Returns it, or None under backpressure
        (bounded waiting queue full — retry after stepping the engine;
        never None when the engine sheds on full). `deadline` is a RELATIVE
        latency budget in seconds (falls back to the engine default);
        overdue requests are dropped/evicted with a deadline outcome
        instead of holding resources."""
        prompt = [int(t) for t in np.asarray(prompt).reshape(-1)]
        assert len(prompt) < self.max_seq_len, "prompt does not fit the cache"
        now = self.clock()
        budget = deadline if deadline is not None else self.default_deadline
        req = Request(
            prompt=prompt,
            max_new_tokens=max_new_tokens,
            eos_id=eos_id,
            ignore_eos=ignore_eos,
            arrival_time=arrival_time,
            deadline=None if budget is None else now + budget,
        )
        return req if self.scheduler.submit(req, now) else None

    # ---------------------------------------------------------------- step

    def _observe(self, req: Request) -> Request:
        """Route every request outcome (finish OR drop) through telemetry
        exactly once: counters, SLO histograms, and the per-request record."""
        self.telemetry.on_finish(req, len(req.output))
        return req

    def _plan_packed(self, active):
        """Packed-layout step plan, or None when the legacy one-row-per-slot
        layout is already step-optimal.

        Packing pays only when some prompt has more than `chunk_size` tokens
        left: its tail chunks can then SPREAD across rows that would
        otherwise idle (exactness argument in
        common._attention_chunk_packed — all-global stacks only), finishing
        a k-chunk prefill in ceil(k / n_free_rows) steps instead of k. Short
        fresh prompts are tucked into used rows' free columns as extra
        segments, vacating their rows for spreading. Returns the operand
        arrays of `serve_step_packed` plus the bookkeeping plan; falls back
        to None whenever the resulting layout would be identical to the
        legacy one (so steady-state decode keeps the legacy program)."""
        b, c = self.n_slots, self.chunk_size
        if not self._can_spread:
            return None
        if not any(
            not slot.prompt_done
            and len(slot.request.prompt) - slot.n_prefilled > c
            for _, slot in active
        ):
            return None

        tokens = np.zeros((b, c), np.int32)
        positions = np.zeros((b, c), np.int32)
        segments = np.full((b, c), -1, np.int32)
        write_slots = np.full((b, c), -1, np.int32)
        cache_rows = np.arange(b, dtype=np.int32)
        gather_rows = np.zeros((b,), np.int32)
        gather_cols = np.zeros((b,), np.int32)
        col_used = np.zeros((b,), np.int32)
        next_seg = np.ones((b,), np.int32)
        row_taken = [False] * b
        plan: List[tuple] = []

        decodes, shorts, streams = [], [], []
        for i, slot in active:
            if slot.prompt_done:
                decodes.append((i, slot))
            elif slot.n_prefilled == 0 and len(slot.request.prompt) < c:
                shorts.append((i, slot))
            else:
                streams.append((i, slot))

        for i, slot in decodes:
            tokens[i, 0] = slot.request.output[-1]
            positions[i, 0] = slot.pos - 1  # == cache pos of slot i
            segments[i, 0] = 0
            write_slots[i, 0] = i
            col_used[i] = 1
            row_taken[i] = True
            gather_rows[i], gather_cols[i] = i, 0
            plan.append((i, slot, DECODE, 1))

        # prefill streams: first chunk in the slot's own row as the resident
        # (segment 0) continuation of its cache
        rem: Dict[int, int] = {}
        last_at: Dict[int, tuple] = {}
        stream_slot = dict(streams)
        for i, slot in streams:
            p0 = slot.n_prefilled
            L = min(len(slot.request.prompt) - p0, c)
            tokens[i, :L] = slot.request.prompt[p0 : p0 + L]
            positions[i, :L] = np.arange(p0, p0 + L)
            segments[i, :L] = 0
            write_slots[i, :L] = i
            col_used[i] = L
            row_taken[i] = True
            rem[i] = len(slot.request.prompt) - p0 - L
            last_at[i] = (i, L - 1, L)  # (row, col, placed-so-far)

        # short fresh prompts: best-fit into a used row's padding columns as
        # a fresh segment (frees their own row for spreading below)
        for i, slot in sorted(
            shorts, key=lambda t: -len(t[1].request.prompt)
        ):
            L = len(slot.request.prompt)
            fit = [
                r for r in range(b) if row_taken[r] and col_used[r] + L <= c
            ]
            r = min(fit, key=lambda r: c - col_used[r] - L) if fit else i
            s = int(next_seg[r])
            row_taken[r] = True
            lo = col_used[r]
            tokens[r, lo : lo + L] = slot.request.prompt
            positions[r, lo : lo + L] = np.arange(L)
            segments[r, lo : lo + L] = s
            write_slots[r, lo : lo + L] = i
            next_seg[r] = s + 1
            col_used[r] = lo + L
            gather_rows[i], gather_cols[i] = r, lo + L - 1
            plan.append((i, slot, PREFILL, L))

        # spread: hand free rows to the streams with the most prompt left
        free = [r for r in range(b) if not row_taken[r]]
        used_extra = False
        for r in free:
            if not rem:
                break
            i = max(rem, key=rem.get)
            if rem[i] <= 0:
                break
            slot = stream_slot[i]
            p0 = slot.n_prefilled + last_at[i][2]
            L = min(rem[i], c)
            tokens[r, :L] = slot.request.prompt[p0 : p0 + L]
            positions[r, :L] = np.arange(p0, p0 + L)
            segments[r, :L] = 0
            cache_rows[r] = i  # this row CONTINUES slot i's stream
            write_slots[r, :L] = i
            col_used[r] = L
            row_taken[r] = True
            rem[i] -= L
            last_at[i] = (r, L - 1, last_at[i][2] + L)
            used_extra = True

        if not used_extra:
            return None  # no spreading happened: legacy layout is identical
        for i, slot in streams:
            r, col, placed = last_at[i]
            gather_rows[i], gather_cols[i] = r, col
            plan.append((i, slot, PREFILL, placed))
        return (
            tokens, positions, segments, write_slots, cache_rows,
            gather_rows, gather_cols, plan,
        )

    def step(self) -> List[Request]:
        """One fused serve step. Returns requests completed this step —
        including any dropped by the deadline/timeout sweep or shed at
        submit, so every request's outcome is reported exactly once."""
        if self.step_delay > 0:
            time.sleep(self.step_delay)  # slow_step fault injection
        if self._profiler is not None:
            self._profiler.step(self.telemetry.n_steps)
        now = self.clock()
        # sweep BEFORE admission: evicting overdue slots frees them for
        # waiting work within the same step
        dropped = [
            self._observe(r)
            for r in self.scheduler.expire(now) + self.scheduler.take_dropped()
        ]
        for slot_idx, _req in self.scheduler.admit(now):
            self.cache = self._reset(self.cache, jnp.asarray(slot_idx))

        b, c = self.n_slots, self.chunk_size
        active = list(self.scheduler.active())
        if not active:
            return dropped

        packed = self._plan_packed(active) if self._can_pack else None
        self._rng, sub = jax.random.split(self._rng)
        if packed is not None:
            (tokens, positions, segments, write_slots, cache_rows,
             gather_rows, gather_cols, plan) = packed
            with trace_span("serve/step"):
                nxt, self.cache, self.router_states, mets = (
                    self._serve_step_packed(
                        self.params,
                        self.cache,
                        self.router_states,
                        jnp.asarray(tokens),
                        jnp.asarray(positions),
                        jnp.asarray(segments),
                        jnp.asarray(write_slots),
                        jnp.asarray(cache_rows),
                        jnp.asarray(gather_rows),
                        jnp.asarray(gather_cols),
                        sub,
                    )
                )
                nxt = np.asarray(nxt)
        else:
            tokens = np.zeros((b, c), np.int32)
            lengths = np.zeros((b,), np.int32)
            plan = []  # (slot_idx, slot, kind, n_tokens)
            for i, slot in active:
                req = slot.request
                if not slot.prompt_done:
                    chunk = req.prompt[slot.n_prefilled : slot.n_prefilled + c]
                    tokens[i, : len(chunk)] = chunk
                    lengths[i] = len(chunk)
                    plan.append((i, slot, PREFILL, len(chunk)))
                else:
                    tokens[i, 0] = req.output[-1]
                    lengths[i] = 1
                    plan.append((i, slot, DECODE, 1))
            with trace_span("serve/step"):
                nxt, self.cache, self.router_states, mets = self._serve_step(
                    self.params,
                    self.cache,
                    self.router_states,
                    jnp.asarray(tokens),
                    jnp.asarray(lengths),
                    sub,
                )
                nxt = np.asarray(nxt)
        self.telemetry.on_step(
            mets,
            n_prefill=sum(n for _, _, kind, n in plan if kind == PREFILL),
            n_decode=sum(1 for _, _, kind, _ in plan if kind == DECODE),
            queue_depth=len(self.scheduler.waiting),
        )

        done: List[Request] = dropped
        now = self.clock()
        for i, slot, kind, n_tok in plan:
            req = slot.request
            if kind == PREFILL:
                slot.n_prefilled += n_tok
                if not slot.prompt_done:
                    continue  # still mid-prompt: this step's sample is unused
                req.phase = DECODE
                req.t_first_token = now
            # the step that finishes the prompt doubles as the first decode:
            # its last-position logits sample the first generated token
            tok = int(nxt[i])
            req.output.append(tok)
            eos = req.eos_id if req.eos_id is not None else self.eos_id
            if eos is not None and not req.ignore_eos and tok == eos:
                done.append(self._observe(self.scheduler.finish(i, "eos", now)))
            elif len(req.output) >= req.max_new_tokens:
                done.append(
                    self._observe(self.scheduler.finish(i, "max_new_tokens", now))
                )
            elif slot.pos >= self.max_seq_len:
                done.append(self._observe(self.scheduler.finish(i, "length", now)))
        return done

    # ----------------------------------------------------------------- run

    def run(self, requests: Optional[Iterable[Request]] = None) -> List[Request]:
        """Drain: submit any extra `requests` (respecting backpressure by
        interleaving steps), then step until no work remains. Returns all
        requests completed during this call, in completion order."""
        finished: List[Request] = []
        pending = list(requests) if requests is not None else []
        for req in pending:  # same guard submit() applies
            assert len(req.prompt) < self.max_seq_len, "prompt does not fit the cache"
        while pending:
            req = pending[0]
            if self.scheduler.submit(req, self.clock()):
                pending.pop(0)
            else:
                finished.extend(self.step())  # make room
        while self.scheduler.has_work:
            finished.extend(self.step())
        return finished


# ----------------------------------------------------------- compatibility


def greedy_generate(
    model: Model,
    params,
    prompts: jnp.ndarray,
    n_steps: int,
    max_seq_len: int = 2048,
    extra_batch: Optional[Dict[str, jnp.ndarray]] = None,
) -> jnp.ndarray:
    """Batched greedy decoding — thin wrapper over the continuous-batching
    engine (encdec/vlm requests carry per-request side inputs the slot pool
    does not model yet, so they fall back to the per-token legacy path)."""
    cfg = model.cfg
    if extra_batch or cfg.n_enc_layers or cfg.frontend_dim:
        return _legacy_generate(model, params, prompts, n_steps, max_seq_len, extra_batch)
    b, s = prompts.shape
    eng = ContinuousBatchingEngine(
        model,
        params,
        n_slots=b,
        chunk_size=min(max(s, 1), 64),
        # honor the (B, n_steps) shape contract: never evict on 'length'
        max_seq_len=max(max_seq_len, s + n_steps + 1),
    )
    reqs = [
        eng.submit(np.asarray(prompts[i]), n_steps, ignore_eos=True) for i in range(b)
    ]
    assert all(r is not None for r in reqs)
    eng.run()
    return jnp.asarray([r.output for r in reqs], jnp.int32)


def _legacy_generate(
    model: Model, params, prompts, n_steps, max_seq_len, extra_batch
) -> jnp.ndarray:
    """Seed-style per-token prefill + greedy decode (encdec/vlm only)."""
    batch = {"tokens": prompts}
    if extra_batch:
        batch.update(extra_batch)
    cache = model.init_cache(params, batch, max_seq_len)
    states = model.init_router_states()
    decode = jax.jit(model.decode_step)
    logits = None
    for t in range(prompts.shape[1]):
        logits, cache, states = decode(params, prompts[:, t : t + 1], cache, states)
    toks = []
    for _ in range(n_steps):
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        toks.append(nxt)
        logits, cache, states = decode(params, nxt, cache, states)
    return jnp.concatenate(toks, axis=1)
