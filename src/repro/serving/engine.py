"""Batched serving engine: prefill-by-decode + jit'd decode steps.

Small but real: fixed-batch continuous decode with greedy/temperature
sampling, KV ring buffers for sliding-window layers, recurrent state for
SSM layers, and per-step routing (the BIP gate keeps balancing at inference,
which matters for expert-parallel serving utilization).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass
class ServeEngine:
    model: Model
    params: Any
    max_seq_len: int = 2048

    def __post_init__(self):
        self._decode = jax.jit(self.model.decode_step)

    def start(self, batch: Dict[str, jnp.ndarray]):
        cache = self.model.init_cache(self.params, batch, self.max_seq_len)
        states = self.model.init_router_states()
        return cache, states

    def prefill(self, prompts: jnp.ndarray, cache, states):
        """Feed prompt tokens one step at a time (teacher forcing)."""
        logits = None
        for t in range(prompts.shape[1]):
            logits, cache, states = self._decode(
                self.params, prompts[:, t : t + 1], cache, states
            )
        return logits, cache, states

    def decode(
        self,
        last_logits: jnp.ndarray,
        cache,
        states,
        n_steps: int,
        *,
        temperature: float = 0.0,
        key=None,
    ) -> Tuple[jnp.ndarray, Any, Any]:
        """Generate n_steps tokens. Returns (tokens (B, n_steps), cache, states)."""
        toks = []
        logits = last_logits
        key = key if key is not None else jax.random.PRNGKey(0)
        for i in range(n_steps):
            if temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits[:, -1] / temperature)[:, None]
            else:
                nxt = jnp.argmax(logits[:, -1:], axis=-1)
            nxt = nxt.astype(jnp.int32)
            toks.append(nxt)
            logits, cache, states = self._decode(self.params, nxt, cache, states)
        return jnp.concatenate(toks, axis=1), cache, states


def greedy_generate(
    model: Model, params, prompts: jnp.ndarray, n_steps: int, max_seq_len: int = 2048,
    extra_batch: Optional[Dict[str, jnp.ndarray]] = None,
) -> jnp.ndarray:
    batch = {"tokens": prompts}
    if extra_batch:
        batch.update(extra_batch)
    eng = ServeEngine(model, params, max_seq_len)
    cache, states = eng.start(batch)
    logits, cache, states = eng.prefill(prompts, cache, states)
    toks, _, _ = eng.decode(logits, cache, states, n_steps)
    return toks
