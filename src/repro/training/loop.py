"""Training harness: TrainState, sharded/donated/microbatched train step,
checkpointed host-side driver.

The train step threads three pytrees: params, optimizer state, and the
per-MoE-layer router states (the BIP dual vector q / Loss-Free bias). The
host loop accumulates the paper's balance measurements (per-batch MaxVio per
layer -> AvgMaxVio / SupMaxVio) via BalanceTracker — exactly the quantities
in the paper's Tables 2-5.

Production shape (DESIGN.md §Training):

* **Sharding** — `compile_train_step(..., mesh=...)` resolves explicit
  `in_shardings`/`out_shardings` for every TrainState leaf and batch tensor
  from `repro.distributed.sharding` (FSDP params over the data axes, tensor/
  expert parallelism over 'model', replicated router duals) so GSPMD never
  has to guess a layout for the optimizer update.
* **Donation** — the TrainState argument is donated (`donate_argnums=(0,)`):
  params/mu/nu buffers are updated in place, so a step's live memory is one
  copy of the state plus transients, not two.
* **Mixed precision** — master params and Adam moments stay fp32 (or the
  per-config `adam_*_dtype` policy); the forward/backward computes in
  `cfg.compute_dtype` (bf16 for the full-size configs) because every weight
  is cast at its use site inside the model. Gradients therefore come back in
  the fp32 master dtype and the update math runs in fp32 (`optim.adamw`).
* **Gradient accumulation** — `microbatches=k` reshapes the global batch to
  (k, B/k, ...) and runs a `lax.scan` of forward/backward per microbatch,
  accumulating gradients in the parameter dtype; router states thread
  *sequentially* through microbatches (the BIP dual price q updates between
  microbatches, exactly as it would across smaller true steps).
* **Router dual sync** — `cfg.routing.sync` rides into the compiled sharded
  step through the model: 'global' makes every BIP gate run the fused
  multi-threshold dual update with psum'd counts over the mesh's data axes
  inside the step (`ref_bip.bip_dual_update_global`), so the carried q is
  the single-device paper trajectory; 'local' solves per-shard duals and
  pmean-averages them into the warm start (DESIGN.md §Global-sync). The
  replicated router-state sharding spec
  (`distributed.sharding.router_state_specs`) is the same either way, and
  covers every state leaf — including the dual-forecaster EMAs
  ('q_ema'/'q_err') that `cfg.routing.forecast` adds, which thread through
  microbatches and steps exactly like q.
* **Checkpointing** — `train_loop(ckpt_dir=..., ckpt_every=N, resume=True)`
  saves the full TrainState (params, Adam moments, step counter, router
  states — the dual q plus, under `cfg.routing.forecast`, the forecaster
  EMAs) through `checkpoint.store` and resumes bit-exactly: the data
  stream is deterministic per step index and the forecaster state restores
  with the duals, so a restored run replays the remaining schedule on
  identical batches with identical warm-start brackets.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import BalanceTracker
from repro.models.model import Model
from repro.optim import adamw as _adamw


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    router_states: Any


def init_train_state(model: Model, key, opt_cfg: _adamw.AdamWConfig) -> TrainState:
    params = model.init(key)
    return TrainState(
        params=params,
        opt_state=_adamw.adamw_init(params, opt_cfg),
        router_states=model.init_router_states(),
    )


def _split_micro(batch: Dict[str, jnp.ndarray], k: int) -> Dict[str, jnp.ndarray]:
    return jax.tree.map(
        lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]), batch
    )


def _reduce_micro_mets(mets: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """Collapse (k, ...)-stacked per-microbatch metrics to per-step values.

    MaxVio is reduced with max (the conservative per-step number: the worst
    microbatch — matches SupMaxVio semantics); scalars average; perplexity is
    recomputed from the averaged CE so it stays exp(mean nll)."""
    out = {}
    for name, v in mets.items():
        if name == "max_vio_per_layer":
            out[name] = jnp.max(v, axis=0)
        elif name != "perplexity":
            out[name] = jnp.mean(v, axis=0)
    if "ce_loss" in out:
        out["perplexity"] = jnp.exp(out["ce_loss"])
    return out


def make_train_step(
    model: Model,
    opt_cfg: _adamw.AdamWConfig,
    lr_fn: Callable[[jnp.ndarray], jnp.ndarray],
    *,
    microbatches: int = 1,
    rng: Optional[jnp.ndarray] = None,
):
    """Returns train_step(state, batch) -> (state, metrics). Pure; jit-ready.

    With microbatches=k the batch's leading axis must divide by k; the
    forward/backward runs as a k-trip lax.scan with gradient accumulation so
    the residual/activation footprint is that of B/k sequences.

    `rng` (optional) is a base PRNG key; each step derives its key by
    folding in the optimizer's step counter (and the microbatch index under
    accumulation), so the per-step randomness seen by dropout-style
    regularizers is a pure function of checkpointed state — resume-stable
    by construction.
    """

    def _fwd_bwd(params, batch, router, key):
        return jax.value_and_grad(model.loss_fn, has_aux=True)(
            params, batch, router, key
        )

    def _apply(state: TrainState, grads, new_router, mets):
        lr = lr_fn(state.opt_state["step"].astype(jnp.float32))
        new_params, new_opt, info = _adamw.adamw_update(
            grads, state.opt_state, state.params, lr, opt_cfg
        )
        mets = dict(mets)
        mets.update(info)
        return (
            TrainState(params=new_params, opt_state=new_opt, router_states=new_router),
            mets,
        )

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        step_key = (
            None if rng is None else jax.random.fold_in(rng, state.opt_state["step"])
        )
        if microbatches <= 1:
            (loss, (new_router, mets)), grads = _fwd_bwd(
                state.params, batch, state.router_states, step_key
            )
            mets = dict(mets)
            mets["loss"] = loss
            return _apply(state, grads, new_router, mets)

        mb = _split_micro(batch, microbatches)
        # accumulate in the parameter dtype: fp32 accumulation doubles the
        # carry footprint for bf16-param models (arctic) with negligible
        # benefit at <=16 microbatches
        acc_dt = model.cfg.param_dtype

        def body(carry, inp):
            one, mb_idx = inp
            grads_acc, router = carry
            key = None if step_key is None else jax.random.fold_in(step_key, mb_idx)
            (loss, (router, mets)), grads = _fwd_bwd(state.params, one, router, key)
            grads_acc = jax.tree.map(
                lambda a, g: a + g.astype(acc_dt), grads_acc, grads
            )
            mets = dict(mets)
            mets["loss"] = loss
            return (grads_acc, router), mets

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), state.params)
        (grads, new_router), mets = jax.lax.scan(
            body, (zero, state.router_states), (mb, jnp.arange(microbatches))
        )
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        return _apply(state, grads, new_router, _reduce_micro_mets(mets))

    return train_step


def compile_train_step(
    model: Model,
    opt_cfg: _adamw.AdamWConfig,
    lr_fn,
    state: TrainState,
    batch: Dict[str, Any],
    *,
    mesh=None,
    microbatches: int = 1,
    donate: bool = True,
    st_specs=None,
    b_specs=None,
    rng: Optional[jnp.ndarray] = None,
):
    """jit the train step, with explicit shardings when a mesh is given.

    `state`/`batch` may be concrete arrays or ShapeDtypeStructs — only their
    tree structure and shapes are consulted. On a mesh, every TrainState leaf
    and batch tensor gets the PartitionSpec from `distributed.sharding` as an
    explicit in/out sharding (out == in, so the donated buffers alias
    leaf-for-leaf and the state layout is fixed-point across steps); metrics
    come back replicated. Callers that already resolved the spec trees (e.g.
    train_loop, which also places the arrays with them) pass st_specs /
    b_specs so there is one resolution per run.
    """
    step = make_train_step(model, opt_cfg, lr_fn, microbatches=microbatches, rng=rng)
    donate_argnums = (0,) if donate else ()
    if mesh is None:
        return jax.jit(step, donate_argnums=donate_argnums)

    from jax.sharding import NamedSharding

    from repro.distributed.sharding import batch_specs, train_state_specs

    if st_specs is None:
        st_specs = train_state_specs(state, model.cfg, mesh)
    if b_specs is None:
        b_all = batch_specs(model.cfg, mesh, jax.tree.leaves(batch)[0].shape[0])
        b_specs = {k: b_all[k] for k in batch}
    as_sharding = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec),
    )
    return jax.jit(
        step,
        in_shardings=(as_sharding(st_specs), as_sharding(b_specs)),
        out_shardings=(as_sharding(st_specs), None),
        donate_argnums=donate_argnums,
    )


@dataclasses.dataclass
class TrainLog:
    """Host-side record of one run, including the paper's balance metrics."""

    losses: List[float] = dataclasses.field(default_factory=list)
    perplexities: List[float] = dataclasses.field(default_factory=list)
    step_times: List[float] = dataclasses.field(default_factory=list)
    max_vio_steps: List[np.ndarray] = dataclasses.field(default_factory=list)
    per_layer: List[BalanceTracker] = dataclasses.field(default_factory=list)
    model_tracker: BalanceTracker = dataclasses.field(default_factory=BalanceTracker)

    def record(self, mets: Dict[str, Any], dt: float) -> None:
        self.losses.append(float(mets["ce_loss"]))
        self.perplexities.append(float(mets["perplexity"]))
        self.step_times.append(dt)
        vios = np.asarray(mets.get("max_vio_per_layer", np.zeros(0)))
        if vios.size:
            self.max_vio_steps.append(vios)
            if not self.per_layer:
                self.per_layer = [BalanceTracker() for _ in range(vios.size)]
            for t, v in zip(self.per_layer, vios):
                t.add(float(v))
            # model-level MaxVio for the batch = max over layers (conservative)
            self.model_tracker.add(float(vios.max()))

    def summary(self) -> Dict[str, Any]:
        out = {
            "final_loss": self.losses[-1] if self.losses else None,
            "final_ppl": self.perplexities[-1] if self.perplexities else None,
            "mean_step_time": float(np.mean(self.step_times[2:]))
            if len(self.step_times) > 2
            else None,
            **self.model_tracker.summary(),
        }
        if self.per_layer:
            out["AvgMaxVio_per_layer"] = [t.avg_max_vio for t in self.per_layer]
        return out


def train_loop(
    model: Model,
    batches: Iterable[Dict[str, jnp.ndarray]],
    *,
    key=None,
    lr: float = 3e-4,
    warmup_steps: int = 20,
    total_steps: int = 200,
    opt_overrides: Optional[Dict] = None,
    log_every: int = 0,
    state: Optional[TrainState] = None,
    mesh=None,
    microbatches: int = 1,
    donate: bool = True,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 0,
    resume: bool = False,
    async_ckpt: bool = True,
) -> Tuple[TrainState, TrainLog]:
    """Host driver. With `mesh` the state/batches are placed with the specs
    from `distributed.sharding` and the step compiles with explicit
    shardings + donation; without one it is the plain single-device jit.

    `batches` is any iterable of batch dicts; when it is a `BatchStream`
    (has state_dict/load_state_dict — `data.ShardedTextLoader`,
    `data.SyntheticBatchStream`, or a `data.Prefetcher` around either),
    its cursor is checkpointed alongside the TrainState and `resume=True`
    seeks it in O(1) instead of regenerating + discarding the consumed
    prefix. Plain iterables keep the replay-skip fallback.

    Checkpoints are written asynchronously by default (`async_ckpt=True`):
    the save snapshots device buffers and overlaps the host gather + npz
    write with the next steps, barriering at the following save
    (checkpoint/store.py). Iteration stops at `total_steps` even when the
    stream is infinite (real-corpus loaders loop epochs forever).

    `resume=True` restores the newest checkpoint under `ckpt_dir` (if any)
    and continues bit-exactly — including the router duals q and the data
    cursor.
    """
    from repro.optim.schedules import linear_warmup_cosine

    key = key if key is not None else jax.random.PRNGKey(0)
    opt_cfg = _adamw.from_model_config(model.cfg, **(opt_overrides or {}))

    manager = None
    if ckpt_dir is not None:
        from repro.checkpoint import CheckpointManager

        manager = CheckpointManager(ckpt_dir)

    is_stream = hasattr(batches, "state_dict") and hasattr(batches, "load_state_dict")
    start_step = 0
    data_state = None
    if resume and manager is not None and state is None:
        from repro.checkpoint.store import latest_step

        if latest_step(ckpt_dir) is not None:
            start_step, state = manager.restore_train_state()
            data_state = manager.restore_data_state(start_step)
    if state is None:
        state = init_train_state(model, key, opt_cfg)

    loop_start = 0  # index the enumerate starts at
    if is_stream and data_state is not None:
        batches.load_state_dict(data_state)  # O(1) seek past the consumed prefix
        loop_start = start_step

    st_specs = b_specs = None
    if mesh is not None:
        from repro.distributed.sharding import (
            batch_specs,
            shard_tree,
            train_state_specs,
        )

        st_specs = train_state_specs(state, model.cfg, mesh)
        state = shard_tree(state, st_specs, mesh)

    step_fn = None
    log = TrainLog()
    mesh_ctx = mesh if mesh is not None else contextlib.nullcontext()
    saved_at = -1
    it = iter(batches)
    i = loop_start - 1
    while True:
        # bound infinite streams (epoch-looping corpus loaders) *before*
        # pulling: the stream cursor must stay in sync with the step count,
        # so never consume a batch that won't be trained on
        if total_steps and i + 1 >= total_steps:
            break
        try:
            batch = next(it)
        except StopIteration:
            break
        i += 1
        if i < start_step:
            continue  # resumed plain iterable: replay-skip the consumed prefix
        if mesh is not None:
            if b_specs is None:
                b_all = batch_specs(model.cfg, mesh, jax.tree.leaves(batch)[0].shape[0])
                b_specs = {k: b_all[k] for k in batch}
            batch = shard_tree(batch, b_specs, mesh)
        if step_fn is None:
            step_fn = compile_train_step(
                model,
                opt_cfg,
                linear_warmup_cosine(lr, warmup_steps, total_steps),
                state,
                batch,
                mesh=mesh,
                microbatches=microbatches,
                donate=donate,
                st_specs=st_specs,
                b_specs=b_specs,
                rng=jax.random.fold_in(key, 0x5eed),
            )
        t0 = time.perf_counter()
        with mesh_ctx:
            state, mets = step_fn(state, batch)
        jax.block_until_ready(mets["loss"])
        log.record(mets, time.perf_counter() - t0)
        if log_every and i % log_every == 0:
            print(
                f"step {i:5d} loss {log.losses[-1]:.4f} ppl {log.perplexities[-1]:.2f}"
                + (
                    f" maxvio {log.max_vio_steps[-1].max():.3f}"
                    if log.max_vio_steps
                    else ""
                )
            )
        if manager is not None and ckpt_every and (i + 1) % ckpt_every == 0:
            manager.save_train_state(
                state,
                data_state=batches.state_dict() if is_stream else None,
                block=not async_ckpt,
            )
            saved_at = i
    if manager is not None and ckpt_every and saved_at != i:
        manager.save_train_state(  # final state, off-boundary stop
            state,
            data_state=batches.state_dict() if is_stream else None,
            block=not async_ckpt,
        )
    if manager is not None:
        manager.wait()  # checkpoints durable before the loop returns
    if hasattr(batches, "close"):
        batches.close()  # stop a Prefetcher's producer on early break
    return state, log


def evaluate_ppl(model: Model, state: TrainState, batches) -> float:
    """Test perplexity, routing states frozen (read-only copy per batch).

    Per-batch CE means are weighted by each batch's valid-token count, so
    ragged final batches / masked labels don't skew the corpus perplexity."""
    ces, ns = [], []
    loss_fn = jax.jit(model.loss_fn)
    for batch in batches:
        _, (_, mets) = loss_fn(state.params, batch, state.router_states)
        ces.append(float(mets["ce_loss"]))
        ns.append(int(np.sum(np.asarray(batch["labels"]) >= 0)))
    return float(np.exp(np.average(ces, weights=ns)))
