"""Training loop: TrainState, jit'd train_step factory, host-side driver.

The train step threads three pytrees: params, optimizer state, and the
per-MoE-layer router states (the BIP dual vector q / Loss-Free bias). The
host loop accumulates the paper's balance measurements (per-batch MaxVio per
layer -> AvgMaxVio / SupMaxVio) via BalanceTracker — exactly the quantities
in the paper's Tables 2-5.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import BalanceTracker
from repro.models.model import Model
from repro.optim import adamw as _adamw


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    router_states: Any


def init_train_state(model: Model, key, opt_cfg: _adamw.AdamWConfig) -> TrainState:
    params = model.init(key)
    return TrainState(
        params=params,
        opt_state=_adamw.adamw_init(params, opt_cfg),
        router_states=model.init_router_states(),
    )


def make_train_step(
    model: Model,
    opt_cfg: _adamw.AdamWConfig,
    lr_fn: Callable[[jnp.ndarray], jnp.ndarray],
):
    """Returns train_step(state, batch) -> (state, metrics). Pure; jit-ready."""

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        (loss, (new_router, mets)), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True
        )(state.params, batch, state.router_states)
        lr = lr_fn(state.opt_state["step"].astype(jnp.float32))
        new_params, new_opt, info = _adamw.adamw_update(
            grads, state.opt_state, state.params, lr, opt_cfg
        )
        mets = dict(mets)
        mets.update(loss=loss, **info)
        return (
            TrainState(params=new_params, opt_state=new_opt, router_states=new_router),
            mets,
        )

    return train_step


@dataclasses.dataclass
class TrainLog:
    """Host-side record of one run, including the paper's balance metrics."""

    losses: List[float] = dataclasses.field(default_factory=list)
    perplexities: List[float] = dataclasses.field(default_factory=list)
    step_times: List[float] = dataclasses.field(default_factory=list)
    max_vio_steps: List[np.ndarray] = dataclasses.field(default_factory=list)
    per_layer: List[BalanceTracker] = dataclasses.field(default_factory=list)
    model_tracker: BalanceTracker = dataclasses.field(default_factory=BalanceTracker)

    def record(self, mets: Dict[str, Any], dt: float) -> None:
        self.losses.append(float(mets["ce_loss"]))
        self.perplexities.append(float(mets["perplexity"]))
        self.step_times.append(dt)
        vios = np.asarray(mets.get("max_vio_per_layer", np.zeros(0)))
        if vios.size:
            self.max_vio_steps.append(vios)
            if not self.per_layer:
                self.per_layer = [BalanceTracker() for _ in range(vios.size)]
            for t, v in zip(self.per_layer, vios):
                t.add(float(v))
            # model-level MaxVio for the batch = max over layers (conservative)
            self.model_tracker.add(float(vios.max()))

    def summary(self) -> Dict[str, Any]:
        out = {
            "final_loss": self.losses[-1] if self.losses else None,
            "final_ppl": self.perplexities[-1] if self.perplexities else None,
            "mean_step_time": float(np.mean(self.step_times[2:]))
            if len(self.step_times) > 2
            else None,
            **self.model_tracker.summary(),
        }
        if self.per_layer:
            out["AvgMaxVio_per_layer"] = [t.avg_max_vio for t in self.per_layer]
        return out


def train_loop(
    model: Model,
    batches: Iterable[Dict[str, jnp.ndarray]],
    *,
    key=None,
    lr: float = 3e-4,
    warmup_steps: int = 20,
    total_steps: int = 200,
    opt_overrides: Optional[Dict] = None,
    log_every: int = 0,
    state: Optional[TrainState] = None,
) -> Tuple[TrainState, TrainLog]:
    from repro.optim.schedules import linear_warmup_cosine

    key = key if key is not None else jax.random.PRNGKey(0)
    opt_cfg = _adamw.from_model_config(model.cfg, **(opt_overrides or {}))
    if state is None:
        state = init_train_state(model, key, opt_cfg)
    step_fn = jax.jit(
        make_train_step(model, opt_cfg, linear_warmup_cosine(lr, warmup_steps, total_steps))
    )
    log = TrainLog()
    for i, batch in enumerate(batches):
        t0 = time.perf_counter()
        state, mets = step_fn(state, batch)
        jax.block_until_ready(mets["loss"])
        log.record(mets, time.perf_counter() - t0)
        if log_every and i % log_every == 0:
            print(
                f"step {i:5d} loss {log.losses[-1]:.4f} ppl {log.perplexities[-1]:.2f}"
                + (
                    f" maxvio {log.max_vio_steps[-1].max():.3f}"
                    if log.max_vio_steps
                    else ""
                )
            )
    return state, log


def evaluate_ppl(model: Model, state: TrainState, batches) -> float:
    """Test perplexity, routing states frozen (read-only copy per batch).

    Per-batch CE means are weighted by each batch's valid-token count, so
    ragged final batches / masked labels don't skew the corpus perplexity."""
    ces, ns = [], []
    loss_fn = jax.jit(model.loss_fn)
    for batch in batches:
        _, (_, mets) = loss_fn(state.params, batch, state.router_states)
        ces.append(float(mets["ce_loss"]))
        ns.append(int(np.sum(np.asarray(batch["labels"]) >= 0)))
    return float(np.exp(np.average(ces, weights=ns)))
