"""Training harness: TrainState, sharded/donated/microbatched train step,
checkpointed host-side driver.

The train step threads three pytrees: params, optimizer state, and the
per-MoE-layer router states (the BIP dual vector q / Loss-Free bias). The
host loop accumulates the paper's balance measurements (per-batch MaxVio per
layer -> AvgMaxVio / SupMaxVio) via BalanceTracker — exactly the quantities
in the paper's Tables 2-5.

Production shape (DESIGN.md §Training):

* **Sharding** — `compile_train_step(..., mesh=...)` resolves explicit
  `in_shardings`/`out_shardings` for every TrainState leaf and batch tensor
  from `repro.distributed.sharding` (FSDP params over the data axes, tensor/
  expert parallelism over 'model', replicated router duals) so GSPMD never
  has to guess a layout for the optimizer update.
* **Donation** — the TrainState argument is donated (`donate_argnums=(0,)`):
  params/mu/nu buffers are updated in place, so a step's live memory is one
  copy of the state plus transients, not two.
* **Mixed precision** — master params and Adam moments stay fp32 (or the
  per-config `adam_*_dtype` policy); the forward/backward computes in
  `cfg.compute_dtype` (bf16 for the full-size configs) because every weight
  is cast at its use site inside the model. Gradients therefore come back in
  the fp32 master dtype and the update math runs in fp32 (`optim.adamw`).
* **Gradient accumulation** — `microbatches=k` reshapes the global batch to
  (k, B/k, ...) and runs a `lax.scan` of forward/backward per microbatch,
  accumulating gradients in the parameter dtype; router states thread
  *sequentially* through microbatches (the BIP dual price q updates between
  microbatches, exactly as it would across smaller true steps).
* **Router dual sync** — `cfg.routing.sync` rides into the compiled sharded
  step through the model: 'global' makes every BIP gate run the fused
  multi-threshold dual update with psum'd counts over the mesh's data axes
  inside the step (`ref_bip.bip_dual_update_global`), so the carried q is
  the single-device paper trajectory; 'local' solves per-shard duals and
  pmean-averages them into the warm start (DESIGN.md §Global-sync). The
  replicated router-state sharding spec
  (`distributed.sharding.router_state_specs`) is the same either way, and
  covers every state leaf — including the dual-forecaster EMAs
  ('q_ema'/'q_err') that `cfg.routing.forecast` adds, which thread through
  microbatches and steps exactly like q.
* **Checkpointing** — `train_loop(ckpt_dir=..., ckpt_every=N, resume=True)`
  saves the full TrainState (params, Adam moments, step counter, router
  states — the dual q plus, under `cfg.routing.forecast`, the forecaster
  EMAs) through `checkpoint.store` and resumes bit-exactly: the data
  stream is deterministic per step index and the forecaster state restores
  with the duals, so a restored run replays the remaining schedule on
  identical batches with identical warm-start brackets.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import BalanceTracker
from repro.models.model import Model
from repro.optim import adamw as _adamw
from repro.telemetry.metrics import MetricSeries, TrainTelemetry


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    router_states: Any


def init_train_state(model: Model, key, opt_cfg: _adamw.AdamWConfig) -> TrainState:
    params = model.init(key)
    return TrainState(
        params=params,
        opt_state=_adamw.adamw_init(params, opt_cfg),
        router_states=model.init_router_states(),
    )


def _split_micro(batch: Dict[str, jnp.ndarray], k: int) -> Dict[str, jnp.ndarray]:
    return jax.tree.map(
        lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]), batch
    )


def _reduce_micro_mets(mets: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """Collapse (k, ...)-stacked per-microbatch metrics to per-step values.

    MaxVio is reduced with max (the conservative per-step number: the worst
    microbatch — matches SupMaxVio semantics); dispatch counts SUM (the
    step's total per-expert load, keeping integer dtype); state-magnitude
    telemetry (dual |q|, forecaster error) takes the LAST microbatch — the
    carried state after the step, matching what a ckpt would hold; scalars
    average; perplexity is recomputed from the averaged CE so it stays
    exp(mean nll)."""
    out = {}
    for name, v in mets.items():
        if name == "max_vio_per_layer":
            out[name] = jnp.max(v, axis=0)
        elif name == "load_per_layer":
            out[name] = jnp.sum(v, axis=0)
        elif name in ("q_abs_max_per_layer", "forecast_err_per_layer"):
            out[name] = v[-1]
        elif name != "perplexity":
            out[name] = jnp.mean(v, axis=0)
    if "ce_loss" in out:
        out["perplexity"] = jnp.exp(out["ce_loss"])
    return out


# control-vector layout for the guarded train step: a (3,) float32 array of
# per-step scalars the host can set without recompiling.
CTRL_INJECT_NAN = 0  # > 0: fault injection — scale the loss (hence grads) by NaN
CTRL_FORCE_SKIP = 1  # > 0: select the pre-step state (planned skip / replay)
CTRL_LR_SCALE = 2    # multiplier on the scheduled LR (guard's reduce-LR ladder)


def default_controls() -> np.ndarray:
    return np.array([0.0, 0.0, 1.0], np.float32)


def make_train_step(
    model: Model,
    opt_cfg: _adamw.AdamWConfig,
    lr_fn: Callable[[jnp.ndarray], jnp.ndarray],
    *,
    microbatches: int = 1,
    rng: Optional[jnp.ndarray] = None,
    guarded: bool = False,
):
    """Returns train_step(state, batch) -> (state, metrics). Pure; jit-ready.

    With microbatches=k the batch's leading axis must divide by k; the
    forward/backward runs as a k-trip lax.scan with gradient accumulation so
    the residual/activation footprint is that of B/k sequences.

    `rng` (optional) is a base PRNG key; each step derives its key by
    folding in the optimizer's step counter (and the microbatch index under
    accumulation), so the per-step randomness seen by dropout-style
    regularizers is a pure function of checkpointed state — resume-stable
    by construction.

    `guarded=True` changes the signature to train_step(state, batch,
    controls) with `controls` a (3,) float32 vector (see CTRL_*), and adds
    the in-graph anomaly guard: `step_ok = isfinite(loss) &
    isfinite(grad_norm) & ~force_skip`, with EVERY output leaf (params,
    Adam moments incl. the step counter, router states) selected back to
    its pre-step value when false. A NaN/Inf step therefore cannot poison
    the state, and a skipped step is bit-identical to the step never having
    run — the invariant the rollback-recovery determinism test relies on.
    Metrics gain 'step_ok'.
    """

    def _fwd_bwd(params, batch, router, key, nan_coef=None):
        def f(p):
            loss, aux = model.loss_fn(p, batch, router, key)
            if nan_coef is not None:
                # fault seam (robustness/faults.NanGrad): nan_coef is 1.0
                # normally, NaN when the injector fires — grads = coef * dL
                loss = loss * nan_coef
            return loss, aux

        with jax.named_scope("train/fwd_bwd"):
            return jax.value_and_grad(f, has_aux=True)(params)

    def _apply(state: TrainState, grads, new_router, mets, lr_scale=None):
        lr = lr_fn(state.opt_state["step"].astype(jnp.float32))
        if lr_scale is not None:
            lr = lr * lr_scale
        with jax.named_scope("train/apply"):
            new_params, new_opt, info = _adamw.adamw_update(
                grads, state.opt_state, state.params, lr, opt_cfg
            )
        mets = dict(mets)
        mets.update(info)
        return (
            TrainState(params=new_params, opt_state=new_opt, router_states=new_router),
            mets,
        )

    def _run(state: TrainState, batch: Dict[str, jnp.ndarray], nan_coef, lr_scale):
        step_key = (
            None if rng is None else jax.random.fold_in(rng, state.opt_state["step"])
        )
        if microbatches <= 1:
            (loss, (new_router, mets)), grads = _fwd_bwd(
                state.params, batch, state.router_states, step_key, nan_coef
            )
            mets = dict(mets)
            mets["loss"] = loss
            return _apply(state, grads, new_router, mets, lr_scale)

        mb = _split_micro(batch, microbatches)
        # accumulate in the parameter dtype: fp32 accumulation doubles the
        # carry footprint for bf16-param models (arctic) with negligible
        # benefit at <=16 microbatches
        acc_dt = model.cfg.param_dtype

        def body(carry, inp):
            one, mb_idx = inp
            grads_acc, router = carry
            key = None if step_key is None else jax.random.fold_in(step_key, mb_idx)
            (loss, (router, mets)), grads = _fwd_bwd(
                state.params, one, router, key, nan_coef
            )
            grads_acc = jax.tree.map(
                lambda a, g: a + g.astype(acc_dt), grads_acc, grads
            )
            mets = dict(mets)
            mets["loss"] = loss
            return (grads_acc, router), mets

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), state.params)
        (grads, new_router), mets = jax.lax.scan(
            body, (zero, state.router_states), (mb, jnp.arange(microbatches))
        )
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        return _apply(state, grads, new_router, _reduce_micro_mets(mets), lr_scale)

    if not guarded:

        def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
            return _run(state, batch, None, None)

        return train_step

    def guarded_step(
        state: TrainState, batch: Dict[str, jnp.ndarray], controls: jnp.ndarray
    ):
        controls = controls.astype(jnp.float32)
        nan_coef = jnp.where(controls[CTRL_INJECT_NAN] > 0, jnp.nan, 1.0)
        new_state, mets = _run(
            state, batch, nan_coef, controls[CTRL_LR_SCALE]
        )
        ok = (
            jnp.isfinite(mets["loss"])
            & jnp.isfinite(mets["grad_norm"])
            & (controls[CTRL_FORCE_SKIP] <= 0)
        )
        # anomaly => keep the PRE-step state for every leaf (params, Adam
        # moments + step counter, router duals/forecaster): elementwise
        # select, so donation aliasing still holds and a healthy step pays
        # one predicated copy
        final = jax.tree.map(
            lambda new, old: jnp.where(ok, new, old), new_state, state
        )
        mets["step_ok"] = ok
        return final, mets

    return guarded_step


def compile_train_step(
    model: Model,
    opt_cfg: _adamw.AdamWConfig,
    lr_fn,
    state: TrainState,
    batch: Dict[str, Any],
    *,
    mesh=None,
    microbatches: int = 1,
    donate: bool = True,
    st_specs=None,
    b_specs=None,
    rng: Optional[jnp.ndarray] = None,
    guarded: bool = False,
    telemetry: Optional[TrainTelemetry] = None,
):
    """jit the train step, with explicit shardings when a mesh is given.

    `state`/`batch` may be concrete arrays or ShapeDtypeStructs — only their
    tree structure and shapes are consulted. On a mesh, every TrainState leaf
    and batch tensor gets the PartitionSpec from `distributed.sharding` as an
    explicit in/out sharding (out == in, so the donated buffers alias
    leaf-for-leaf and the state layout is fixed-point across steps); metrics
    come back replicated. Callers that already resolved the spec trees (e.g.
    train_loop, which also places the arrays with them) pass st_specs /
    b_specs so there is one resolution per run.

    `guarded=True` compiles the 3-arg guarded step (see make_train_step);
    the control vector is replicated on a mesh.

    `telemetry` (a TrainTelemetry) instruments the step: the metric layout
    is derived via `jax.eval_shape` on the UN-instrumented step, and the
    compiled signature gains two trailing args — the in-graph MetricStream
    buffer and the step index — returning (state, mets, buffer). The
    buffer is NOT donated (the host holds async copies of drained windows)
    and is replicated on a mesh; every scattered value is one the step
    already computed, so instrumentation adds no collectives and no syncs.
    """
    step = make_train_step(
        model, opt_cfg, lr_fn, microbatches=microbatches, rng=rng, guarded=guarded
    )
    donate_argnums = (0,) if donate else ()

    raw_step = step
    if telemetry is not None:
        eval_args = (state, batch)
        if guarded:
            eval_args = eval_args + (jax.ShapeDtypeStruct((3,), jnp.float32),)
        _, mets_shapes = jax.eval_shape(raw_step, *eval_args)
        telemetry.ensure_built(mets_shapes)
        stream = telemetry.stream

        def step(*args):
            *inner, buf, step_idx = args
            new_state, mets = raw_step(*inner)
            buf = stream.accumulate(buf, mets, step_idx)
            return new_state, mets, buf

    if mesh is None:
        return jax.jit(step, donate_argnums=donate_argnums)

    from jax.sharding import NamedSharding, PartitionSpec

    from repro.distributed.sharding import batch_specs, train_state_specs

    if st_specs is None:
        st_specs = train_state_specs(state, model.cfg, mesh)
    if b_specs is None:
        b_all = batch_specs(model.cfg, mesh, jax.tree.leaves(batch)[0].shape[0])
        b_specs = {k: b_all[k] for k in batch}
    as_sharding = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec),
    )
    repl = NamedSharding(mesh, PartitionSpec())
    in_shardings = (as_sharding(st_specs), as_sharding(b_specs))
    if guarded:
        in_shardings = in_shardings + (repl,)
    out_shardings = (as_sharding(st_specs), None)
    if telemetry is not None:
        buf_shardings = jax.tree.map(lambda _: repl, telemetry.buf)
        in_shardings = in_shardings + (buf_shardings, repl)
        out_shardings = out_shardings + (buf_shardings,)
    return jax.jit(
        step,
        in_shardings=in_shardings,
        out_shardings=out_shardings,
        donate_argnums=donate_argnums,
    )


class TrainLog:
    """Host-side record of one run, including the paper's balance metrics.

    Backed by one `telemetry.MetricSeries` column store instead of the
    historical parallel lists; `losses` / `perplexities` / `step_times` /
    `max_vio_steps` survive as read-only views so every existing caller
    (tests, benchmarks, launchers) keeps working unchanged. `events` stays
    a plain settable list — the guard ladder assigns it wholesale.
    """

    def __init__(self) -> None:
        self.series = MetricSeries()
        self.per_layer: List[BalanceTracker] = []
        self.model_tracker: BalanceTracker = BalanceTracker()
        self.events: List[Dict[str, Any]] = []

    def __len__(self) -> int:
        return len(self.series)

    @property
    def losses(self) -> List[float]:
        return list(self.series.column("ce_loss"))

    @property
    def perplexities(self) -> List[float]:
        return list(self.series.column("perplexity"))

    @property
    def step_times(self) -> List[float]:
        return list(self.series.column("step_time"))

    @property
    def max_vio_steps(self) -> List[np.ndarray]:
        return [v for v in self.series.column("max_vio") if v is not None]

    def truncate(self, n: int) -> None:
        """Drop records past the first `n` steps and rebuild the balance
        trackers from the survivors — a rollback rewinds the log so replayed
        steps are not double-counted in AvgMaxVio/SupMaxVio."""
        self.series.truncate(max(0, n))
        self.per_layer = []
        self.model_tracker = BalanceTracker()
        for vios in self.max_vio_steps:
            if not self.per_layer:
                self.per_layer = [BalanceTracker() for _ in range(vios.size)]
            for t, v in zip(self.per_layer, vios):
                t.add(float(v))
            self.model_tracker.add(float(vios.max()))

    def record(self, mets: Dict[str, Any], dt: float) -> None:
        rec: Dict[str, Any] = {
            "ce_loss": float(mets["ce_loss"]),
            "perplexity": float(mets["perplexity"]),
            "step_time": dt,
        }
        vios = np.asarray(mets.get("max_vio_per_layer", np.zeros(0)))
        if vios.size:
            rec["max_vio"] = vios
            if not self.per_layer:
                self.per_layer = [BalanceTracker() for _ in range(vios.size)]
            for t, v in zip(self.per_layer, vios):
                t.add(float(v))
            # model-level MaxVio for the batch = max over layers (conservative)
            self.model_tracker.add(float(vios.max()))
        self.series.append(rec)

    def summary(self) -> Dict[str, Any]:
        times = self.step_times
        out = {
            "final_loss": self.losses[-1] if len(self.series) else None,
            "final_ppl": self.perplexities[-1] if len(self.series) else None,
            "mean_step_time": None,
            "step_time_p50": None,
            "step_time_p99": None,
            **self.model_tracker.summary(),
        }
        if len(times) > 2:
            # skip the first two steps (compile + warm caches) so the
            # quantiles describe steady-state throughput
            steady = np.asarray(times[2:], dtype=np.float64)
            out["mean_step_time"] = float(steady.mean())
            out["step_time_p50"] = float(np.percentile(steady, 50))
            out["step_time_p99"] = float(np.percentile(steady, 99))
        if self.per_layer:
            out["AvgMaxVio_per_layer"] = [t.avg_max_vio for t in self.per_layer]
        if self.events:
            out["guard_events"] = list(self.events)
        return out


def train_loop(
    model: Model,
    batches: Iterable[Dict[str, jnp.ndarray]],
    *,
    key=None,
    lr: float = 3e-4,
    warmup_steps: int = 20,
    total_steps: int = 200,
    opt_overrides: Optional[Dict] = None,
    log_every: int = 0,
    state: Optional[TrainState] = None,
    mesh=None,
    microbatches: int = 1,
    donate: bool = True,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 0,
    resume: bool = False,
    async_ckpt: bool = True,
    guard=None,
    faults=None,
    telemetry: Optional[TrainTelemetry] = None,
) -> Tuple[TrainState, TrainLog]:
    """Host driver. With `mesh` the state/batches are placed with the specs
    from `distributed.sharding` and the step compiles with explicit
    shardings + donation; without one it is the plain single-device jit.

    `batches` is any iterable of batch dicts; when it is a `BatchStream`
    (has state_dict/load_state_dict — `data.ShardedTextLoader`,
    `data.SyntheticBatchStream`, or a `data.Prefetcher` around either),
    its cursor is checkpointed alongside the TrainState and `resume=True`
    seeks it in O(1) instead of regenerating + discarding the consumed
    prefix. Plain iterables keep the replay-skip fallback.

    Checkpoints are written asynchronously by default (`async_ckpt=True`):
    the save snapshots device buffers and overlaps the host gather + npz
    write with the next steps, barriering at the following save
    (checkpoint/store.py). Iteration stops at `total_steps` even when the
    stream is infinite (real-corpus loaders loop epochs forever).

    `resume=True` restores the newest VALID checkpoint under `ckpt_dir`
    (corrupt/truncated files are skipped with a warning) and continues
    bit-exactly — including the router duals q and the data cursor.

    Robustness (DESIGN.md §Robustness):

    * `guard` (a `robustness.GuardConfig`) compiles the guarded step —
      non-finite loss/grads leave the state bit-untouched — and runs the
      host-side skip -> reduce-LR -> rollback ladder. A rollback restores
      the newest valid checkpoint, rewinds the data cursor through the
      stream's `load_state_dict`, truncates the log, and replays; the
      anomalous step is force-skipped on replay, so recovery is
      deterministic (bit-identical to a run that skipped the step
      in place). Rollback requires a checkpoint manager AND a rewindable
      BatchStream; without them the ladder raises `TrainingDiverged`.
    * `faults` (a `robustness.FaultPlan`) drives the injection seams: the
      NaN scalar into the guarded step, and post-save checkpoint
      corruption for chaos tests.
    * SIGTERM (preemption) triggers one final SYNCHRONOUS checkpoint and a
      clean return — installed only on the main thread and restored on
      exit.

    `telemetry` (a `telemetry.TrainTelemetry`) threads the in-graph metric
    buffer through the compiled step, records per-step wall time, drains
    windows asynchronously to the sink, and streams guard/fault/lifecycle
    events as they happen. The partial final window is flushed in the
    `finally` block; closing the sink is the caller's job.
    """
    from repro.optim.schedules import linear_warmup_cosine

    key = key if key is not None else jax.random.PRNGKey(0)
    opt_cfg = _adamw.from_model_config(model.cfg, **(opt_overrides or {}))

    manager = None
    if ckpt_dir is not None:
        from repro.checkpoint import CheckpointManager

        manager = CheckpointManager(ckpt_dir)

    is_stream = hasattr(batches, "state_dict") and hasattr(batches, "load_state_dict")
    start_step = 0
    data_state = None
    if resume and manager is not None and state is None:
        from repro.checkpoint.store import latest_step

        if latest_step(ckpt_dir) is not None:
            start_step, state = manager.restore_train_state()
            data_state = manager.restore_data_state(start_step)
    if state is None:
        state = init_train_state(model, key, opt_cfg)

    loop_start = 0  # index the enumerate starts at
    if is_stream and data_state is not None:
        batches.load_state_dict(data_state)  # O(1) seek past the consumed prefix
        loop_start = start_step

    st_specs = b_specs = None
    if mesh is not None:
        from repro.distributed.sharding import (
            batch_specs,
            shard_tree,
            train_state_specs,
        )

        st_specs = train_state_specs(state, model.cfg, mesh)
        state = shard_tree(state, st_specs, mesh)

    guarded = guard is not None or (faults is not None and faults.get("nan_grad"))
    tguard = None
    if guarded:
        from repro.robustness.guards import ROLLBACK, GuardConfig, TrainGuard

        tguard = TrainGuard(
            guard if guard is not None else GuardConfig(),
            can_rollback=manager is not None and is_stream and ckpt_every > 0,
        )

    # preemption safety: SIGTERM requests one final synchronous checkpoint.
    # Signal handlers are a main-thread-only facility; elsewhere (e.g. a
    # train_loop driven from a worker thread in tests) the flag stays False.
    import signal as _signal
    import threading as _threading

    sig_flag = {"term": False}
    prev_handler = None
    hook_signal = (
        manager is not None
        and _threading.current_thread() is _threading.main_thread()
    )
    if hook_signal:
        prev_handler = _signal.getsignal(_signal.SIGTERM)
        _signal.signal(_signal.SIGTERM, lambda *_: sig_flag.update(term=True))

    step_fn = None
    log = TrainLog()
    mesh_ctx = mesh if mesh is not None else contextlib.nullcontext()
    saved_at = -1

    emitted = {"n": 0}

    def _stream_events() -> None:
        # forward newly appended guard-ladder events to the telemetry sink
        # exactly once each, in order
        if telemetry is None or tguard is None:
            return
        while emitted["n"] < len(tguard.events):
            telemetry.event(dict(tguard.events[emitted["n"]]))
            emitted["n"] += 1

    def _save(block: bool) -> Optional[str]:
        path = manager.save_train_state(
            state,
            data_state=batches.state_dict() if is_stream else None,
            block=block,
        )
        if faults is not None and faults.get("ckpt_corrupt") is not None:
            manager.wait()  # the file must be fully written before corrupting
            if faults.corrupt_after_save(path):
                ev = {"step": i, "kind": "ckpt_corrupted", "path": path}
                log.events.append(ev)
                if telemetry is not None:
                    telemetry.event(ev)
        return path

    try:
        it = iter(batches)
        i = loop_start - 1
        while True:
            # bound infinite streams (epoch-looping corpus loaders) *before*
            # pulling: the stream cursor must stay in sync with the step count,
            # so never consume a batch that won't be trained on
            if total_steps and i + 1 >= total_steps:
                break
            try:
                batch = next(it)
            except StopIteration:
                break
            i += 1
            if i < start_step:
                continue  # resumed plain iterable: replay-skip the consumed prefix
            if mesh is not None:
                if b_specs is None:
                    b_all = batch_specs(
                        model.cfg, mesh, jax.tree.leaves(batch)[0].shape[0]
                    )
                    b_specs = {k: b_all[k] for k in batch}
                batch = shard_tree(batch, b_specs, mesh)
            if step_fn is None:
                step_fn = compile_train_step(
                    model,
                    opt_cfg,
                    linear_warmup_cosine(lr, warmup_steps, total_steps),
                    state,
                    batch,
                    mesh=mesh,
                    microbatches=microbatches,
                    donate=donate,
                    st_specs=st_specs,
                    b_specs=b_specs,
                    rng=jax.random.fold_in(key, 0x5eed),
                    guarded=bool(guarded),
                    telemetry=telemetry,
                )
            if telemetry is not None:
                telemetry.before_step(i)  # profiler window, if configured
            t0 = time.perf_counter()
            step_args = (state, batch)
            if guarded:
                force_skip, lr_scale = tguard.controls(i)
                inject = faults is not None and faults.nan_fires(i)
                controls = jnp.asarray(
                    [float(inject), float(force_skip), lr_scale], jnp.float32
                )
                step_args = step_args + (controls,)
            if telemetry is not None:
                step_args = step_args + (telemetry.buf, jnp.asarray(i, jnp.int32))
                with mesh_ctx:
                    state, mets, tbuf = step_fn(*step_args)
            else:
                with mesh_ctx:
                    state, mets = step_fn(*step_args)
            jax.block_until_ready(mets["loss"])
            dt = time.perf_counter() - t0
            if telemetry is not None:
                telemetry.note_step_time(i, dt)
                # adopt before guard observation so an anomalous step's row
                # is captured even when the guard rolls back past it
                telemetry.after_step(i, tbuf)
            if guarded:
                action = tguard.observe(  # raises TrainingDiverged on RAISE
                    i, float(mets["loss"]), bool(mets["step_ok"])
                )
                log.events = tguard.events
                _stream_events()
                if action == ROLLBACK:
                    r_step, state = manager.restore_train_state()
                    ds = manager.restore_data_state(r_step)
                    if ds is None:
                        from repro.robustness.guards import TrainingDiverged

                        raise TrainingDiverged(
                            f"rollback to step {r_step}: checkpoint has no "
                            f"data cursor to rewind the stream with"
                        )
                    if hasattr(batches, "close"):
                        batches.close()  # a Prefetcher must re-arm post-rewind
                    batches.load_state_dict(ds)
                    it = iter(batches)
                    if mesh is not None:
                        state = shard_tree(state, st_specs, mesh)
                    log.truncate(r_step - loop_start)
                    log.events = tguard.events
                    _stream_events()
                    if telemetry is not None:
                        telemetry.event(
                            {"step": i, "kind": "rollback_replay", "to_step": r_step}
                        )
                    start_step = 0  # a fallback restore may predate `resume`
                    i = r_step - 1
                    if log_every:
                        print(f"rollback -> step {r_step} (replaying)")
                    continue
            log.record(mets, dt)
            if log_every and i % log_every == 0:
                print(
                    f"step {i:5d} loss {log.losses[-1]:.4f} "
                    f"ppl {log.perplexities[-1]:.2f}"
                    + (
                        f" maxvio {log.max_vio_steps[-1].max():.3f}"
                        if log.max_vio_steps
                        else ""
                    )
                )
            if manager is not None and ckpt_every and (i + 1) % ckpt_every == 0:
                _save(block=not async_ckpt)
                saved_at = i
            if sig_flag["term"]:
                # preemption: make the state durable NOW, synchronously
                _save(block=True)
                saved_at = i
                ev = {"step": i, "kind": "sigterm_checkpoint"}
                log.events.append(ev)
                if telemetry is not None:
                    telemetry.event(ev)
                break
        if manager is not None and ckpt_every and saved_at != i:
            _save(block=not async_ckpt)  # final state, off-boundary stop
    finally:
        if telemetry is not None:
            telemetry.finish()  # partial window + outstanding async copies
        if hook_signal:
            _signal.signal(_signal.SIGTERM, prev_handler)
        if manager is not None:
            manager.wait()  # checkpoints durable before the loop returns
        if hasattr(batches, "close"):
            batches.close()  # stop a Prefetcher's producer on early break
    return state, log


def evaluate_ppl(model: Model, state: TrainState, batches) -> float:
    """Test perplexity, routing states frozen (read-only copy per batch).

    Per-batch CE means are weighted by each batch's valid-token count, so
    ragged final batches / masked labels don't skew the corpus perplexity."""
    ces, ns = [], []
    loss_fn = jax.jit(model.loss_fn)
    for batch in batches:
        _, (_, mets) = loss_fn(state.params, batch, state.router_states)
        ces.append(float(mets["ce_loss"]))
        ns.append(int(np.sum(np.asarray(batch["labels"]) >= 0)))
    return float(np.exp(np.average(ces, weights=ns)))
