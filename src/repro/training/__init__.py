"""repro.training — TrainState and the training loop."""
from repro.training.loop import TrainState, make_train_step, train_loop

__all__ = ["TrainState", "make_train_step", "train_loop"]
