"""repro.training — TrainState and the training harness."""
from repro.training.loop import (
    TrainState,
    compile_train_step,
    init_train_state,
    make_train_step,
    train_loop,
)

__all__ = [
    "TrainState",
    "compile_train_step",
    "init_train_state",
    "make_train_step",
    "train_loop",
]
