"""repro.optim — AdamW + schedules, pure JAX (no optax dependency)."""
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from repro.optim.schedules import constant, cosine_schedule, linear_warmup_cosine

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "constant",
    "cosine_schedule",
    "global_norm",
    "linear_warmup_cosine",
]
