"""AdamW with per-config dtype policy and global-norm clipping.

Optimizer state dtypes are configurable per model (ModelConfig.adam_mu_dtype /
adam_nu_dtype): arctic-480b uses bf16 mu to fit 16 GB/chip on one pod
(DESIGN.md §6). State is a pytree mirroring params:
    {'step': (), 'mu': tree, 'nu': tree}
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    mu_dtype: Any = jnp.float32
    nu_dtype: Any = jnp.float32


def _dtype(name: str):
    return jnp.bfloat16 if name == "bf16" else jnp.float32


def from_model_config(cfg, **overrides) -> AdamWConfig:
    return AdamWConfig(
        mu_dtype=_dtype(cfg.adam_mu_dtype),
        nu_dtype=_dtype(cfg.adam_nu_dtype),
        **overrides,
    )


def adamw_init(params, cfg: AdamWConfig) -> Dict[str, Any]:
    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=cfg.mu_dtype), params),
        "nu": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=cfg.nu_dtype), params),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(
    grads,
    opt_state: Dict[str, Any],
    params,
    lr: jnp.ndarray,
    cfg: AdamWConfig,
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """Returns (new_params, new_opt_state, info)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm > 0:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd_math(g, mu, nu, p):
        g32 = g.astype(jnp.float32)
        mu_n = b1 * mu.astype(jnp.float32) + (1 - b1) * g32
        nu_n = b2 * nu.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = mu_n / c1
        vhat = nu_n / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2 and cfg.weight_decay > 0:  # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_n = p.astype(jnp.float32) - lr * delta
        return p_n.astype(p.dtype), mu_n.astype(mu.dtype), nu_n.astype(nu.dtype)

    # elementwise chains fuse in XLA, so whole-leaf updates do NOT
    # materialize f32 intermediates; keeping them whole also preserves
    # donation aliasing of params/mu/nu (measured: slicing the update into a
    # lax.map COSTS ~11 GB on arctic-480b by breaking aliasing)
    out = jax.tree.map(upd_math, grads, opt_state["mu"], opt_state["nu"], params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return (
        new_params,
        {"step": step, "mu": new_mu, "nu": new_nu},
        {"grad_norm": gnorm, "lr": lr},
    )
