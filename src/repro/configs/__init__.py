"""Config registry: 10 assigned architectures + the paper's two minimind MoEs.

Each module defines CONFIG (exact published dims, source cited) and the
registry exposes get(name) / reduced_for_smoke(name).
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig, RoutingSpec, SSMSpec, reduced

ARCH_IDS = [
    "zamba2_7b",
    "paligemma_3b",
    "llama4_scout_17b_a16e",
    "deepseek_coder_33b",
    "phi4_mini_3_8b",
    "mamba2_130m",
    "seamless_m4t_large_v2",
    "gemma2_27b",
    "arctic_480b",
    "stablelm_1_6b",
    # the paper's own models (Minimind MoE)
    "minimind_moe_16e",
    "minimind_moe_64e",
]

# external ids (with dashes) as used on the CLI --arch flag
CLI_ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
CLI_ALIASES.update(
    {
        "zamba2-7b": "zamba2_7b",
        "paligemma-3b": "paligemma_3b",
        "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
        "deepseek-coder-33b": "deepseek_coder_33b",
        "phi4-mini-3.8b": "phi4_mini_3_8b",
        "mamba2-130m": "mamba2_130m",
        "seamless-m4t-large-v2": "seamless_m4t_large_v2",
        "gemma2-27b": "gemma2_27b",
        "arctic-480b": "arctic_480b",
        "stablelm-1.6b": "stablelm_1_6b",
    }
)


def get(name: str) -> ModelConfig:
    key = CLI_ALIASES.get(name, name)
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(CLI_ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.CONFIG


def reduced_for_smoke(name: str, **overrides) -> ModelConfig:
    return reduced(get(name), **overrides)


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get(a) for a in ARCH_IDS}


__all__ = [
    "ARCH_IDS",
    "CLI_ALIASES",
    "ModelConfig",
    "RoutingSpec",
    "SSMSpec",
    "all_configs",
    "get",
    "reduced",
    "reduced_for_smoke",
]
