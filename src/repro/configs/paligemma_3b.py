"""paligemma-3b [vlm] — SigLIP vision encoder + Gemma-2B decoder
[arXiv:2407.07726]. Backbone: 18L, d_model=2048, 8 heads (GQA kv=1,
head_dim=256), d_ff=16384 (gelu), vocab=257216.

The SigLIP frontend is a stub per the assignment carve-out: `input_specs()`
provides 256 patch embeddings of dim 1152 (224px / 14px patches); the
learned projector and the full language model are real.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    source="[arXiv:2407.07726]",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    act="gelu",
    vocab_size=257216,
    frontend_tokens=256,
    frontend_dim=1152,
    rope_theta=10000.0,
    max_seq_len=32768,
    attn_chunk=512,
)
