"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal translation
[arXiv:2308.11596]. Decoder 24L, d_model=1024, 16 heads (kv=16, head_dim=64),
d_ff=8192, vocab=256206; 24-layer text/speech encoder.

The conformer speech frontend (mel + conv codec) is a stub per the
assignment carve-out: `input_specs()` provides 4096 frame embeddings of
dim 1024; the encoder transformer, cross-attention, and decoder are real.
Dense FFN: BIP inapplicable. 500k-token decode is out of this model's
operating envelope — long_500k skipped (DESIGN.md §Skips).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    source="[arXiv:2308.11596]",
    n_layers=24,
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    enc_seq_len=4096,
    frontend_dim=1024,
    rope_theta=10000.0,
    max_seq_len=32768,
    attn_chunk=512,
)
