"""llama4-scout-17b-a16e [moe] — 16 experts, top-1 routing, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E]. 48L, d_model=5120, 40 heads (GQA
kv=8, head_dim=128), expert d_ff=8192, vocab=202048.

iRoPE layout: chunked-local attention (8192) on 3 of every 4 layers, global
(NoPE-style long-range) every 4th — modeled here as sliding-window 8192
locals + full-attention globals, which is the TPU-friendly equivalent for
decode (DESIGN.md §7). A shared expert runs in parallel with the routed
top-1 expert (llama4 style). This arch is a primary target for the paper's
BIP routing (k=1, m=16).

Dtype policy: fully-bf16 Adam — at 109B total params, fp32 state leaves no
activation headroom on a single v5e-256 pod (dry-run: 18.6 vs 13.4 GB/chip,
EXPERIMENTS.md §Dry-run).
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RoutingSpec

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    source="[hf:meta-llama/Llama-4-Scout-17B-16E]",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    moe_d_ff=8192,
    vocab_size=202048,
    routing=RoutingSpec(
        n_experts=16, top_k=1, strategy="bip", bip_iters=4, capacity_factor=1.25
    ),
    n_shared_experts=1,
    attn_pattern=("local", "local", "local", "global"),
    window_size=8192,
    rope_theta=500000.0,
    max_seq_len=524288,
    attn_chunk=512,
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
    adam_mu_dtype="bf16",
    adam_nu_dtype="bf16",
)
