"""ModelConfig — the single config schema every architecture compiles from.

A config fully determines: parameter shapes, the per-layer kind sequence
(attention variant / dense vs MoE FFN / mamba / shared block), the routing
strategy, sharding logical axes, and dtype policy. One file per assigned
architecture lives next to this module; each cites its source in brackets.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class RoutingSpec:
    """Routing gate settings for MoE layers.

    DEPRECATION NOTE: this spec is now a thin superset of
    `repro.core.types.RouterConfig` — the fields the router consumes are
    converted 1:1 by `to_router_config()` (the ONE conversion point; do not
    hand-copy fields), and validation happens once, in RouterConfig's
    `__post_init__`, via that conversion. Only the model-level knobs that
    RouterConfig has no business knowing (capacity_factor, moe_impl) are
    RoutingSpec's own. New router knobs belong in RouterConfig first;
    mirror them here only when model configs need to set them.
    """

    n_experts: int = 0
    top_k: int = 0
    strategy: str = "bip"          # any registered balancer (core/balancers.py)
    bip_iters: int = 4
    aux_loss_alpha: float = 0.1
    lossfree_lr: float = 0.001
    norm_topk_prob: bool = False
    score_fn: str = "softmax"
    capacity_factor: float = 1.25   # static capacity C = ceil(k·n/m · cf)
    # BIP dual sync across data shards (DESIGN.md §Global-sync):
    # 'local'  per-shard duals, pmean-averaged into the warm start — no
    #          router collectives, balance guaranteed per shard only.
    # 'global' psum'd threshold order statistics: every device converges on
    #          the single-device duals over the global batch
    #          (bisect_rounds(n_bisect, bisect_fanout) fused psums per dual
    #          iteration; 5 at the defaults).
    sync: str = "local"
    use_kernel: bool = False       # Pallas ADMM kernel for the dual update
    # threshold-bisection order statistic (sync='global' / masked paths):
    n_bisect: int = 26             # bits of resolution (bracket width 2^-n_bisect)
    # thresholds per fused round, rounded UP to the next 2^r - 1 (midpoint
    # ladder; 1 = classic bisection):
    bisect_fanout: int = 32
    # dual forecaster (predictive warm-start of the bisection bracket):
    forecast: bool = False
    forecast_decay: float = 0.9    # EMA decay for the statistic and its error
    forecast_margin: float = 4.0   # bracket half-width = margin·EMA|err| + floor
    forecast_floor: float = 1e-3
    # dual-health watchdog: reset a layer's carried q / forecaster EMAs to
    # safe init when any entry is non-finite or |q| > dual_abs_limit
    guard_duals: bool = False
    dual_abs_limit: float = 100.0
    # registry-method knobs (φ-Balancing / Latent Prototype Routing):
    phi_lr: float = 0.01
    lpr_decay: float = 0.99
    lpr_blend: float = 0.5
    # expert-parallel implementation (DESIGN.md §6 / EXPERIMENTS.md §Perf):
    # 'ep2d' gathers activations, weights stay (experts->model, f->data)
    #        sharded; routing sees the full microbatch (paper-global duals).
    # 'ep'   FSDP path: weights gathered over data per layer per microbatch.
    # 'auto' ep2d for small token counts (decode), ep for train/prefill.
    moe_impl: str = "auto"

    def __post_init__(self):
        # one validation path: RouterConfig.__post_init__ (via the
        # conversion shim). Dense configs keep the inert 0-expert default.
        if self.n_experts > 0:
            self.to_router_config()

    def to_router_config(self, data_axes: Sequence[str] = (), **overrides):
        """Convert to the router's RouterConfig (the single mapping point).

        Every field RouterConfig declares that RoutingSpec also carries is
        copied 1:1; `data_axes` (a mesh property, not a model property) and
        any `overrides` (e.g. a serving-time use_kernel) are applied on top.
        """
        import dataclasses as _dc

        from repro.core.types import RouterConfig

        shared = {f.name for f in _dc.fields(RouterConfig)} & {
            f.name for f in _dc.fields(self)
        }
        kw = {name: getattr(self, name) for name in shared}
        kw["data_axes"] = tuple(data_axes)
        kw.update(overrides)
        return RouterConfig(**kw)


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    """Mamba2 / SSD block settings."""

    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity ---------------------------------------------------------------
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | encdec | vlm
    source: str = ""       # citation, e.g. "[arXiv:2401.14196]"

    # trunk ------------------------------------------------------------------
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0          # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    tie_embeddings: bool = True
    rms_norm_eps: float = 1e-6
    act: str = "silu"          # 'silu' (swiglu) | 'gelu' (geglu)

    # attention pattern ---------------------------------------------------
    # Cycled across layers, e.g. ('local', 'global') for gemma2,
    # ('local','local','local','global') for llama4 iRoPE. 'none' = mamba.
    attn_pattern: Tuple[str, ...] = ("global",)
    window_size: int = 0           # sliding window for 'local' layers
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    rope_theta: float = 10000.0
    rope_local_theta: float = 0.0  # separate theta for local layers (gemma2/llama4)
    qk_norm: bool = False
    post_block_norms: bool = False  # gemma2-style post-attn / post-ffn norms

    # MoE ----------------------------------------------------------------
    routing: RoutingSpec = RoutingSpec()
    moe_d_ff: int = 0              # expert hidden dim (0 -> d_ff)
    moe_pattern: Tuple[bool, ...] = (True,)  # cycled: which layers are MoE
    dense_residual: bool = False   # arctic: dense FFN in parallel with MoE
    n_shared_experts: int = 0      # always-on shared experts (minimind/deepseek style)

    # SSM / hybrid ---------------------------------------------------------
    ssm: SSMSpec = SSMSpec()
    # hybrid: a weight-shared (attn+mlp) block applied every `shared_attn_every`
    # backbone layers (zamba2-style).
    shared_attn_every: int = 0

    # encoder (encdec family) ---------------------------------------------
    n_enc_layers: int = 0
    enc_seq_len: int = 0           # encoder stub sequence length (frames)

    # modality frontend stub (vlm / audio) ---------------------------------
    frontend_tokens: int = 0       # patch/frame embeddings prepended (vlm)
    frontend_dim: int = 0          # embedding dim delivered by the stub

    # sequence / serving -----------------------------------------------------
    max_seq_len: int = 8192
    attn_chunk: int = 512          # query-chunk size for memory-tiled attention

    # training memory policy ---------------------------------------------
    # 'none' | 'block': jax.checkpoint around each scanned layer group so
    # backward recomputes activations (required for the big configs at 4k).
    remat: str = "none"

    # dtype policy -----------------------------------------------------------
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    # optimizer state dtypes (see repro.optim): 'fp32' | 'bf16'
    adam_mu_dtype: str = "fp32"
    adam_nu_dtype: str = "fp32"

    # ------------------------------------------------------------------ utils
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.routing.n_experts > 0

    def layer_kinds(self) -> Tuple[Tuple[str, str], ...]:
        """Per-layer (mixer_kind, ffn_kind) sequence.

        mixer_kind: 'global' | 'local' | 'mamba' | 'mamba+shared'
        ffn_kind:   'dense' | 'moe' | 'none' (mamba blocks carry their own gating)
        """
        kinds = []
        for i in range(self.n_layers):
            if self.family in ("ssm", "hybrid"):
                mixer = "mamba"
                if (
                    self.shared_attn_every
                    and (i + 1) % self.shared_attn_every == 0
                ):
                    mixer = "mamba+shared"
                kinds.append((mixer, "none"))
            else:
                mixer = self.attn_pattern[i % len(self.attn_pattern)]
                is_moe = self.is_moe and self.moe_pattern[i % len(self.moe_pattern)]
                kinds.append((mixer, "moe" if is_moe else "dense"))
        return tuple(kinds)

    def scan_period(self) -> int:
        """Layers per scan group: the smallest cycle of the layer-kind pattern."""
        kinds = self.layer_kinds()
        for p in range(1, len(kinds) + 1):
            # smallest p such that the whole sequence is the cycled prefix;
            # a non-dividing remainder is fine (the stack scans a short tail).
            if all(kinds[i] == kinds[i % p] for i in range(len(kinds))):
                return p
        return len(kinds)

    def validate(self) -> None:
        assert self.n_heads % max(self.n_kv_heads, 1) == 0
        if self.is_moe:
            assert self.routing.top_k <= self.routing.n_experts
        if "local" in self.attn_pattern:
            assert self.window_size > 0, "local attention needs window_size"


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test variant: same family/pattern, tiny dims (<=512 d_model,
    2 scan periods of layers, <=4 experts)."""
    period = cfg.scan_period()
    small: dict = dict(
        n_layers=max(2, min(2 * period, cfg.n_layers)),
        d_model=min(cfg.d_model, 128),
        n_heads=min(cfg.n_heads, 4),
        n_kv_heads=min(cfg.n_kv_heads, 2),
        head_dim=32,
        d_ff=min(cfg.d_ff, 256),
        vocab_size=min(cfg.vocab_size, 512),
        max_seq_len=256,
        attn_chunk=64,
        window_size=min(cfg.window_size, 64) if cfg.window_size else 0,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        enc_seq_len=min(cfg.enc_seq_len, 64),
        frontend_tokens=min(cfg.frontend_tokens, 16),
        frontend_dim=min(cfg.frontend_dim, 64) if cfg.frontend_dim else 0,
        moe_d_ff=min(cfg.moe_d_ff, 128) if cfg.moe_d_ff else 0,
        param_dtype=jnp.float32,
        compute_dtype=jnp.float32,
    )
    if cfg.is_moe:
        small["routing"] = dataclasses.replace(
            cfg.routing,
            n_experts=min(cfg.routing.n_experts, 4),
            top_k=min(cfg.routing.top_k, 2),
        )
    if cfg.family in ("ssm", "hybrid"):
        small["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=min(cfg.ssm.d_state, 16), head_dim=32, chunk_size=32
        )
        if cfg.shared_attn_every:
            small["shared_attn_every"] = 2
            small["n_layers"] = 4
    if cfg.n_kv_heads == cfg.n_heads:  # keep MHA configs MHA
        small["n_kv_heads"] = small["n_heads"]
    small.update(overrides)
    out = dataclasses.replace(cfg, **small)
    out.validate()
    return out
