"""arctic-480b [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base]. 35L, d_model=7168, 56 heads (GQA kv=8,
head_dim=128), expert d_ff=4864, vocab=32000. Dense-MoE hybrid: a dense FFN
runs in parallel with the routed MoE residual on every layer.

m=128 is where the paper's BIP routing matters most (imbalance grows with
expert count — paper Fig. 2); sync='local' keeps the ADMM dual update
device-local. Dtype policy: fully-bf16 Adam (params+mu+nu = 6 B/param =
11.25 GB/chip at 256 chips) — the ONLY policy that leaves headroom for
activations on one pod; fp32 state fits on the 512-chip multi-pod mesh
(see EXPERIMENTS.md §Dry-run).
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RoutingSpec

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    source="[hf:Snowflake/snowflake-arctic-base]",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    moe_d_ff=4864,
    vocab_size=32000,
    routing=RoutingSpec(
        n_experts=128, top_k=2, strategy="bip", bip_iters=4, capacity_factor=1.25
    ),
    dense_residual=True,
    rope_theta=10000.0,
    max_seq_len=32768,
    attn_chunk=512,
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
    adam_mu_dtype="bf16",
    adam_nu_dtype="bf16",
)
