"""deepseek-coder-33b [dense] — llama-arch code model [arXiv:2401.14196].
62L, d_model=7168, 56 heads (GQA kv=8, head_dim=128), d_ff=19200,
vocab=32256, rope_theta=100000 (RoPE scaling for 16k ctx).

Dense FFN: the paper's MoE routing is inapplicable (DESIGN.md
§Arch-applicability). Pure full attention: long_500k decode is skipped
(DESIGN.md §Skips).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    source="[arXiv:2401.14196]",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab_size=32256,
    rope_theta=100000.0,
    max_seq_len=32768,
    attn_chunk=512,
)
