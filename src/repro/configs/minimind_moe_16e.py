"""minimind-moe 16-expert (0.3B) — the paper's own 16-expert model
[Jingyaogong 2024, github.com/jingyaogong/minimind; paper Table 1].

Paper Table 1: vocab 6400, 8 attention heads, 8 MoE layers, m=16 routed
experts, k=4 activated, softmax gate, <20M params/expert, 0.3B total.
Dims chosen to match: d_model=512, expert d_ff=1408 (3·512·1408 ≈ 2.2M
params/expert; 16 experts × 8 layers ≈ 0.28B). One shared expert
(minimind default). BIP routing with T=4 is the paper's best setting.
"""
from repro.configs.base import ModelConfig, RoutingSpec

CONFIG = ModelConfig(
    name="minimind-moe-16e",
    family="moe",
    source="[minimind; paper Table 1]",
    n_layers=8,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=1408,
    moe_d_ff=1408,
    vocab_size=6400,
    routing=RoutingSpec(
        n_experts=16,
        top_k=4,
        strategy="bip",
        bip_iters=4,
        aux_loss_alpha=0.1,   # Loss-Controlled baseline setting (paper §4.1)
        lossfree_lr=0.001,    # Loss-Free baseline setting   (paper §4.1)
        score_fn="softmax",
        capacity_factor=1.25,
    ),
    n_shared_experts=1,
    rope_theta=10000.0,
    max_seq_len=8192,
    attn_chunk=512,
)
