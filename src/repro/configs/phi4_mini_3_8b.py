"""phi4-mini-3.8b [dense] — RoPE + SwiGLU + GQA [arXiv:2412.08905].
32L, d_model=3072, 24 heads (GQA kv=8, head_dim=128), d_ff=8192,
vocab=200064, tied embeddings.

Dense FFN: BIP routing inapplicable. Pure full attention: long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    source="[arXiv:2412.08905]",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
    tie_embeddings=True,
    rope_theta=10000.0,
    max_seq_len=32768,
    attn_chunk=512,
)
