"""zamba2-7b [hybrid] — Mamba2 backbone + weight-shared attention blocks
[arXiv:2411.15242]. 81 backbone layers, d_model=3584, shared (attn+MLP)
block applied every 6th layer (32 heads, kv=32), d_ff=14336, vocab=32000,
ssm_state=64.

Simplification vs the published model (DESIGN.md §7): one shared block
(the release alternates two) and no per-invocation LoRA deltas.
Not MoE — the paper's routing technique is inapplicable (no routed FFN);
implemented without it per DESIGN.md §Arch-applicability.
"""
from repro.configs.base import ModelConfig, SSMSpec

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    source="[arXiv:2411.15242]",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    shared_attn_every=6,
    ssm=SSMSpec(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=2, chunk_size=128),
    rope_theta=10000.0,
    max_seq_len=524288,
    attn_chunk=512,
)
