"""mamba2-130m [ssm] — SSD / state-space duality [arXiv:2405.21060].
24L, d_model=768 (attention-free), d_inner=1536 (expand=2, head_dim=64,
24 ssm heads), ssm_state=128, vocab=50280.

Attention-free: constant-size recurrent state makes this the canonical
long_500k arch. No router anywhere — BIP inapplicable.
"""
from repro.configs.base import ModelConfig, SSMSpec

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    source="[arXiv:2405.21060]",
    n_layers=24,
    d_model=768,
    n_heads=12,       # unused (attention-free); kept for config completeness
    n_kv_heads=12,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMSpec(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk_size=128),
    max_seq_len=524288,
)
