"""gemma2-27b [dense] — local/global alternating attention + logit softcaps
[arXiv:2408.00118]. 46L, d_model=4608, 32 heads (GQA kv=16, head_dim=128),
d_ff=36864 (geglu), vocab=256000, sliding window 4096 on local layers,
attn softcap 50, final softcap 30, post-block norms.

long_500k decode runs: local layers use the ring-buffer window cache; the
23 global layers decode linearly against a model-axis-sharded KV cache
(DESIGN.md §Skips).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    source="[arXiv:2408.00118]",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    act="gelu",
    vocab_size=256000,
    attn_pattern=("local", "global"),
    window_size=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    post_block_norms=True,
    rope_theta=10000.0,
    max_seq_len=524288,
    attn_chunk=512,
)
