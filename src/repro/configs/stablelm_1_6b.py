"""stablelm-1.6b [dense] — [hf:stabilityai/stablelm-2-1_6b].
24L, d_model=2048, 32 heads (MHA kv=32, head_dim=64), d_ff=5632,
vocab=100352.

Dense FFN: BIP inapplicable. Pure full attention: long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    source="[hf:stabilityai/stablelm-2-1_6b]",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab_size=100352,
    rope_theta=10000.0,
    max_seq_len=32768,
    attn_chunk=512,
)
