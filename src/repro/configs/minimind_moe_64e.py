"""minimind-moe 64-expert (1.1B) — the paper's own 64-expert model
[Jingyaogong 2024; paper Table 1]. m=64, k=8, otherwise the 16e layout:
2.2M params/expert × 64 experts × 8 layers ≈ 1.1B total. Paper's best
setting here is T=14.
"""
from repro.configs.base import ModelConfig, RoutingSpec

CONFIG = ModelConfig(
    name="minimind-moe-64e",
    family="moe",
    source="[minimind; paper Table 1]",
    n_layers=8,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=1408,
    moe_d_ff=1408,
    vocab_size=6400,
    routing=RoutingSpec(
        n_experts=64,
        top_k=8,
        strategy="bip",
        bip_iters=14,
        aux_loss_alpha=0.1,
        lossfree_lr=0.001,
        score_fn="softmax",
        capacity_factor=1.25,
    ),
    n_shared_experts=1,
    rope_theta=10000.0,
    max_seq_len=8192,
    attn_chunk=512,
)
