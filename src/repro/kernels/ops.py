"""jit'd public wrappers around the Pallas kernels.

`bip_dual_update(s, q0, top_k, n_iters)` is a drop-in for the exact oracle in
repro.core.ref_bip (the router dispatches here when RouterConfig.use_kernel).

interpret=True executes the kernel bodies in Python on CPU (this container);
on TPU hardware set REPRO_PALLAS_INTERPRET=0 (or pass interpret=False) so
pallas_call lowers to Mosaic.
"""
from __future__ import annotations

import functools
import os
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.ref_bip import expert_kth_index
from repro.kernels import bip_admm as _bip
from repro.kernels import moe_gemm as _gemm


def _interpret_default() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


@functools.partial(
    jax.jit,
    static_argnames=("top_k", "n_iters", "n_bins", "block_n", "refine", "interpret"),
)
def bip_dual_update(
    s: jnp.ndarray,
    q0: jnp.ndarray,
    *,
    top_k: int,
    n_iters: int,
    n_bins: int = 512,
    block_n: int = 1024,
    refine: int = 1,
    interpret: bool = True,
) -> jnp.ndarray:
    """T fused ADMM iterations on the (n, m) score matrix. Returns q (m,).

    Each iteration runs 1 coarse histogram pass over [-1, 1] plus `refine`
    passes over the located bin (per-expert bounds), so the order-statistic
    resolution is (2/n_bins)^(refine+1)·… ≈ 8e-6 at the defaults — tighter
    than fp32 softmax score gaps (validated in tests/test_kernels.py).
    """
    n, m = s.shape
    rank = expert_kth_index(n, top_k, m)
    if rank < 0:  # capacity slack: constraint never binds
        return jnp.zeros_like(q0)

    def body(_, q):
        lo = jnp.full((m,), _bip.LO, jnp.float32)
        hi = jnp.full((m,), _bip.HI, jnp.float32)
        for _pass in range(refine + 1):
            _p, cnt = _bip.bip_admm_iteration(
                s, q, top_k=top_k, n_bins=n_bins, block_n=block_n,
                lo=lo, hi=hi, interpret=interpret,
            )
            cur_lo, cur_hi = lo, hi  # bounds this cnt was computed over
            bin_lo, bin_hi, found = _bip.locate_bin(cnt, rank, n_bins, lo, hi)
            lo = jnp.where(found, bin_lo, lo)
            hi = jnp.where(found, bin_hi, hi)
        return _bip.q_from_histogram(cnt, rank, n_bins, lo=cur_lo, hi=cur_hi)

    # inherit s's varying-manual-axes type for the loop carry (shard_map)
    q_init = q0.astype(jnp.float32) + 0.0 * s[0].astype(jnp.float32)
    return lax.fori_loop(0, n_iters, body, q_init)


def expert_ffn(x, w_gate, w_up, w_down, *, interpret: bool = None, **block_kw):
    interpret = _interpret_default() if interpret is None else interpret
    return _gemm.expert_ffn(
        x, w_gate, w_up, w_down, interpret=interpret, **block_kw
    )


def grouped_matmul(h, w, *, interpret: bool = None, **block_kw):
    interpret = _interpret_default() if interpret is None else interpret
    return _gemm.grouped_matmul(h, w, interpret=interpret, **block_kw)


def grouped_gated_ffn_in(x, wg, wu, *, interpret: bool = None, **block_kw):
    interpret = _interpret_default() if interpret is None else interpret
    return _gemm.grouped_gated_ffn_in(x, wg, wu, interpret=interpret, **block_kw)
