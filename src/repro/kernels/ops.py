"""jit'd public wrappers around the Pallas kernels.

`bip_dual_update(s, q0, top_k, n_iters)` is a drop-in for the exact oracle in
repro.core.ref_bip (the router dispatches here when RouterConfig.use_kernel).

interpret=True executes the kernel bodies in Python on CPU (this container);
on TPU hardware set REPRO_PALLAS_INTERPRET=0 (or pass interpret=False) so
pallas_call lowers to Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.ref_bip import expert_kth_index
from repro.kernels import bip_admm as _bip
from repro.kernels import moe_gemm as _gemm
from repro.kernels.moe_gemm import _interpret_default

# shard_map replication typing for pallas_call: jax 0.4.x ships no rule, so
# calling the kernel under shard_map(check_vma/check_rep=True) raises
# NotImplementedError. The *standard* rule (outputs vary over the union of
# the inputs' varying axes) is exactly right for a Pallas kernel — it is a
# per-shard local computation with no collectives inside — and registering
# it is what makes the collective dual update below legal inside the EP
# shard_maps (models/moe.py) without disabling replication checking.
try:  # pragma: no cover - exercised indirectly by the collective tests
    from jax._src.pallas.pallas_call import pallas_call_p as _pallas_call_p
    from jax.experimental import shard_map as _shard_map_mod

    _shard_map_mod.register_standard_check(_pallas_call_p)
    _shard_map_mod.register_standard_rewrite(_pallas_call_p)
except Exception:  # newer jax versions register their own rule
    pass


@functools.partial(
    jax.jit,
    static_argnames=(
        "top_k", "n_iters", "n_bins", "block_n", "refine", "interpret", "axis_names",
    ),
)
def bip_dual_update(
    s: jnp.ndarray,
    q0: jnp.ndarray,
    *,
    top_k: int,
    n_iters: int,
    n_bins: int = 512,
    block_n: int = 1024,
    refine: int = 1,
    interpret: Optional[bool] = None,
    axis_names: tuple = (),
) -> jnp.ndarray:
    """T fused ADMM iterations on the (n, m) score matrix. Returns q (m,).

    Each iteration runs 1 coarse histogram pass over [-1, 1] plus `refine`
    passes over the located bin (per-expert bounds), so the order-statistic
    resolution is (2/n_bins)^(refine+1)·… ≈ 8e-6 at the defaults — tighter
    than fp32 softmax score gaps (validated in tests/test_kernels.py).

    With `axis_names` (the collective form, sync='global' under shard_map):
    `s` is the device-local (n_local, m) token shard, the counting pass
    stays fully local, and the (m, n_bins) histogram counts are psum'd
    across the mesh axes between the count pass and the rank location —
    one fused collective per pass, refine+1 per dual iteration — so every
    device locates the SAME global order statistic. The rank becomes the
    traced floor(n_glob·k/m) (the bin comparisons accept a tracer), and the
    q carry starts from the replicated q0 so the result can leave the
    shard_map under an out_spec of P(None).
    """
    interpret = _interpret_default() if interpret is None else interpret
    n, m = s.shape
    axis_names = tuple(axis_names)
    if not axis_names:
        rank = expert_kth_index(n, top_k, m)
        if rank < 0:  # capacity slack: constraint never binds
            return jnp.zeros_like(q0)
        n_glob = None
    else:
        n_glob = lax.psum(jnp.asarray(n, jnp.int32), axis_names)
        rank = (n_glob * top_k) // m  # traced counterpart of expert_kth_index

    def body(_, q):
        lo = jnp.full((m,), _bip.LO, jnp.float32)
        hi = jnp.full((m,), _bip.HI, jnp.float32)
        for _pass in range(refine + 1):
            _p, cnt = _bip.bip_admm_iteration(
                s, q, top_k=top_k, n_bins=n_bins, block_n=block_n,
                lo=lo, hi=hi, interpret=interpret,
            )
            if axis_names:
                cnt = lax.psum(cnt, axis_names)
            cur_lo, cur_hi = lo, hi  # bounds this cnt was computed over
            bin_lo, bin_hi, found = _bip.locate_bin(cnt, rank, n_bins, lo, hi)
            lo = jnp.where(found, bin_lo, lo)
            hi = jnp.where(found, bin_hi, hi)
        q_new = _bip.q_from_histogram(cnt, rank, n_bins, lo=cur_lo, hi=cur_hi)
        if axis_names:
            # slack capacity (global cap index past the global token count)
            q_new = jnp.where(rank >= n_glob, jnp.zeros_like(q_new), q_new)
        return q_new

    if axis_names:
        # the carry must stay REPLICATED: q_new is assembled from psum'd
        # counts, so starting from the replicated q0 keeps the types aligned
        q_init = q0.astype(jnp.float32)
    else:
        # inherit s's varying-manual-axes type for the loop carry (shard_map)
        q_init = q0.astype(jnp.float32) + 0.0 * s[0].astype(jnp.float32)
    return lax.fori_loop(0, n_iters, body, q_init)


# ----------------------------------------------- grouped expert FFN (model path)


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def _pick_block(dim: int, want: int) -> int:
    """Largest usable block ≤ `want` that divides `dim` (dim is a multiple
    of 128 after padding; non-dividing requests fall back to one MXU tile)."""
    if dim % want == 0:
        return min(want, dim)
    return min(128, dim)


@functools.lru_cache(maxsize=None)
def _expert_ffn_vjp(bc: int, bf: int, bd: int, interpret: bool):
    """custom_vjp'd grouped FFN at fixed (aligned) block shapes.

    Forward is the fused Pallas pair (grouped_gated_ffn_in + grouped_matmul).
    Backward rematerializes the gate/up pre-activations and expresses every
    dgrad/wgrad as a grouped_matmul over transposed operands, so training
    never falls back to differentiating through pallas_call.
    """
    mm = functools.partial(_gemm.grouped_matmul, interpret=interpret)

    @jax.custom_vjp
    def f(x, wg, wu, wd):
        h = _gemm.grouped_gated_ffn_in(
            x, wg, wu, block_c=bc, block_f=bf, block_d=bd, interpret=interpret
        )
        return mm(h, wd, block_c=bc, block_d=bd, block_f=bf)

    def fwd(x, wg, wu, wd):
        return f(x, wg, wu, wd), (x, wg, wu, wd)

    def bwd(res, dy):
        x, wg, wu, wd = res
        t = lambda a: jnp.swapaxes(a, -1, -2)
        # rematerialize pre-activations: residuals are just the inputs
        g = mm(x, wg, block_c=bc, block_f=bd, block_d=bf)
        u = mm(x, wu, block_c=bc, block_f=bd, block_d=bf)
        gf = g.astype(jnp.float32)
        uf = u.astype(jnp.float32)
        sg = jax.nn.sigmoid(gf)
        silu = gf * sg
        h = (silu * uf).astype(x.dtype)
        # dgrad/wgrad of the down projection
        dh = mm(dy, t(wd), block_c=bc, block_f=bd, block_d=bf)
        dwd = mm(t(h), dy, block_c=bf, block_f=bc, block_d=bd)
        dhf = dh.astype(jnp.float32)
        dg = (dhf * uf * (sg * (1.0 + gf * (1.0 - sg)))).astype(x.dtype)
        du = (dhf * silu).astype(x.dtype)
        # dgrad/wgrad of the fused gate/up projections
        dx = mm(dg, t(wg), block_c=bc, block_f=bf, block_d=bd) + mm(
            du, t(wu), block_c=bc, block_f=bf, block_d=bd
        )
        dwg = mm(t(x), dg, block_c=bd, block_f=bc, block_d=bf)
        dwu = mm(t(x), du, block_c=bd, block_f=bc, block_d=bf)
        return dx, dwg, dwu, dwd

    f.defvjp(fwd, bwd)
    return f


def expert_ffn(
    x: jnp.ndarray,       # (E, C, D)
    w_gate: jnp.ndarray,  # (E, D, F)
    w_up: jnp.ndarray,    # (E, D, F)
    w_down: jnp.ndarray,  # (E, F, D)
    *,
    interpret: Optional[bool] = None,
    block_c: int = 128,
    block_f: int = 256,
    block_d: int = 256,
) -> jnp.ndarray:
    """Differentiable grouped expert FFN with automatic MXU alignment.

    Pads capacity/d/f up to multiples of 128 (zero rows/columns are exact:
    they contribute nothing through the GEMMs and the SwiGLU of zeros is
    zero), runs the Pallas kernel pair under a custom_vjp whose backward is
    itself grouped GEMMs, and slices the padding back off. This is the
    entry point the model path (models/moe._expert_ffn) uses when
    cfg.routing.use_kernel is set.
    """
    interpret = _interpret_default() if interpret is None else interpret
    e, c, d = x.shape
    f = w_gate.shape[-1]
    cp, dp, fp = _round_up(c, 128), _round_up(d, 128), _round_up(f, 128)
    bc = _pick_block(cp, block_c)
    bd = _pick_block(dp, block_d)
    bf = _pick_block(fp, block_f)

    def pad(a, rows, cols):
        return jnp.pad(a, ((0, 0), (0, rows - a.shape[1]), (0, cols - a.shape[2])))

    y = _expert_ffn_vjp(bc, bf, bd, bool(interpret))(
        pad(x, cp, dp), pad(w_gate, dp, fp), pad(w_up, dp, fp), pad(w_down, fp, dp)
    )
    return y[:, :c, :d]


def grouped_matmul(h, w, *, interpret: Optional[bool] = None, **block_kw):
    return _gemm.grouped_matmul(h, w, interpret=interpret, **block_kw)


def grouped_gated_ffn_in(x, wg, wu, *, interpret: Optional[bool] = None, **block_kw):
    return _gemm.grouped_gated_ffn_in(x, wg, wu, interpret=interpret, **block_kw)
