"""Pallas TPU kernel for the BIP-ADMM dual iteration (the paper's hot loop).

TPU adaptation (DESIGN.md §3): the reference implementation sorts score
columns per ADMM iteration (torch.topk on GPU). Column-wise sort over n up
to 10^6 maps badly onto the VPU, so selection is replaced by *histogram
counting* — which is exactly the paper's own Algorithm 4 approximation, made
hardware-native:

One kernel invocation = one ADMM iteration over the (n, m) score matrix,
streamed through VMEM once in n-blocks. Per block it
  1. computes p_i = max(0, (k+1)-th largest of s_i - q) for its rows by
     iterative max-extraction over the m lanes (k+1 unrolled VPU passes,
     tie-broken by index), and
  2. accumulates per-expert counts of (s_ij - p_i) against n_bins fixed
     histogram edges spanning [-1, 1] (softmax scores are in [0, 1]) —
     broadcast compare + reduce, no sort, no scatter.

The (m, n_bins) count matrix persists in the output across the sequential
TPU grid; the jnp wrapper (ops.py) turns it into
q_j = max(0, (kn/m+1)-th largest) by locating the rank's bin and
interpolating — resolution (hi-lo)/n_bins ≈ 0.004 at 512 bins, far below
any meaningful routing-score gap (validated against the exact oracle in
tests/test_kernels_bip.py).

Cost model per iteration: reads s once (n·m·4 B), VPU work n·m·(k+1 +
n_bins) compares — at n=32768, m=128, 512 bins ≈ 2.2 G lane-ops ≈ 0.5 ms on
a v5e core, ~100x less than a per-column sort.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

LO, HI = -1.0, 1.0  # score domain: softmax/sigmoid scores in [0,1], minus p in [0,1]
PAD_VALUE = -2.0    # below LO: padded rows never enter any histogram bin


def _iteration_kernel(
    s_ref,      # (blk, m) VMEM block of scores
    q_ref,      # (m,) current expert prices (replicated to every block)
    lo_ref,     # (m,) per-expert histogram lower bound
    hi_ref,     # (m,) per-expert histogram upper bound
    p_ref,      # (blk,) out: token prices for this block
    cnt_ref,    # (m, n_bins) out: histogram counts, accumulated over grid
    *,
    top_k: int,
    n_bins: int,
):
    blk, m = s_ref.shape
    x = s_ref[...].astype(jnp.float32) - q_ref[...].astype(jnp.float32)[None, :]

    # --- p_i = max(0, (k+1)-th largest of x_i) : k+1 max-extraction passes
    lane = lax.broadcasted_iota(jnp.int32, (blk, m), 1)
    active = jnp.ones((blk, m), jnp.bool_)
    cur = jnp.full((blk,), PAD_VALUE, jnp.float32)
    for _ in range(top_k + 1):
        masked = jnp.where(active, x, PAD_VALUE)
        cur = jnp.max(masked, axis=1)  # (blk,)
        hit = active & (masked == cur[:, None])
        first = jnp.min(jnp.where(hit, lane, m), axis=1)  # tie-break by index
        active = active & (lane != first[:, None])
    p = jnp.maximum(cur, 0.0)
    p_ref[...] = p

    # --- histogram of (s - p) per expert over per-expert edge ranges
    shifted = s_ref[...].astype(jnp.float32) - p[:, None]   # (blk, m)
    lo = lo_ref[...].astype(jnp.float32)
    hi = hi_ref[...].astype(jnp.float32)
    frac = lax.broadcasted_iota(jnp.float32, (n_bins,), 0) / n_bins
    edges = lo[:, None] + (hi - lo)[:, None] * frac[None, :]  # (m, n_bins)
    cnt = jnp.sum(
        (shifted[:, :, None] > edges[None, :, :]).astype(jnp.float32), axis=0
    )  # (m, n_bins)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    cnt_ref[...] += cnt


def bip_admm_iteration(
    s: jnp.ndarray,  # (n, m) scores in [0, 1]
    q: jnp.ndarray,  # (m,)
    *,
    top_k: int,
    n_bins: int = 512,
    block_n: int = 1024,
    lo=None,          # (m,) per-expert histogram bounds (default [LO, HI))
    hi=None,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One fused ADMM iteration. Returns (p (n,), counts (m, n_bins))."""
    n, m = s.shape
    if lo is None:
        lo = jnp.full((m,), LO, jnp.float32)
    if hi is None:
        hi = jnp.full((m,), HI, jnp.float32)
    pad = (-n) % block_n
    if pad:
        s = jnp.pad(s, ((0, pad), (0, 0)), constant_values=PAD_VALUE)
    np_ = s.shape[0]
    grid = (np_ // block_n,)

    kernel = functools.partial(_iteration_kernel, top_k=top_k, n_bins=n_bins)
    p, cnt = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, m), lambda i: (i, 0)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((m, n_bins), lambda i: (0, 0)),  # accumulated in place
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), jnp.float32),
            jax.ShapeDtypeStruct((m, n_bins), jnp.float32),
        ],
        interpret=interpret,
    )(
        s.astype(jnp.float32),
        q.astype(jnp.float32),
        lo.astype(jnp.float32),
        hi.astype(jnp.float32),
    )
    return p[:n], cnt


def locate_bin(
    cnt: jnp.ndarray,  # (m, n_bins) counts of (x > edge_b), non-increasing in b
    rank: int,         # cap index: want the (rank+1)-th largest value
    n_bins: int,
    lo: jnp.ndarray,   # (m,) bounds the histogram was built over
    hi: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Bin containing the order statistic. Returns (bin_lo, bin_hi, found).

    The (rank+1)-th largest value v satisfies cnt[b] > rank for edges below v
    and cnt[b] <= rank at/above it, so v lies in (edge_{b*}, edge_{b*}+Δ]
    with b* the last edge whose count exceeds rank.
    """
    width = (hi - lo) / n_bins  # (m,)
    above = cnt > rank
    b_star = jnp.sum(above.astype(jnp.int32), axis=1) - 1  # last True edge
    b_clip = jnp.clip(b_star, 0, n_bins - 1).astype(jnp.float32)
    bin_lo = lo + b_clip * width
    bin_hi = bin_lo + width
    return bin_lo, bin_hi, b_star >= 0


def q_from_histogram(
    cnt: jnp.ndarray,
    rank: int,
    n_bins: int,
    lo=None,
    hi=None,
) -> jnp.ndarray:
    """q_j = max(0, order statistic) with linear interpolation in its bin."""
    m = cnt.shape[0]
    if lo is None:
        lo = jnp.full((m,), LO, jnp.float32)
    if hi is None:
        hi = jnp.full((m,), HI, jnp.float32)
    width = (hi - lo) / n_bins
    bin_lo, _, found = locate_bin(cnt, rank, n_bins, lo, hi)
    b_clip = jnp.clip(
        jnp.sum((cnt > rank).astype(jnp.int32), axis=1) - 1, 0, n_bins - 1
    )
    c_lo = jnp.take_along_axis(cnt, b_clip[:, None], axis=1)[:, 0]
    c_hi = jnp.where(
        b_clip + 1 < n_bins,
        jnp.take_along_axis(
            cnt, jnp.clip(b_clip + 1, 0, n_bins - 1)[:, None], axis=1
        )[:, 0],
        0.0,
    )
    frac = (c_lo - rank) / jnp.maximum(c_lo - c_hi, 1.0)
    v = bin_lo + jnp.clip(frac, 0.0, 1.0) * width
    return jnp.where(found, jnp.maximum(v, 0.0), 0.0)
