"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.ref_bip import bip_dual_update as bip_dual_update_exact  # noqa: F401
from repro.core.ref_bip import expert_kth_index, kth_largest


def bip_iteration_ref(s, q, *, top_k):
    """One exact ADMM iteration: returns (p, q_candidates_fn inputs).

    p_i = max(0, (k+1)-th largest of s_i - q); the column order statistic is
    taken exactly with top_k (the kernel approximates it by histogram).
    """
    p = jnp.maximum(0.0, kth_largest(s - q[None, :], top_k, axis=-1))
    return p


def bip_dual_update_ref(s, q0, *, top_k, n_iters):
    """Exact T-iteration dual update (same as repro.core.ref_bip)."""
    from repro.core.ref_bip import bip_dual_update

    q, p = bip_dual_update(s, q0, top_k=top_k, n_iters=n_iters)
    return q


def histogram_counts_ref(s, p, *, n_bins, lo=-1.0, hi=1.0):
    """Per-expert counts of (s_ij - p_i) > edge_b for fixed edges."""
    shifted = s.astype(jnp.float32) - p[:, None]
    edges = lo + (hi - lo) * jnp.arange(n_bins, dtype=jnp.float32) / n_bins
    return jnp.sum(
        (shifted[:, :, None] > edges[None, None, :]).astype(jnp.float32), axis=0
    )  # (m, n_bins)


def expert_ffn_ref(x, w_gate, w_up, w_down):
    """Grouped expert FFN oracle: y = (silu(x wg) * (x wu)) wd, fp32 accum."""
    x32 = x.astype(jnp.float32)
    g = jnp.einsum("ecd,edf->ecf", x32, w_gate.astype(jnp.float32))
    u = jnp.einsum("ecd,edf->ecf", x32, w_up.astype(jnp.float32))
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, w_down.astype(jnp.float32))
    return y.astype(x.dtype)


def grouped_matmul_ref(h, w):
    y = jnp.einsum(
        "ecf,efd->ecd", h.astype(jnp.float32), w.astype(jnp.float32)
    )
    return y.astype(h.dtype)


def gated_ffn_in_ref(x, w_gate, w_up):
    x32 = x.astype(jnp.float32)
    g = jnp.einsum("ecd,edf->ecf", x32, w_gate.astype(jnp.float32))
    u = jnp.einsum("ecd,edf->ecf", x32, w_up.astype(jnp.float32))
    return (jax.nn.silu(g) * u).astype(x.dtype)
