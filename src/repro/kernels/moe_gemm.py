"""Grouped expert-FFN Pallas kernels (capacity-packed MoE compute).

After BIP-balanced dispatch, expert inputs sit in a dense (E, C, D) buffer
(C = capacity). The FFN is two grouped GEMMs with a gated activation between;
kernel 1 fuses the gate/up pair and the SwiGLU product so the (E, C, F)
hidden tensor is produced in one pass over x:

    h = silu(x @ w_gate) * (x @ w_up)        kernel: grouped_gated_ffn_in
    y = h @ w_down                           kernel: grouped_matmul

Tiling: grid (E, C/bc, F/bf) with an inner fori_loop over D/bd accumulating
in VMEM scratch — MXU-aligned block shapes (multiples of 128 on the minor
two dims). BlockSpec streams one expert's tiles at a time, so VMEM holds
bc·bd + 2·bd·bf + 2·bc·bf floats (~2 MB at the default 256/512/256).

Balance synergy (the paper's point): with MaxVio ≲ 0.2 the capacity C can be
~1.25·k·n/m, so the (E, C) grid is nearly padding-free; under aux-loss
routing early in training C must be ~2·k·n/m and half the MXU issue slots
compute zeros.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _interpret_default() -> bool:
    """Pallas interpret mode unless REPRO_PALLAS_INTERPRET=0 (TPU: Mosaic).

    Every kernel entry point resolves interpret=None through this, so TPU
    runs lower to hardware without callers threading flags.
    """
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def _gated_in_kernel(x_ref, wg_ref, wu_ref, h_ref, acc_g, acc_u):
    """One (expert, c-block, f-block) tile of h = silu(x wg) * (x wu)."""

    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_g[...] = jnp.zeros_like(acc_g)
        acc_u[...] = jnp.zeros_like(acc_u)

    x = x_ref[0].astype(jnp.float32)    # (bc, bd)
    wg = wg_ref[0].astype(jnp.float32)  # (bd, bf)
    wu = wu_ref[0].astype(jnp.float32)
    acc_g[...] += jnp.dot(x, wg, preferred_element_type=jnp.float32)
    acc_u[...] += jnp.dot(x, wu, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == pl.num_programs(3) - 1)
    def _done():
        h_ref[0] = (jax.nn.silu(acc_g[...]) * acc_u[...]).astype(h_ref.dtype)


def grouped_gated_ffn_in(
    x: jnp.ndarray,   # (E, C, D)
    w_gate: jnp.ndarray,  # (E, D, F)
    w_up: jnp.ndarray,    # (E, D, F)
    *,
    block_c: int = 128,
    block_f: int = 256,
    block_d: int = 256,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    interpret = _interpret_default() if interpret is None else interpret
    e, c, d = x.shape
    f = w_gate.shape[-1]
    bc, bf, bd = min(block_c, c), min(block_f, f), min(block_d, d)
    assert c % bc == 0 and f % bf == 0 and d % bd == 0, (c, f, d, bc, bf, bd)
    grid = (e, c // bc, f // bf, d // bd)
    return pl.pallas_call(
        _gated_in_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e_, i, j, k: (e_, i, k)),
            pl.BlockSpec((1, bd, bf), lambda e_, i, j, k: (e_, k, j)),
            pl.BlockSpec((1, bd, bf), lambda e_, i, j, k: (e_, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e_, i, j, k: (e_, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bc, bf), jnp.float32),
            pltpu.VMEM((bc, bf), jnp.float32),
        ],
        interpret=interpret,
    )(x, w_gate, w_up)


def _matmul_kernel(h_ref, w_ref, y_ref, acc):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jnp.dot(
        h_ref[0].astype(jnp.float32),
        w_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(3) == pl.num_programs(3) - 1)
    def _done():
        y_ref[0] = acc[...].astype(y_ref.dtype)


def grouped_matmul(
    h: jnp.ndarray,   # (E, C, F)
    w: jnp.ndarray,   # (E, F, D)
    *,
    block_c: int = 128,
    block_d: int = 256,
    block_f: int = 256,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    interpret = _interpret_default() if interpret is None else interpret
    e, c, f = h.shape
    d = w.shape[-1]
    bc, bd, bf = min(block_c, c), min(block_d, d), min(block_f, f)
    assert c % bc == 0 and d % bd == 0 and f % bf == 0
    grid = (e, c // bc, d // bd, f // bf)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bf), lambda e_, i, j, k: (e_, i, k)),
            pl.BlockSpec((1, bf, bd), lambda e_, i, j, k: (e_, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bd), lambda e_, i, j, k: (e_, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, c, d), h.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bd), jnp.float32)],
        interpret=interpret,
    )(h, w)


def expert_ffn(
    x: jnp.ndarray,      # (E, C, D)
    w_gate: jnp.ndarray,
    w_up: jnp.ndarray,
    w_down: jnp.ndarray,  # (E, F, D)
    *,
    interpret: Optional[bool] = None,
    **block_kw,
) -> jnp.ndarray:
    """Full grouped expert FFN: y = (silu(x wg) * (x wu)) wd.

    Raw aligned-shape kernel pair; for the differentiable, auto-padded
    entry point used by the model path see repro.kernels.ops.expert_ffn.
    """
    interpret = _interpret_default() if interpret is None else interpret
    h = grouped_gated_ffn_in(x, w_gate, w_up, interpret=interpret, **block_kw)
    return grouped_matmul(h, w_down, interpret=interpret, **block_kw)
