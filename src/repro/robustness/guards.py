"""Anomaly-guard policies for the training loop (DESIGN.md §Robustness).

Two halves, split by where the decision must run:

* **In-graph** (training/loop.py): the guarded train step computes
  ``step_ok = isfinite(loss) & isfinite(grad_norm) & ~force_skip`` and
  selects the PRE-step state for every leaf when it is false — a non-finite
  step can never poison params, Adam moments, or router duals, and a
  host-forced skip is bit-identical to the step never having run.
* **Host-side** (this module): `TrainGuard` watches the per-step metrics
  and decides how to *respond* to an anomaly — the configurable
  skip-step -> reduce-LR -> rollback ladder, plus loss-spike windowing
  (spikes are finite, so their update has already been applied; the only
  recovery is a rollback to the last valid checkpoint).

Determinism contract: every decision is a pure function of the observed
metric sequence and the guard's own state. A step that triggered a
rollback lands in `skip_steps`, so the replay force-skips it — the
recovered trajectory is bit-identical to an uninterrupted run that skipped
the same step (tests/test_robustness.py proves this).
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Any, Dict, List, Optional, Set

# actions returned by TrainGuard.observe()
OK = "ok"
SKIP = "skip"          # state already preserved in-graph; just continue
ROLLBACK = "rollback"  # restore newest valid checkpoint, rewind data cursor
RAISE = "raise"        # unrecoverable: surface TrainingDiverged


class TrainingDiverged(RuntimeError):
    """Raised when the guard's recovery budget is exhausted (or policy
    'raise' sees its first anomaly)."""


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Anomaly policy for train_loop(guard=...).

    policy: response to a non-finite loss/grad —
      'skip'     keep the pre-step state and move on; persistent anomalies
                 climb the ladder (reduce LR, then roll back).
      'rollback' restore the newest *valid* checkpoint, rewind the data
                 cursor, and replay (the anomalous step is force-skipped on
                 replay so a deterministic fault cannot loop forever).
      'raise'    fail fast (CI-style).
    spike_factor: > 0 enables loss-spike detection: a finite loss above
      factor x median(recent window) is an anomaly. Spike updates are
      already applied when detected, so the response is 'rollback' when a
      checkpoint manager is available, else the spike is recorded only.
    spike_window: finite losses in the reference window (detection starts
      once the window is full).
    skips_before_lr_drop: consecutive skips before the LR scale is dropped.
    lr_drop: multiplier applied to the LR scale at each ladder escalation.
    min_lr_scale: below this the ladder escalates to rollback (or raise).
    max_rollbacks: total rollback budget; exhausted -> raise.
    """

    policy: str = "skip"
    spike_factor: float = 0.0
    spike_window: int = 8
    skips_before_lr_drop: int = 4
    lr_drop: float = 0.5
    min_lr_scale: float = 0.1
    max_rollbacks: int = 4

    def __post_init__(self):
        if self.policy not in (SKIP, ROLLBACK, RAISE):
            raise ValueError(f"unknown guard policy {self.policy!r}")
        if self.spike_factor and self.spike_factor <= 1.0:
            raise ValueError("spike_factor must be > 1 (or 0 to disable)")
        if not (0.0 < self.lr_drop < 1.0):
            raise ValueError("lr_drop must be in (0, 1)")


class TrainGuard:
    """Host-side anomaly monitor; one instance per train_loop run.

    Usage per step i:
        force_skip, lr_scale = guard.controls(i)   # -> step inputs
        ... run the (guarded) step ...
        action = guard.observe(i, loss, step_ok)   # -> OK/SKIP/ROLLBACK
    `observe` raises TrainingDiverged for the RAISE action so callers
    can't accidentally ignore it.
    """

    def __init__(self, cfg: GuardConfig, can_rollback: bool = False):
        self.cfg = cfg
        self.can_rollback = can_rollback
        self.lr_scale = 1.0
        self.skip_steps: Set[int] = set()     # force-skipped on (re)play
        self.rolled_back_from: Set[int] = set()
        self.n_skips = 0
        self.n_rollbacks = 0
        self._consecutive = 0
        self._window: deque = deque(maxlen=max(2, cfg.spike_window))
        self.events: List[Dict[str, Any]] = []

    # ------------------------------------------------------------- inputs

    def controls(self, step: int):
        """(force_skip, lr_scale) for the step about to run."""
        return step in self.skip_steps, self.lr_scale

    # ------------------------------------------------------------ outputs

    def _event(self, step: int, kind: str, **detail) -> None:
        self.events.append({"step": int(step), "kind": kind, **detail})

    def _escalate(self, step: int) -> str:
        """Ladder: repeated anomalies drop the LR; LR floor -> rollback."""
        if self._consecutive % self.cfg.skips_before_lr_drop == 0:
            self.lr_scale *= self.cfg.lr_drop
            self._event(step, "lr_drop", lr_scale=self.lr_scale)
            if self.lr_scale < self.cfg.min_lr_scale:
                return self._rollback_or_raise(step)
        return SKIP

    def _rollback_or_raise(self, step: int) -> str:
        if not self.can_rollback:
            raise TrainingDiverged(
                f"anomaly at step {step} needs a rollback but no checkpoint "
                f"manager / rewindable stream is available"
            )
        if self.n_rollbacks >= self.cfg.max_rollbacks:
            raise TrainingDiverged(
                f"rollback budget ({self.cfg.max_rollbacks}) exhausted at "
                f"step {step}"
            )
        self.n_rollbacks += 1
        self.rolled_back_from.add(step)
        self.skip_steps.add(step)  # replay must not re-apply the bad step
        self._event(step, "rollback", count=self.n_rollbacks)
        return ROLLBACK

    def observe(self, step: int, loss: float, step_ok: bool) -> str:
        """Classify the step just run and return the recovery action."""
        forced = step in self.skip_steps
        if step_ok and not forced:
            # spike windowing (finite losses only)
            if (
                self.cfg.spike_factor
                and len(self._window) == self._window.maxlen
            ):
                ref = sorted(self._window)[len(self._window) // 2]
                if loss > self.cfg.spike_factor * max(ref, 1e-9):
                    self._event(step, "spike", loss=loss, median=ref)
                    if self.can_rollback:
                        return self._rollback_or_raise(step)
                    return OK  # update applied, nothing to undo: record only
            self._window.append(loss)
            self._consecutive = 0
            return OK

        if forced:
            # planned skip (replay of a rolled-back / skip-listed step)
            self.n_skips += 1
            self._event(step, "forced_skip")
            return SKIP

        # unplanned non-finite anomaly
        self._event(step, "nonfinite", loss=loss)
        if self.cfg.policy == RAISE:
            raise TrainingDiverged(f"non-finite loss/grad at step {step}")
        if self.cfg.policy == ROLLBACK:
            return self._rollback_or_raise(step)
        # policy 'skip': in-graph select already preserved the state
        self.n_skips += 1
        self.skip_steps.add(step)  # deterministic on any later replay
        self._consecutive += 1
        return self._escalate(step)

    # ------------------------------------------------------------ summary

    def summary(self) -> Dict[str, Any]:
        return {
            "n_skips": self.n_skips,
            "n_rollbacks": self.n_rollbacks,
            "lr_scale": self.lr_scale,
            "skip_steps": sorted(self.skip_steps),
            "events": list(self.events),
        }


__all__ = [
    "GuardConfig",
    "OK",
    "RAISE",
    "ROLLBACK",
    "SKIP",
    "TrainGuard",
    "TrainingDiverged",
]
