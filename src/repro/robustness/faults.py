"""Deterministic, seedable fault-injection registry (DESIGN.md §Robustness).

Every guard in the robustness layer is only as trustworthy as the failure
it was tested against, so faults are first-class objects: parseable from a
CLI spec string, deterministic given their parameters (all randomness comes
from a seeded `np.random.default_rng`), and scoped to exactly one seam of
the system. The registry contract:

* A fault is registered under a short name and constructed from keyword
  parameters: ``parse_fault("nan_grad@step=3")`` ->
  ``NanGrad(step=3)``. Values parse as int, then float, then str.
* A fault NEVER fires outside the seam it documents (e.g. `NanGrad` only
  flips the injection scalar the guarded train step consumes; it does not
  touch model code).
* Firing is a pure function of the fault's own state + the call arguments,
  so a replay after rollback sees the *same* faults at the same step
  indices — which is exactly what makes rollback-recovery testable.

Seams:

  nan_grad       train step    scales the loss by NaN at given step(s)
  ckpt_corrupt   checkpoint    bit-flips / truncates the written npz
  flaky_open     data loader   shard open/read raises OSError (bounded run)
  flaky_stream   prefetcher    wrapped stream raises at given batch indices
  stall_prefetch prefetcher    producer sleeps before given batch indices
  slow_step      serving       per-engine-step delay (drives deadline misses)

`FaultPlan` bundles the faults of one run and answers the questions the
harness asks ("does a NaN fire at step i?", "wrap this stream", ...).
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence, Type

import numpy as np

REGISTRY: Dict[str, Type["Fault"]] = {}


def register(name: str):
    def deco(cls):
        cls.name = name
        REGISTRY[name] = cls
        return cls

    return deco


class Fault:
    """Base class; subclasses are dataclasses with keyword parameters."""

    name = "fault"

    def describe(self) -> str:
        params = ",".join(
            f"{f.name}={getattr(self, f.name)}" for f in dataclasses.fields(self)
        )
        return f"{self.name}@{params}" if params else self.name


def _parse_value(v: str) -> Any:
    for conv in (int, float):
        try:
            return conv(v)
        except ValueError:
            continue
    return v


def _parse_steps(steps) -> List[int]:
    """'3' / '3:7' (every step in [3,7)) / '3,9' -> sorted step indices."""
    if isinstance(steps, int):
        return [steps]
    out: List[int] = []
    for part in str(steps).split(","):
        if ":" in part:
            lo, hi = part.split(":")
            out.extend(range(int(lo), int(hi)))
        else:
            out.append(int(part))
    return sorted(set(out))


def parse_fault(spec: str) -> Fault:
    """'name@k=v,k2=v2' -> registered Fault instance."""
    name, _, rest = spec.partition("@")
    if name not in REGISTRY:
        raise ValueError(
            f"unknown fault {name!r}; registered: {sorted(REGISTRY)}"
        )
    params = {}
    if rest:
        # ',' separates parameters AND continues list values: a segment
        # without '=' extends the previous value ('step=3,7' -> step='3,7')
        pairs: List[str] = []
        for seg in rest.split(","):
            if "=" in seg:
                pairs.append(seg)
            elif pairs:
                pairs[-1] += "," + seg
            else:
                raise ValueError(f"bad fault parameter {seg!r} in {spec!r}")
        for kv in pairs:
            k, _, v = kv.partition("=")
            if not k:
                raise ValueError(f"bad fault parameter {kv!r} in {spec!r}")
            params[k.strip()] = _parse_value(v.strip())
    return REGISTRY[name](**params)


# ----------------------------------------------------------- train faults


@register("nan_grad")
@dataclasses.dataclass
class NanGrad(Fault):
    """Poison the loss (hence every gradient) at the given step index(es).

    `step` accepts '3', '3,9', or a '3:7' range. Deterministic by step
    index, so a rollback-replay that re-executes the step re-injects the
    same NaN — the guard must converge anyway (skip-set semantics).
    """

    step: Any = 0

    def __post_init__(self):
        self._steps = set(_parse_steps(self.step))

    def fires(self, step: int) -> bool:
        return int(step) in self._steps


@register("ckpt_corrupt")
@dataclasses.dataclass
class CkptCorrupt(Fault):
    """Corrupt a just-written checkpoint file (simulated bitrot/partial
    write). `step` indexes saves in save order (0 = first save of the run);
    mode 'bitflip' XORs one byte, 'truncate' cuts the file roughly in half.
    """

    step: Any = 0
    mode: str = "bitflip"
    seed: int = 0

    def __post_init__(self):
        assert self.mode in ("bitflip", "truncate"), self.mode
        self._steps = set(_parse_steps(self.step))
        self._rng = np.random.default_rng(self.seed)
        self._n_saves = 0

    def fires_for_save(self) -> bool:
        """Call once per completed save; True when this save is a target."""
        idx = self._n_saves
        self._n_saves += 1
        return idx in self._steps

    def corrupt(self, path: str) -> None:
        corrupt_file(path, mode=self.mode, rng=self._rng)


def corrupt_file(path: str, mode: str = "bitflip", rng=None) -> None:
    """Flip one byte / truncate `path` in place (test + injection helper)."""
    rng = rng if rng is not None else np.random.default_rng(0)
    size = os.path.getsize(path)
    if mode == "truncate":
        with open(path, "r+b") as f:
            f.truncate(max(1, size // 2))
        return
    # bitflip somewhere past the zip local header so np.load still opens
    # the archive and the damage lands in array payload or its zip CRC
    off = int(rng.integers(min(64, size - 1), size))
    with open(path, "r+b") as f:
        f.seek(off)
        b = f.read(1)
        f.seek(off)
        f.write(bytes([b[0] ^ 0xFF]))


# ------------------------------------------------------------ data faults


@register("flaky_open")
@dataclasses.dataclass
class FlakyOpen(Fault):
    """An `open()` substitute whose opens/reads fail with probability `p`,
    never more than `max_consecutive` times in a row — so a loader with a
    retry budget >= max_consecutive always makes progress.
    """

    p: float = 0.5
    p_read: float = 0.0
    max_consecutive: int = 2
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._consecutive = 0
        self.n_open_failures = 0
        self.n_read_failures = 0

    def _should_fail(self, p: float) -> bool:
        if self._consecutive >= self.max_consecutive:
            self._consecutive = 0
            return False
        if self._rng.random() < p:
            self._consecutive += 1
            return True
        self._consecutive = 0
        return False

    def __call__(self, path, *args, **kwargs):
        if self._should_fail(self.p):
            self.n_open_failures += 1
            raise OSError(f"injected flaky open: {path}")
        fh = open(path, *args, **kwargs)
        return _FlakyHandle(fh, self) if self.p_read > 0 else fh


class _FlakyHandle:
    """File-handle proxy whose readline() fails per the owning FlakyOpen."""

    def __init__(self, fh, fault: FlakyOpen):
        self._fh = fh
        self._fault = fault

    def readline(self, *a):
        if self._fault._should_fail(self._fault.p_read):
            self._fault.n_read_failures += 1
            raise OSError("injected flaky read")
        return self._fh.readline(*a)

    def __getattr__(self, name):
        return getattr(self._fh, name)


@register("flaky_stream")
@dataclasses.dataclass
class FlakyStream(Fault):
    """Wrap a BatchStream so iteration raises OSError just before yielding
    the given global batch indices — each index fires exactly once, so a
    producer that restarts iteration (Prefetcher retry budget) recovers.
    """

    at: Any = 0

    def __post_init__(self):
        self._pending = set(_parse_steps(self.at))
        self._count = 0

    def wrap(self, stream):
        return _FaultyStream(stream, self)

    def before_batch(self) -> None:
        idx = self._count
        if idx in self._pending:
            self._pending.discard(idx)
            raise OSError(f"injected stream fault before batch {idx}")

    def on_batch(self) -> None:
        self._count += 1


@register("stall_prefetch")
@dataclasses.dataclass
class StallPrefetch(Fault):
    """Sleep `seconds` before yielding the given batch indices (producer
    stall: exercises consumer-side patience / close-while-stalled paths)."""

    at: Any = 0
    seconds: float = 0.2

    def __post_init__(self):
        self._steps = set(_parse_steps(self.at))
        self._count = 0

    def wrap(self, stream):
        return _FaultyStream(stream, self)

    def before_batch(self) -> None:
        if self._count in self._steps:
            time.sleep(self.seconds)

    def on_batch(self) -> None:
        self._count += 1


class _FaultyStream:
    """BatchStream proxy that consults a fault before/after each batch.

    The fault's counter advances only when a batch is actually yielded, so
    a retry after an injected failure re-attempts the SAME batch index —
    matching how a real flaky source behaves under retry.
    """

    def __init__(self, stream, fault):
        self.stream = stream
        self.fault = fault

    def __iter__(self):
        it = iter(self.stream)
        while True:
            self.fault.before_batch()
            try:
                batch = next(it)
            except StopIteration:
                return
            self.fault.on_batch()
            yield batch

    def state_dict(self):
        return self.stream.state_dict()

    def load_state_dict(self, state):
        self.stream.load_state_dict(state)

    def close(self):
        if hasattr(self.stream, "close"):
            self.stream.close()


# --------------------------------------------------------- serving faults


@register("slow_step")
@dataclasses.dataclass
class SlowStep(Fault):
    """Delay every engine step by `ms` milliseconds (decode slowdown /
    head-of-line blocking: drives real-clock deadline misses)."""

    ms: float = 10.0

    @property
    def seconds(self) -> float:
        return self.ms / 1e3


# -------------------------------------------------------------- the plan


class FaultPlan:
    """The faults of one run, queried by the harness at each seam."""

    def __init__(self, faults: Sequence[Fault] = ()):
        self.faults = list(faults)

    @classmethod
    def from_specs(cls, specs: Optional[Iterable[str]]) -> "FaultPlan":
        return cls([parse_fault(s) for s in (specs or [])])

    def get(self, name: str) -> Optional[Fault]:
        for f in self.faults:
            if f.name == name:
                return f
        return None

    def __bool__(self) -> bool:
        return bool(self.faults)

    # seam queries --------------------------------------------------------

    def nan_fires(self, step: int) -> bool:
        f = self.get("nan_grad")
        return bool(f and f.fires(step))

    def corrupt_after_save(self, path: str) -> bool:
        """Apply a pending ckpt_corrupt fault to `path`; True if fired."""
        f = self.get("ckpt_corrupt")
        if f is not None and f.fires_for_save():
            f.corrupt(path)
            return True
        return False

    def open_fn(self):
        """Loader open() substitute, or None when no flaky_open fault."""
        return self.get("flaky_open")

    def wrap_stream(self, stream):
        for f in self.faults:
            if isinstance(f, (FlakyStream, StallPrefetch)):
                stream = f.wrap(stream)
        return stream

    def step_delay(self) -> float:
        f = self.get("slow_step")
        return f.seconds if f else 0.0


__all__ = [
    "CkptCorrupt",
    "Fault",
    "FaultPlan",
    "FlakyOpen",
    "FlakyStream",
    "NanGrad",
    "REGISTRY",
    "SlowStep",
    "StallPrefetch",
    "corrupt_file",
    "parse_fault",
    "register",
]
