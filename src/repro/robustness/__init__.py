"""Fault tolerance: injection registry + anomaly-guard policies.

`faults` makes failures reproducible (seeded injectors for NaN grads,
checkpoint bitrot, flaky shards, stalled prefetch, slow serve steps);
`guards` makes recovery deterministic (skip -> reduce-LR -> rollback
ladder over the in-graph state select). See DESIGN.md §Robustness.
"""
from repro.robustness.faults import (  # noqa: F401
    Fault,
    FaultPlan,
    corrupt_file,
    parse_fault,
)
from repro.robustness.guards import (  # noqa: F401
    GuardConfig,
    TrainGuard,
    TrainingDiverged,
)

__all__ = [
    "Fault",
    "FaultPlan",
    "GuardConfig",
    "TrainGuard",
    "TrainingDiverged",
    "corrupt_file",
    "parse_fault",
]
