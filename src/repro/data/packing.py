"""Document packing into fixed-length training sequences (DESIGN.md §Data).

Three pack modes, all emitting (seq_len+1)-token windows from which the
batch builder derives `tokens = w[:-1]`, `labels = w[1:]` (with invalid
label positions set to -1, which `Model.loss_fn` masks out):

* ``pack`` — documents are concatenated into one stream with an EOS after
  every document; windows tile the stream with stride seq_len (1-token
  overlap), so **every stream token is a label exactly once** and no token
  is dropped. Attention is plain causal across document boundaries (the
  standard GPT recipe).
* ``pack_nocross`` — same stream, but each window carries per-position
  ``segments`` (document index within the stream); labels that would
  predict the first token of the *next* document are masked, and the model
  masks attention to ``seg_q == seg_k`` when the batch carries
  ``segments`` (see `models.common.attention`), so no information crosses
  a document boundary.
* ``pad`` — one document per sequence, truncated at seq_len+1, padded with
  EOS; labels past the document's EOS are masked. (Truncation loses the
  tail of over-long documents — this mode trades tokens for clean
  per-document sequences.)

The packer is a resumable stream stage: `state_dict()` captures the
pending stream tail and the running segment counter, so the loader's
checkpoint cursor (data/loader.py) restores mid-pack bit-exactly.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

PACK_MODES = ("pack", "pack_nocross", "pad")


class SequencePacker:
    """Feeds documents in, yields fixed-length window examples out.

    An example is a dict of np arrays:
        window   (seq_len+1,) int32 token ids
        valid    (seq_len,)   bool: label positions that count toward loss
        segments (seq_len+1,) int32 — only in 'pack_nocross' mode
    """

    def __init__(self, seq_len: int, eos_id: int, mode: str = "pack"):
        assert mode in PACK_MODES, mode
        assert seq_len >= 2
        self.seq_len = seq_len
        self.eos_id = eos_id
        self.mode = mode
        self._buf: List[int] = []
        self._seg: List[int] = []
        self._next_seg = 0

    # ------------------------------------------------------------ feeding

    def add_document(self, ids: Sequence[int]) -> List[Dict[str, np.ndarray]]:
        """Append one document (EOS added here); returns completed windows."""
        ids = list(int(t) for t in ids)
        if not ids:
            return []
        if self.mode == "pad":
            return [self._pad_example(ids)]
        seg = self._next_seg
        self._next_seg += 1
        self._buf.extend(ids + [self.eos_id])
        self._seg.extend([seg] * (len(ids) + 1))
        return self._drain()

    def flush(self) -> List[Dict[str, np.ndarray]]:
        """Emit the final partial window (EOS-padded, pad labels masked).

        A buffer holding only the 1-token overlap tail (or less) carries no
        unconsumed labels and is dropped."""
        out = self._drain()
        if len(self._buf) > 1:
            n = len(self._buf)
            window = self._buf + [self.eos_id] * (self.seq_len + 1 - n)
            seg = self._seg + [-1] * (self.seq_len + 1 - n)
            valid = np.zeros(self.seq_len, bool)
            valid[: n - 1] = True
            out.append(self._example(window, seg, valid))
        self._buf, self._seg = [], []
        return out

    # ----------------------------------------------------------- plumbing

    def _drain(self) -> List[Dict[str, np.ndarray]]:
        out = []
        L = self.seq_len
        while len(self._buf) >= L + 1:
            window, seg = self._buf[: L + 1], self._seg[: L + 1]
            out.append(self._example(window, seg, np.ones(L, bool)))
            # stride L: the window's last token re-enters as the next
            # window's first input, so it is a label exactly once
            self._buf = self._buf[L:]
            self._seg = self._seg[L:]
        return out

    def _example(self, window, seg, valid) -> Dict[str, np.ndarray]:
        window = np.asarray(window, np.int32)
        ex = {"window": window, "valid": np.asarray(valid, bool)}
        if self.mode == "pack_nocross":
            seg = np.asarray(seg, np.int32)
            # mask labels that cross a segment boundary (predicting the
            # first token of the next document from the previous one)
            ex["valid"] = ex["valid"] & (seg[1:] == seg[:-1])
            ex["segments"] = seg
        return ex

    def _pad_example(self, ids: List[int]) -> Dict[str, np.ndarray]:
        L = self.seq_len
        stream = ids + [self.eos_id]
        n = min(len(stream), L + 1)
        window = stream[:n] + [self.eos_id] * (L + 1 - n)
        valid = np.zeros(L, bool)
        valid[: n - 1] = True
        return {"window": np.asarray(window, np.int32), "valid": valid}

    # -------------------------------------------------------------- state

    def state_dict(self) -> Dict:
        return {
            "buf": list(self._buf),
            "seg": list(self._seg),
            "next_seg": self._next_seg,
        }

    def load_state_dict(self, state: Dict) -> None:
        self._buf = list(state["buf"])
        self._seg = list(state["seg"])
        self._next_seg = int(state["next_seg"])


def examples_to_batch(
    examples: Sequence[Dict[str, np.ndarray]]
) -> Dict[str, np.ndarray]:
    """Stack packer examples into the model's batch dict.

    labels are the shifted window with invalid positions set to -1
    (masked by loss_fn); 'segments' rides along iff the packer emitted it,
    renumbered per row from 0 (values are row-local document indices)."""
    windows = np.stack([e["window"] for e in examples])
    valid = np.stack([e["valid"] for e in examples])
    batch = {
        "tokens": windows[:, :-1].astype(np.int32),
        "labels": np.where(valid, windows[:, 1:], -1).astype(np.int32),
    }
    if "segments" in examples[0]:
        seg = np.stack([e["segments"] for e in examples])[:, :-1]
        batch["segments"] = (seg - seg[:, :1]).astype(np.int32)
    return batch
