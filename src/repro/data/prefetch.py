"""Background host->device prefetch for BatchStreams (DESIGN.md §Data).

A producer thread pulls batches from the wrapped stream, optionally
`jax.device_put`s them (starting the H2D transfer off the step's critical
path), and parks them in a bounded queue (depth 2 = classic double
buffering: one batch on device being consumed, one in flight). The main
thread's `next()` then returns an already-resident batch, so host-side
tokenize/pack/transfer overlaps the previous device step.

Checkpoint semantics: each queue item carries the stream's `state_dict()`
snapshot taken *after* that batch was produced. `state_dict()` on the
prefetcher returns the snapshot of the last batch the **consumer** took —
not the producer's read-ahead position — so a resume never skips the
read-ahead batches sitting in the queue.

`close()` (or the context manager / generator-close path) stops the
producer even if it is blocked on a full queue, and joins the thread —
early-stopping consumers never leak a thread.

Robustness (DESIGN.md §Robustness): `retries` gives the producer a
consecutive-failure budget — a crash mid-pull re-`iter()`s the wrapped
stream (which resumes from its own cursor) instead of killing the run;
the budget resets on every successful batch. Calling `next()` on an
iterator after `close()` raises a clear RuntimeError instead of blocking
forever on the drained queue; a FRESH `__iter__()` after close re-arms
the queue and producer, which is how train_loop resumes the stream after
a rollback (close -> load_state_dict -> iter).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

_SENTINEL = object()


class Prefetcher:
    """Wrap a BatchStream with a depth-bounded background producer."""

    def __init__(
        self,
        stream,
        depth: int = 2,
        device_put: Optional[bool] = None,
        retries: int = 0,
    ):
        assert depth >= 1
        self.stream = stream
        self.depth = depth
        # None = auto: transfer eagerly on real accelerators; on the CPU
        # backend there is no H2D copy to hide, so skip the extra dispatch
        self.device_put = device_put
        self.retries = max(0, retries)
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._err: Optional[BaseException] = None
        self._last_state: Optional[Dict] = None
        self._thread: Optional[threading.Thread] = None
        self._closed = False
        self.n_producer_retries = 0

    # ------------------------------------------------------------ producer

    def _produce(self):
        try:
            put = self.device_put
            if put is None:
                import jax

                put = jax.default_backend() != "cpu"
            budget = self.retries
            it = iter(self.stream)
            while True:
                try:
                    batch = next(it)
                except StopIteration:
                    return  # clean end of stream: finally parks the sentinel
                except Exception:
                    # producer crash: streams with a cursor resume from it on
                    # re-iteration, and a wrapped fault stream only advances
                    # its index on an actual yield, so the failed batch is
                    # re-attempted — not dropped
                    if budget <= 0 or self._stop.is_set():
                        raise
                    budget -= 1
                    self.n_producer_retries += 1
                    it = iter(self.stream)
                    continue
                budget = self.retries  # consecutive-failure budget
                if put:
                    import jax

                    batch = jax.device_put(batch)
                snap = self.stream.state_dict() if hasattr(self.stream, "state_dict") else None
                while not self._stop.is_set():
                    try:
                        self._q.put((batch, snap), timeout=0.05)
                        break
                    except queue.Full:
                        continue
                if self._stop.is_set():
                    return
        except BaseException as e:  # surfaced to the consumer on next()
            self._err = e
        finally:
            while not self._stop.is_set():
                try:
                    self._q.put(_SENTINEL, timeout=0.05)
                    break
                except queue.Full:
                    continue

    # ------------------------------------------------------------ consumer

    def __iter__(self) -> Iterator[Dict]:
        if self._thread is None:
            # fresh start OR re-arm after close(): the old Event/Queue are
            # poisoned (stop set, queue drained), so rebuild both
            self._stop = threading.Event()
            self._q = queue.Queue(maxsize=self.depth)
            self._err = None
            self._closed = False
            self._thread = threading.Thread(
                target=self._produce, name="repro-prefetch", daemon=True
            )
            self._thread.start()
        try:
            while True:
                if self._closed:
                    raise RuntimeError(
                        "Prefetcher is closed; iterate it again (a fresh "
                        "__iter__ re-arms the producer) instead of calling "
                        "next() on an iterator that outlived close()"
                    )
                try:
                    item = self._q.get(timeout=0.05)
                except queue.Empty:
                    continue  # poll so a concurrent close() can't wedge us
                if item is _SENTINEL:
                    if self._err is not None:
                        raise self._err
                    return
                batch, snap = item
                self._last_state = snap
                yield batch
        finally:
            self.close()

    def close(self) -> None:
        """Stop the producer (even mid-put) and join it. Idempotent; a
        later fresh `__iter__()` re-arms the prefetcher."""
        self._closed = True
        self._stop.set()
        if self._thread is not None:
            while True:  # unblock a producer stuck on a full queue
                try:
                    self._q.get_nowait()
                except queue.Empty:
                    break
            self._thread.join(timeout=5.0)
            if self._thread.is_alive():
                # keep _thread set: the stream may still be mutating, so
                # load_state_dict / re-iteration must stay refused
                raise RuntimeError(
                    "prefetch producer did not stop within 5s "
                    "(blocked inside the wrapped stream?)"
                )
            self._thread = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # --------------------------------------------------------------- state

    def state_dict(self) -> Dict:
        """Cursor of the last *consumed* batch (read-ahead not counted)."""
        if self._last_state is not None:
            return self._last_state
        return self.stream.state_dict()

    def load_state_dict(self, state: Dict) -> None:
        assert self._thread is None, "load_state_dict before iteration starts"
        self.stream.load_state_dict(state)
        # the snapshot of the last pre-rewind batch is now stale; without
        # this a post-rollback checkpoint would persist the OLD cursor
        self._last_state = None
