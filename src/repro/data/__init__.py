"""repro.data — synthetic + real-text streaming data pipeline (DESIGN.md §Data)."""
from repro.data.loader import BatchStream, ShardedTextLoader, resolve_shards
from repro.data.packing import PACK_MODES, SequencePacker, examples_to_batch
from repro.data.prefetch import Prefetcher
from repro.data.synthetic import (
    SyntheticBatchStream,
    SyntheticLMDataset,
    input_specs,
    make_batches,
)
from repro.data.tokenizer import (
    ByteBPETokenizer,
    iter_corpus_texts,
    train_tokenizer_from_files,
)

__all__ = [
    "BatchStream",
    "ByteBPETokenizer",
    "PACK_MODES",
    "Prefetcher",
    "SequencePacker",
    "ShardedTextLoader",
    "SyntheticBatchStream",
    "SyntheticLMDataset",
    "examples_to_batch",
    "input_specs",
    "iter_corpus_texts",
    "make_batches",
    "resolve_shards",
    "train_tokenizer_from_files",
]
