"""repro.data — synthetic LM data pipeline."""
from repro.data.synthetic import SyntheticLMDataset, make_batches, input_specs

__all__ = ["SyntheticLMDataset", "make_batches", "input_specs"]
