"""Self-contained byte-level BPE tokenizer (DESIGN.md §Data).

No external tokenizer dependency: the base alphabet is the 256 bytes, so
any UTF-8 text round-trips losslessly (encode -> decode is the identity on
strings; unknown symbols can't exist). Merges are learned on a corpus
sample with whitespace pre-chunking (merges never cross a \\S+/\\s+ chunk
boundary — the standard trick that keeps training near-linear and encoding
cacheable per chunk).

Token-id layout (stable across save/load):

    0..255                  raw bytes
    256..256+n_merges-1     merged pairs, in rank order
    vocab_size-1            EOS (doubles as the pad token; padded label
                            positions are masked with -1, so the pad id
                            only ever appears on the input side)

The serialized form is a single JSON file (merges as id pairs + the
declared vocab size), written next to the run's checkpoints so a training
run is reproducible from its artifacts alone.
"""
from __future__ import annotations

import json
import os
import re
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

_CHUNK_RE = re.compile(r"\S+|\s+")
_N_SPECIAL = 1  # EOS


def _chunk(text: str) -> List[str]:
    """Split into alternating word / whitespace runs; concat == text."""
    return _CHUNK_RE.findall(text)


class ByteBPETokenizer:
    """Byte-level BPE with a fixed vocab budget.

    merges: ordered list of (left_id, right_id) pairs; merge i produces
    token id 256 + i. `vocab_size` includes the byte alphabet, the merges,
    and the EOS special.
    """

    def __init__(self, merges: Sequence[Tuple[int, int]], vocab_size: int):
        merges = [tuple(m) for m in merges]
        assert vocab_size >= 256 + len(merges) + _N_SPECIAL, (
            vocab_size,
            len(merges),
        )
        self.merges: List[Tuple[int, int]] = merges
        self.vocab_size = int(vocab_size)
        self.eos_id = self.vocab_size - 1
        self._ranks: Dict[Tuple[int, int], int] = {
            pair: i for i, pair in enumerate(merges)
        }
        self._cache: Dict[str, Tuple[int, ...]] = {}

    # ------------------------------------------------------------ encode

    def _bpe(self, chunk: str) -> Tuple[int, ...]:
        cached = self._cache.get(chunk)
        if cached is not None:
            return cached
        ids = list(chunk.encode("utf-8"))
        while len(ids) > 1:
            best_rank, best_i = None, -1
            for i in range(len(ids) - 1):
                r = self._ranks.get((ids[i], ids[i + 1]))
                if r is not None and (best_rank is None or r < best_rank):
                    best_rank, best_i = r, i
            if best_rank is None:
                break
            new_id = 256 + best_rank
            # merge every occurrence of this exact pair in one pass
            out, i = [], 0
            while i < len(ids):
                if (
                    i < len(ids) - 1
                    and ids[i] == self.merges[best_rank][0]
                    and ids[i + 1] == self.merges[best_rank][1]
                ):
                    out.append(new_id)
                    i += 2
                else:
                    out.append(ids[i])
                    i += 1
            ids = out
        result = tuple(ids)
        if len(self._cache) < 65536:
            self._cache[chunk] = result
        return result

    def encode(self, text: str) -> List[int]:
        out: List[int] = []
        for chunk in _chunk(text):
            out.extend(self._bpe(chunk))
        return out

    def decode(self, ids: Iterable[int]) -> str:
        # expand merges recursively back to bytes
        expand = self._expand_table()
        data = bytearray()
        for t in ids:
            t = int(t)
            if t == self.eos_id or t >= 256 + len(self.merges):
                continue  # specials / unused budget carry no bytes
            data.extend(expand[t])
        return data.decode("utf-8", errors="replace")

    def _expand_table(self) -> List[bytes]:
        table: List[bytes] = [bytes([b]) for b in range(256)]
        for left, right in self.merges:
            table.append(table[left] + table[right])
        return table

    # ------------------------------------------------------- persistence

    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(
                {
                    "format": "repro.byte_bpe.v1",
                    "vocab_size": self.vocab_size,
                    "merges": [list(m) for m in self.merges],
                },
                f,
            )

    @classmethod
    def load(cls, path: str) -> "ByteBPETokenizer":
        with open(path) as f:
            obj = json.load(f)
        assert obj.get("format") == "repro.byte_bpe.v1", obj.get("format")
        return cls(
            merges=[tuple(m) for m in obj["merges"]],
            vocab_size=obj["vocab_size"],
        )

    # ---------------------------------------------------------- training

    @classmethod
    def train(
        cls, texts: Iterable[str], vocab_size: int, max_sample_chunks: int = 200_000
    ) -> "ByteBPETokenizer":
        """Learn merges by greedy pair-frequency BPE on chunk counts.

        The merge budget is vocab_size - 256 - 1 (EOS); training stops early
        if no pair repeats (tiny corpora), leaving unused ids between the
        last merge and EOS — harmless, EOS stays pinned at vocab_size - 1.
        """
        assert vocab_size > 256 + _N_SPECIAL, "vocab must exceed byte alphabet"
        counts: Dict[Tuple[int, ...], int] = {}
        n_chunks = 0
        for text in texts:
            for chunk in _chunk(text):
                key = tuple(chunk.encode("utf-8"))
                if len(key) > 1:
                    counts[key] = counts.get(key, 0) + 1
                n_chunks += 1
            if n_chunks >= max_sample_chunks:
                break

        words = {k: list(k) for k in counts}
        merges: List[Tuple[int, int]] = []
        budget = vocab_size - 256 - _N_SPECIAL
        while len(merges) < budget:
            pair_counts: Dict[Tuple[int, int], int] = {}
            for key, ids in words.items():
                c = counts[key]
                for a, b in zip(ids, ids[1:]):
                    pair_counts[(a, b)] = pair_counts.get((a, b), 0) + c
            if not pair_counts:
                break
            # deterministic: break count ties by smallest pair ids
            (left, right), best = min(
                pair_counts.items(), key=lambda kv: (-kv[1], kv[0])
            )
            if best < 2:
                break
            new_id = 256 + len(merges)
            merges.append((left, right))
            for key, ids in words.items():
                out, i = [], 0
                while i < len(ids):
                    if i < len(ids) - 1 and ids[i] == left and ids[i + 1] == right:
                        out.append(new_id)
                        i += 2
                    else:
                        out.append(ids[i])
                        i += 1
                words[key] = out
        return cls(merges=merges, vocab_size=vocab_size)


# ----------------------------------------------------------- corpus helpers


def parse_doc_line(path: str, line: str) -> Optional[str]:
    """One shard line -> document text (None for blanks). The single
    definition of the corpus line format — the tokenizer trainer and the
    loader must agree on what a document is."""
    line = line.rstrip("\n")
    if not line:
        return None
    if path.endswith(".jsonl"):
        return json.loads(line)["text"]
    return line


def iter_corpus_texts(paths: Sequence[str]) -> Iterator[str]:
    """Yield document texts from .jsonl ({'text': ...} per line) / .txt
    (one document per line) shards, in path order."""
    for path in paths:
        with open(path, "r", encoding="utf-8") as f:
            for line in f:
                text = parse_doc_line(path, line)
                if text is not None:
                    yield text


def train_tokenizer_from_files(
    paths: Sequence[str], vocab_size: int, max_sample_chunks: int = 200_000
) -> ByteBPETokenizer:
    return ByteBPETokenizer.train(
        iter_corpus_texts(paths), vocab_size, max_sample_chunks=max_sample_chunks
    )
