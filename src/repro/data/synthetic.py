"""Synthetic language-modeling data with learnable structure.

The paper trains on the Minimind Chinese web-text corpus, which we cannot
ship; all its claims are *relative between routing methods on identical
data*, so any corpus with (a) a skewed unigram distribution and (b)
predictable sequential structure reproduces the phenomenon: skew creates
routing-collapse pressure (some experts see far more tokens), structure
gives the model something to learn so perplexity separates methods.

The generator is a small order-2 Markov chain over the vocab with
Zipf-distributed stationary probabilities and deterministic "grammar"
transitions mixed in. Fully deterministic given the seed; shards
reproducibly by (host, step).

`input_specs(cfg, shape)` builds ShapeDtypeStruct stand-ins for the dry-run
(no allocation), covering every model input including modality stubs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class SyntheticLMDataset:
    """Order-2 mixture: zipf unigrams + cyclic grammar, split train/test."""

    vocab_size: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.3
    structure: float = 0.75  # fraction of steps that follow the grammar

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab_size
        probs = 1.0 / np.arange(1, v + 1) ** self.zipf_a
        self._probs = probs / probs.sum()
        # deterministic successor table ("grammar"): tok -> next tok
        self._succ = rng.permutation(v).astype(np.int64)
        self._rng = rng

    def sample_tokens(self, rng: np.random.Generator, n: int) -> np.ndarray:
        out = np.empty(n, dtype=np.int64)
        out[0] = rng.choice(self.vocab_size, p=self._probs)
        structured = rng.random(n) < self.structure
        iid = rng.choice(self.vocab_size, size=n, p=self._probs)
        for t in range(1, n):
            out[t] = self._succ[out[t - 1]] if structured[t] else iid[t]
        return out

    def batches(
        self, batch_size: int, n_batches: int, split: str = "train"
    ) -> Iterator[Dict[str, jnp.ndarray]]:
        """Deterministic batch stream; 'test' uses a disjoint seed stream."""
        base = self.seed * 1_000_003 + (500_000 if split == "test" else 0)
        for b in range(n_batches):
            rng = np.random.default_rng(base + b)
            toks = np.stack(
                [self.sample_tokens(rng, self.seq_len + 1) for _ in range(batch_size)]
            )
            yield {
                "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
                "labels": jnp.asarray(toks[:, 1:], jnp.int32),
            }


def make_batches(cfg: ModelConfig, batch_size: int, seq_len: int, n_batches: int,
                 seed: int = 0, split: str = "train"):
    ds = SyntheticLMDataset(cfg.vocab_size, seq_len, seed=seed)
    for batch in ds.batches(batch_size, n_batches, split):
        batch = dict(batch)
        _add_frontend_stubs(cfg, batch, batch_size, numeric=True, seed=seed)
        yield batch


class SyntheticBatchStream:
    """`make_batches` behind the checkpointable BatchStream cursor protocol.

    Each batch is a pure function of (cfg, seed, split, step), so the whole
    cursor is the step index: `load_state_dict({"step": n})` resumes in
    O(1) instead of regenerating and discarding the consumed prefix the way
    a plain generator forces `train_loop` to (see data/loader.BatchStream).
    """

    def __init__(self, cfg: ModelConfig, batch_size: int, seq_len: int,
                 n_batches: int, seed: int = 0, split: str = "train"):
        self.cfg = cfg
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.n_batches = n_batches
        self.seed = seed
        self.split = split
        self._ds = SyntheticLMDataset(cfg.vocab_size, seq_len, seed=seed)
        self._step = 0

    def _one(self, b: int) -> Dict[str, jnp.ndarray]:
        base = self.seed * 1_000_003 + (500_000 if self.split == "test" else 0)
        rng = np.random.default_rng(base + b)
        toks = np.stack(
            [self._ds.sample_tokens(rng, self.seq_len + 1) for _ in range(self.batch_size)]
        )
        batch = {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }
        _add_frontend_stubs(self.cfg, batch, self.batch_size, numeric=True, seed=self.seed)
        return batch

    def __iter__(self):
        while self._step < self.n_batches:
            batch = self._one(self._step)
            self._step += 1
            yield batch

    def state_dict(self) -> Dict:
        return {"step": self._step}

    def load_state_dict(self, state: Dict) -> None:
        self._step = int(state["step"])


def _add_frontend_stubs(cfg, batch, batch_size, numeric=False, seed=0):
    if cfg.family == "vlm":
        shape = (batch_size, cfg.frontend_tokens, cfg.frontend_dim)
        batch["patches"] = (
            jnp.asarray(np.random.default_rng(seed).standard_normal(shape), jnp.float32)
            if numeric
            else jax.ShapeDtypeStruct(shape, jnp.float32)
        )
    if cfg.family == "encdec":
        shape = (batch_size, cfg.enc_seq_len, cfg.frontend_dim)
        batch["frames"] = (
            jnp.asarray(np.random.default_rng(seed).standard_normal(shape), jnp.float32)
            if numeric
            else jax.ShapeDtypeStruct(shape, jnp.float32)
        )


# --------------------------------------------------------------- dry-run


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One assigned (seq_len, global_batch) workload."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def input_specs(
    cfg: ModelConfig, shape: InputShape
) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b = shape.global_batch
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32),
        }
    elif shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32)}
    else:  # decode: one new token per sequence; the KV/state cache holds seq_len
        specs = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    _add_frontend_stubs(cfg, specs, b, numeric=False)
    return specs
