"""Deterministic, resumable, rank-sharded text-shard loader (DESIGN.md §Data).

`ShardedTextLoader` reads .jsonl / .txt shards and yields model-ready
batches (tokens / labels [/ segments]) through tokenize -> shuffle-buffer
-> pack stages. Two properties the training harness depends on:

* **Determinism + rank sharding** — documents are numbered in (epoch,
  file, line) order; rank r of world W owns documents with index % W == r.
  Every rank scans the same shard list (document striding, not file
  striding, so any W partitions any corpus evenly) and the per-rank stream
  is a pure function of (shards, seed, rank, world_size).
* **Checkpointable cursor** — `state_dict()` is an *offset-replay* cursor:
  it records the stream position (epoch, file index, byte offset, document
  counter), the RNG and packer state as of the start of the current
  shuffle block, and two counters (documents drained from the block,
  packed windows already consumed into emitted batches). It never
  serializes buffered document *contents*: `load_state_dict()` seeks to
  the block anchor and re-reads at most one block, re-deriving the buffer
  membership from the replayed RNG. The cursor size is therefore O(1) in
  `shuffle_buffer` — O(batch_size · seq_len) for the packer tail and the
  sub-batch pending windows — so it stays sidecar-sized at production
  buffer sizes.

Shuffling is *block* shuffling: read `shuffle_buffer` documents, permute
them with the stream RNG, drain them to the packer, repeat. Within-block
order is uniform; mixing across blocks comes from epoch reseeding. The
whole state is JSON-serializable (ints, lists, the PCG64 state dict) and
rides in a sidecar file next to the TrainState npz (checkpoint/store.py).
"""
from __future__ import annotations

import glob as _glob
import os
import time
from typing import Dict, Iterator, List, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.data.packing import SequencePacker, examples_to_batch
from repro.data.tokenizer import ByteBPETokenizer, parse_doc_line


@runtime_checkable
class BatchStream(Protocol):
    """An iterable of batch dicts with a checkpointable cursor.

    `state_dict()` must describe exactly the batches already yielded, so
    that a fresh stream + `load_state_dict()` continues with the next
    batch bit-exactly (train_loop checkpoints it alongside TrainState)."""

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]: ...

    def state_dict(self) -> Dict: ...

    def load_state_dict(self, state: Dict) -> None: ...


def resolve_shards(data: str) -> List[str]:
    """Expand a directory / glob / single file into a sorted shard list."""
    if os.path.isdir(data):
        paths = [
            os.path.join(data, f)
            for f in os.listdir(data)
            if f.endswith((".jsonl", ".txt"))
        ]
    elif any(ch in data for ch in "*?["):
        paths = _glob.glob(data)
    else:
        paths = [data]
    paths = sorted(paths)
    if not paths:
        raise FileNotFoundError(f"no .jsonl/.txt shards under {data!r}")
    return paths


class ShardedTextLoader:
    """BatchStream over text shards: tokenize -> shuffle -> pack -> batch.

    epochs=None loops the corpus forever (reshuffling each epoch with a
    deterministic per-epoch seed); a finite epoch count flushes the packer
    at the end and drops the final sub-batch-size remainder (static batch
    shapes keep the jit cache to one entry).

    I/O robustness (DESIGN.md §Robustness): transient shard open/read
    errors are retried with exponential backoff — up to `io_retries`
    CONSECUTIVE failures (any successful read resets the streak) before
    the error propagates. A failed handle is reopened and re-seeked to
    `_byte_offset`, which always points at the start of the next unread
    line, so retries never skip or duplicate a document. Undecodable
    .jsonl lines are skipped (their document index is still consumed, so
    every rank skips the same line and rank sharding stays aligned). Both
    pathologies are counted and the counters ride in `state_dict()`.
    `open_fn` is injectable for fault-injection tests (robustness.faults).
    """

    def __init__(
        self,
        shards: Sequence[str],
        tokenizer: ByteBPETokenizer,
        *,
        batch_size: int,
        seq_len: int,
        pack_mode: str = "pack",
        rank: int = 0,
        world_size: int = 1,
        shuffle_buffer: int = 64,
        seed: int = 0,
        epochs: Optional[int] = None,
        io_retries: int = 3,
        io_backoff: float = 0.05,
        open_fn=None,
    ):
        assert 0 <= rank < world_size
        self.shards = [str(p) for p in shards]
        self.tokenizer = tokenizer
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.pack_mode = pack_mode
        self.rank = rank
        self.world_size = world_size
        self.shuffle_buffer = max(1, shuffle_buffer)
        self.seed = seed
        self.epochs = epochs
        self.io_retries = max(0, io_retries)
        self.io_backoff = io_backoff
        self._open_fn = open_fn if open_fn is not None else open

        self._n_io_retries = 0     # transient open/read failures retried
        self._n_skipped_lines = 0  # undecodable .jsonl lines dropped
        self._io_streak = 0        # consecutive failures (resets on success)
        self._epoch = 0
        self._file_idx = 0
        self._byte_offset = 0
        self._doc_count = 0  # global (all-rank) doc counter within the epoch
        self._rng = np.random.default_rng(self._epoch_seed(0))
        self._packer = SequencePacker(seq_len, tokenizer.eos_id, pack_mode)
        self._pending: List[Dict[str, np.ndarray]] = []  # packed windows
        self._batches_emitted = 0
        self._exhausted = False
        self._fh = None
        # block-shuffle replay state: `_block` holds the not-yet-drained
        # remainder of the current permuted block (reversed: pop() = next);
        # `_anchor` snapshots everything needed to replay the block from
        # the stream, so the cursor never stores document contents
        self._block: List[List[int]] = []
        self._drained = 0            # docs of the current block already packed
        self._windows_consumed = 0   # windows emitted into batches since anchor
        self._flushed_since_anchor = False
        self._anchor = self._make_anchor()

    # ----------------------------------------------------------- reading

    def _epoch_seed(self, epoch: int) -> np.random.SeedSequence:
        return np.random.SeedSequence([self.seed, epoch])

    def _open(self):
        if self._fh is None and self._file_idx < len(self.shards):
            fh = self._open_fn(self.shards[self._file_idx], "r", encoding="utf-8")
            fh.seek(self._byte_offset)
            self._fh = fh
            self._io_streak = 0  # a successful open is progress too
        return self._fh

    def _io_retry_or_raise(self, err: OSError) -> None:
        """Transient open/read failure: drop the handle, back off, let the
        caller re-attempt (the reopen seeks to `_byte_offset`, the start of
        the next unread line). Raises after `io_retries` CONSECUTIVE
        failures — any successful read resets the streak."""
        if self._fh is not None:
            try:
                self._fh.close()
            except OSError:
                pass
            self._fh = None
        self._io_streak += 1
        if self._io_streak > self.io_retries:
            raise err
        self._n_io_retries += 1
        if self.io_backoff > 0:
            time.sleep(self.io_backoff * (2 ** (self._io_streak - 1)))

    def _next_rank_doc(self) -> Optional[List[int]]:
        """Next tokenized document owned by this rank, advancing the cursor;
        None at end of the final allowed epoch."""
        while True:
            try:
                fh = self._open()
            except OSError as e:
                self._io_retry_or_raise(e)
                continue
            if fh is None:  # epoch exhausted
                if self.epochs is not None and self._epoch + 1 >= self.epochs:
                    return None
                self._epoch += 1
                self._file_idx = 0
                self._byte_offset = 0
                self._doc_count = 0
                self._rng = np.random.default_rng(self._epoch_seed(self._epoch))
                continue
            try:
                line = fh.readline()
            except OSError as e:
                self._io_retry_or_raise(e)
                continue
            self._io_streak = 0
            if not line:
                fh.close()
                self._fh = None
                self._file_idx += 1
                self._byte_offset = 0
                continue
            self._byte_offset = fh.tell()
            if not line.rstrip("\n"):
                continue  # blanks don't consume a document index
            idx = self._doc_count
            self._doc_count += 1
            if idx % self.world_size != self.rank:
                continue  # another rank's document: skip without parsing
            try:
                text = parse_doc_line(self.shards[self._file_idx], line)
            except (ValueError, KeyError, TypeError):
                # undecodable line (corrupt JSON / wrong schema): its index
                # was already consumed above, so every rank of any world
                # size skips this exact line — sharding stays aligned
                self._n_skipped_lines += 1
                continue
            ids = self.tokenizer.encode(text)
            if ids:
                return ids

    # ----------------------------------------------------------- batching

    def _make_anchor(self) -> Dict:
        """Snapshot of everything a restore needs to replay the current
        block: stream position, RNG, packer tail, and the pending windows
        left over from previous blocks. All O(1) in `shuffle_buffer`."""
        return {
            "epoch": self._epoch,
            "file_idx": self._file_idx,
            "byte_offset": self._byte_offset,
            "doc_count": self._doc_count,
            "rng_state": self._rng.bit_generator.state,
            "packer": self._packer.state_dict(),
            "pending": list(self._pending),  # window dicts are immutable
        }

    def _read_block(self) -> List[List[int]]:
        """Read up to `shuffle_buffer` documents and permute them with the
        stream RNG. Called both live (from `_pump`) and during replay, so
        the permutation is a pure function of the anchor state."""
        docs: List[List[int]] = []
        while len(docs) < self.shuffle_buffer:
            doc = self._next_rank_doc()
            if doc is None:
                self._exhausted = True
                break
            docs.append(doc)
        order = self._rng.permutation(len(docs)) if docs else []
        return [docs[i] for i in order]

    def _pump(self) -> bool:
        """Advance the pipeline one document; False when fully exhausted."""
        if not self._block:
            if self._exhausted:
                return False
            # new block: re-anchor the replay cursor BEFORE reading, then
            # read + permute (reversed so pop() yields permuted order)
            self._drained = 0
            self._windows_consumed = 0
            self._flushed_since_anchor = False
            self._anchor = self._make_anchor()
            self._block = self._read_block()[::-1]
            if not self._block:
                return False
        self._drained += 1
        self._pending.extend(self._packer.add_document(self._block.pop()))
        return True

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            while len(self._pending) < self.batch_size:
                if not self._pump():
                    break
            if len(self._pending) < self.batch_size and self._exhausted:
                if not self._block:
                    self._pending.extend(self._packer.flush())
                    self._flushed_since_anchor = True
                if len(self._pending) < self.batch_size:
                    return  # drop the ragged remainder: batch shape is static
            batch = examples_to_batch(self._pending[: self.batch_size])
            self._pending = self._pending[self.batch_size :]
            self._windows_consumed += self.batch_size
            self._batches_emitted += 1
            yield batch

    # -------------------------------------------------------------- state

    @staticmethod
    def _windows_to_json(windows) -> List[Dict]:
        return [
            {k: np.asarray(v).tolist() for k, v in ex.items()} for ex in windows
        ]

    @staticmethod
    def _windows_from_json(windows) -> List[Dict[str, np.ndarray]]:
        return [
            {
                k: np.asarray(v, bool if k == "valid" else np.int32)
                for k, v in ex.items()
            }
            for ex in windows
        ]

    def state_dict(self) -> Dict:
        return {
            "version": 2,
            # current read position: diagnostics + mid-shard visibility
            "epoch": self._epoch,
            "file_idx": self._file_idx,
            "byte_offset": self._byte_offset,
            "doc_count": self._doc_count,
            "batches_emitted": self._batches_emitted,
            "exhausted": self._exhausted,
            "io_retries": self._n_io_retries,
            "skipped_lines": self._n_skipped_lines,
            # offset-replay cursor: block anchor + consumed-prefix counters;
            # restore re-reads the block instead of storing its contents
            "anchor": {
                "epoch": self._anchor["epoch"],
                "file_idx": self._anchor["file_idx"],
                "byte_offset": self._anchor["byte_offset"],
                "doc_count": self._anchor["doc_count"],
                "rng_state": self._anchor["rng_state"],
                "packer": self._anchor["packer"],
                "pending": self._windows_to_json(self._anchor["pending"]),
            },
            "drained": self._drained,
            "windows_consumed": self._windows_consumed,
            "flushed": self._flushed_since_anchor,
        }

    def load_state_dict(self, state: Dict) -> None:
        assert state.get("version") == 2, state.get("version")
        if self._fh is not None:
            self._fh.close()
            self._fh = None
        a = state["anchor"]
        self._epoch = int(a["epoch"])
        self._file_idx = int(a["file_idx"])
        self._byte_offset = int(a["byte_offset"])
        self._doc_count = int(a["doc_count"])
        self._rng = np.random.default_rng(0)
        self._rng.bit_generator.state = a["rng_state"]
        self._packer.load_state_dict(a["packer"])
        self._pending = self._windows_from_json(a["pending"])
        self._exhausted = False
        self._block = []
        drained = int(state["drained"])
        # replay: re-read the in-flight block from the anchor (re-deriving
        # buffer membership from the replayed RNG), re-feed the consumed
        # document prefix through the packer, drop already-emitted windows
        if drained > 0:
            permuted = self._read_block()
            for doc in permuted[:drained]:
                self._pending.extend(self._packer.add_document(doc))
            self._block = permuted[drained:][::-1]
        if bool(state.get("flushed", False)):
            self._pending.extend(self._packer.flush())
        wc = int(state["windows_consumed"])
        self._pending = self._pending[wc:]
        self._anchor = {
            "epoch": int(a["epoch"]),
            "file_idx": int(a["file_idx"]),
            "byte_offset": int(a["byte_offset"]),
            "doc_count": int(a["doc_count"]),
            "rng_state": a["rng_state"],
            "packer": dict(a["packer"]),
            "pending": self._windows_from_json(a["pending"]),
        }
        self._drained = drained
        self._windows_consumed = wc
        self._flushed_since_anchor = bool(state.get("flushed", False))
        # the replayed read must land exactly where the snapshot was taken
        assert (
            self._epoch == int(state["epoch"])
            and self._file_idx == int(state["file_idx"])
            and self._byte_offset == int(state["byte_offset"])
            and self._doc_count == int(state["doc_count"])
        ), "cursor replay diverged from the snapshotted stream position"
        self._batches_emitted = int(state["batches_emitted"])
        self._exhausted = bool(state["exhausted"])
        self._n_io_retries = int(state.get("io_retries", 0))
        self._n_skipped_lines = int(state.get("skipped_lines", 0))
        self._io_streak = 0
