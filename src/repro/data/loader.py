"""Deterministic, resumable, rank-sharded text-shard loader (DESIGN.md §Data).

`ShardedTextLoader` reads .jsonl / .txt shards and yields model-ready
batches (tokens / labels [/ segments]) through tokenize -> shuffle-buffer
-> pack stages. Two properties the training harness depends on:

* **Determinism + rank sharding** — documents are numbered in (epoch,
  file, line) order; rank r of world W owns documents with index % W == r.
  Every rank scans the same shard list (document striding, not file
  striding, so any W partitions any corpus evenly) and the per-rank stream
  is a pure function of (shards, seed, rank, world_size).
* **Checkpointable cursor** — `state_dict()` captures the full stream
  state: (epoch, file index, byte offset, document counter), the
  shuffle-buffer RNG *and contents*, the packer's pending tail, and
  already-packed-but-unbatched windows. `load_state_dict()` seeks straight
  to the byte offset, so `train_loop(resume=True)` restarts bit-exactly in
  O(1) — no replay of the consumed prefix.

The whole state is JSON-serializable (ints, lists, the PCG64 state dict),
sized by shuffle_buffer ≈ buffered documents — it rides in a sidecar file
next to the TrainState npz (checkpoint/store.py).
"""
from __future__ import annotations

import glob as _glob
import os
import time
from typing import Dict, Iterator, List, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.data.packing import SequencePacker, examples_to_batch
from repro.data.tokenizer import ByteBPETokenizer, parse_doc_line


@runtime_checkable
class BatchStream(Protocol):
    """An iterable of batch dicts with a checkpointable cursor.

    `state_dict()` must describe exactly the batches already yielded, so
    that a fresh stream + `load_state_dict()` continues with the next
    batch bit-exactly (train_loop checkpoints it alongside TrainState)."""

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]: ...

    def state_dict(self) -> Dict: ...

    def load_state_dict(self, state: Dict) -> None: ...


def resolve_shards(data: str) -> List[str]:
    """Expand a directory / glob / single file into a sorted shard list."""
    if os.path.isdir(data):
        paths = [
            os.path.join(data, f)
            for f in os.listdir(data)
            if f.endswith((".jsonl", ".txt"))
        ]
    elif any(ch in data for ch in "*?["):
        paths = _glob.glob(data)
    else:
        paths = [data]
    paths = sorted(paths)
    if not paths:
        raise FileNotFoundError(f"no .jsonl/.txt shards under {data!r}")
    return paths


class ShardedTextLoader:
    """BatchStream over text shards: tokenize -> shuffle -> pack -> batch.

    epochs=None loops the corpus forever (reshuffling each epoch with a
    deterministic per-epoch seed); a finite epoch count flushes the packer
    at the end and drops the final sub-batch-size remainder (static batch
    shapes keep the jit cache to one entry).

    I/O robustness (DESIGN.md §Robustness): transient shard open/read
    errors are retried with exponential backoff — up to `io_retries`
    CONSECUTIVE failures (any successful read resets the streak) before
    the error propagates. A failed handle is reopened and re-seeked to
    `_byte_offset`, which always points at the start of the next unread
    line, so retries never skip or duplicate a document. Undecodable
    .jsonl lines are skipped (their document index is still consumed, so
    every rank skips the same line and rank sharding stays aligned). Both
    pathologies are counted and the counters ride in `state_dict()`.
    `open_fn` is injectable for fault-injection tests (robustness.faults).
    """

    def __init__(
        self,
        shards: Sequence[str],
        tokenizer: ByteBPETokenizer,
        *,
        batch_size: int,
        seq_len: int,
        pack_mode: str = "pack",
        rank: int = 0,
        world_size: int = 1,
        shuffle_buffer: int = 64,
        seed: int = 0,
        epochs: Optional[int] = None,
        io_retries: int = 3,
        io_backoff: float = 0.05,
        open_fn=None,
    ):
        assert 0 <= rank < world_size
        self.shards = [str(p) for p in shards]
        self.tokenizer = tokenizer
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.pack_mode = pack_mode
        self.rank = rank
        self.world_size = world_size
        self.shuffle_buffer = max(1, shuffle_buffer)
        self.seed = seed
        self.epochs = epochs
        self.io_retries = max(0, io_retries)
        self.io_backoff = io_backoff
        self._open_fn = open_fn if open_fn is not None else open

        self._n_io_retries = 0     # transient open/read failures retried
        self._n_skipped_lines = 0  # undecodable .jsonl lines dropped
        self._io_streak = 0        # consecutive failures (resets on success)
        self._epoch = 0
        self._file_idx = 0
        self._byte_offset = 0
        self._doc_count = 0  # global (all-rank) doc counter within the epoch
        self._rng = np.random.default_rng(self._epoch_seed(0))
        self._buffer: List[List[int]] = []  # tokenized docs awaiting shuffle-pop
        self._packer = SequencePacker(seq_len, tokenizer.eos_id, pack_mode)
        self._pending: List[Dict[str, np.ndarray]] = []  # packed windows
        self._batches_emitted = 0
        self._exhausted = False
        self._fh = None

    # ----------------------------------------------------------- reading

    def _epoch_seed(self, epoch: int) -> np.random.SeedSequence:
        return np.random.SeedSequence([self.seed, epoch])

    def _open(self):
        if self._fh is None and self._file_idx < len(self.shards):
            fh = self._open_fn(self.shards[self._file_idx], "r", encoding="utf-8")
            fh.seek(self._byte_offset)
            self._fh = fh
            self._io_streak = 0  # a successful open is progress too
        return self._fh

    def _io_retry_or_raise(self, err: OSError) -> None:
        """Transient open/read failure: drop the handle, back off, let the
        caller re-attempt (the reopen seeks to `_byte_offset`, the start of
        the next unread line). Raises after `io_retries` CONSECUTIVE
        failures — any successful read resets the streak."""
        if self._fh is not None:
            try:
                self._fh.close()
            except OSError:
                pass
            self._fh = None
        self._io_streak += 1
        if self._io_streak > self.io_retries:
            raise err
        self._n_io_retries += 1
        if self.io_backoff > 0:
            time.sleep(self.io_backoff * (2 ** (self._io_streak - 1)))

    def _next_rank_doc(self) -> Optional[List[int]]:
        """Next tokenized document owned by this rank, advancing the cursor;
        None at end of the final allowed epoch."""
        while True:
            try:
                fh = self._open()
            except OSError as e:
                self._io_retry_or_raise(e)
                continue
            if fh is None:  # epoch exhausted
                if self.epochs is not None and self._epoch + 1 >= self.epochs:
                    return None
                self._epoch += 1
                self._file_idx = 0
                self._byte_offset = 0
                self._doc_count = 0
                self._rng = np.random.default_rng(self._epoch_seed(self._epoch))
                continue
            try:
                line = fh.readline()
            except OSError as e:
                self._io_retry_or_raise(e)
                continue
            self._io_streak = 0
            if not line:
                fh.close()
                self._fh = None
                self._file_idx += 1
                self._byte_offset = 0
                continue
            self._byte_offset = fh.tell()
            if not line.rstrip("\n"):
                continue  # blanks don't consume a document index
            idx = self._doc_count
            self._doc_count += 1
            if idx % self.world_size != self.rank:
                continue  # another rank's document: skip without parsing
            try:
                text = parse_doc_line(self.shards[self._file_idx], line)
            except (ValueError, KeyError, TypeError):
                # undecodable line (corrupt JSON / wrong schema): its index
                # was already consumed above, so every rank of any world
                # size skips this exact line — sharding stays aligned
                self._n_skipped_lines += 1
                continue
            ids = self.tokenizer.encode(text)
            if ids:
                return ids

    # ----------------------------------------------------------- batching

    def _pump(self) -> bool:
        """Advance the pipeline one document; False when fully exhausted."""
        if not self._exhausted:
            doc = self._next_rank_doc()
            if doc is None:
                self._exhausted = True
            else:
                self._buffer.append(doc)
                if len(self._buffer) < self.shuffle_buffer:
                    return True
        if not self._buffer:
            return False
        pick = int(self._rng.integers(len(self._buffer)))
        self._pending.extend(self._packer.add_document(self._buffer.pop(pick)))
        return True

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            while len(self._pending) < self.batch_size:
                if not self._pump():
                    break
            if len(self._pending) < self.batch_size and self._exhausted:
                if not self._buffer:
                    self._pending.extend(self._packer.flush())
                if len(self._pending) < self.batch_size:
                    return  # drop the ragged remainder: batch shape is static
            batch = examples_to_batch(self._pending[: self.batch_size])
            self._pending = self._pending[self.batch_size :]
            self._batches_emitted += 1
            yield batch

    # -------------------------------------------------------------- state

    def state_dict(self) -> Dict:
        return {
            "version": 1,
            "epoch": self._epoch,
            "file_idx": self._file_idx,
            "byte_offset": self._byte_offset,
            "doc_count": self._doc_count,
            "rng_state": self._rng.bit_generator.state,
            "buffer": [list(d) for d in self._buffer],
            "packer": self._packer.state_dict(),
            "pending": [
                {k: np.asarray(v).tolist() for k, v in ex.items()}
                for ex in self._pending
            ],
            "batches_emitted": self._batches_emitted,
            "exhausted": self._exhausted,
            "io_retries": self._n_io_retries,
            "skipped_lines": self._n_skipped_lines,
        }

    def load_state_dict(self, state: Dict) -> None:
        assert state.get("version") == 1, state.get("version")
        if self._fh is not None:
            self._fh.close()
            self._fh = None
        self._epoch = int(state["epoch"])
        self._file_idx = int(state["file_idx"])
        self._byte_offset = int(state["byte_offset"])
        self._doc_count = int(state["doc_count"])
        self._rng = np.random.default_rng(0)
        self._rng.bit_generator.state = state["rng_state"]
        self._buffer = [list(map(int, d)) for d in state["buffer"]]
        self._packer.load_state_dict(state["packer"])
        self._pending = [
            {
                k: np.asarray(v, bool if k == "valid" else np.int32)
                for k, v in ex.items()
            }
            for ex in state["pending"]
        ]
        self._batches_emitted = int(state["batches_emitted"])
        self._exhausted = bool(state["exhausted"])
        # .get: counters were added after version 1 shipped; absent = 0
        self._n_io_retries = int(state.get("io_retries", 0))
        self._n_skipped_lines = int(state.get("skipped_lines", 0))
        self._io_streak = 0
