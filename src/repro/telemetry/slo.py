"""Serving SLO plane: streaming latency histograms + request lifecycle.

`StreamingHistogram` keeps integer counts over fixed log-spaced buckets so
p50/p99 queries are O(buckets) with no sample retention — the engine can
absorb millions of requests without growing. `ServingTelemetry` owns every
counter the engine used to keep ad hoc (step/token counts, per-expert load,
MaxVio trace, shed/deadline tallies) plus the SLO histograms:

  - TTFT  = t_first_token - t_submitted (includes queue wait)
  - ITL   = (t_done - t_first_token) / max(n_generated - 1, 1)
  - queue wait = t_admitted - t_submitted

Per-request lifecycle records ('kind': 'serve_request') and the final
summary ('kind': 'serve_summary') flow through the same Sink API as
training metrics. All timestamps come from the engine's injectable clock,
so deterministic-clock tests exercise the full SLO path.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import numpy as np

from .sinks import Sink


class StreamingHistogram:
    """Fixed log-spaced buckets with integer counts and quantile queries.

    Bucket edges span [lo, hi) multiplicatively; values below lo land in the
    first bucket, values at/above hi in the overflow bucket. Quantiles are
    linearly interpolated inside the owning bucket (in log space the buckets
    are narrow enough that this is within a bucket-width of exact).
    """

    def __init__(self, lo: float = 1e-4, hi: float = 1e3, n_buckets: int = 128):
        assert lo > 0 and hi > lo and n_buckets >= 2
        self.edges = np.logspace(np.log10(lo), np.log10(hi), n_buckets + 1)
        self.counts = np.zeros(n_buckets + 1, dtype=np.int64)  # [+overflow]
        self.n = 0
        self._sum = 0.0
        self._max = 0.0

    def add(self, value: float) -> None:
        v = float(value)
        if not np.isfinite(v) or v < 0:
            return
        i = int(np.searchsorted(self.edges, v, side="right")) - 1
        i = min(max(i, 0), len(self.counts) - 1)
        self.counts[i] += 1
        self.n += 1
        self._sum += v
        self._max = max(self._max, v)

    def quantile(self, p: float) -> float:
        if self.n == 0:
            return 0.0
        target = p * self.n
        cum = np.cumsum(self.counts)
        i = int(np.searchsorted(cum, target, side="left"))
        i = min(i, len(self.counts) - 1)
        if i >= len(self.edges) - 1:  # overflow bucket has no right edge
            return self._max
        lo, hi = self.edges[i], self.edges[i + 1]
        prev = cum[i - 1] if i > 0 else 0
        frac = (target - prev) / max(self.counts[i], 1)
        return float(lo + (hi - lo) * min(max(frac, 0.0), 1.0))

    @property
    def mean(self) -> float:
        return self._sum / self.n if self.n else 0.0

    def to_dict(self) -> Dict[str, Any]:
        nz = np.nonzero(self.counts)[0]
        return {
            "n": int(self.n),
            "mean": self.mean,
            "max": self._max,
            "p50": self.quantile(0.50),
            "p90": self.quantile(0.90),
            "p99": self.quantile(0.99),
            # sparse bucket encoding keeps summary records compact
            "bucket_lo": [float(self.edges[i]) for i in nz],
            "bucket_count": [int(self.counts[i]) for i in nz],
        }

    def reset(self) -> None:
        self.counts[:] = 0
        self.n = 0
        self._sum = 0.0
        self._max = 0.0


class ServingTelemetry:
    """All engine-side observability state, reset-able between measured phases.

    The engine exposes these fields through read-only properties so existing
    consumers (`eng.expert_load`, `eng.n_steps`, ...) keep working; benchmark
    warmup resets go through `reset()` instead of poking engine attributes.
    """

    def __init__(self, n_experts: int, sink: Optional[Sink] = None):
        self.n_experts = n_experts
        self.sink = sink
        self.reset()

    def reset(self) -> None:
        self.n_steps = 0
        self.prefill_tokens = 0
        self.decode_tokens = 0
        self.expert_load = np.zeros(self.n_experts, dtype=np.float64)
        self.max_vio_per_step: List[float] = []
        self.n_deadline_missed = 0
        self.n_shed = 0
        self.n_finished = 0
        self.queue_depth: List[int] = []
        self.ttft = StreamingHistogram()
        self.itl = StreamingHistogram()
        self.queue_wait = StreamingHistogram()

    # -- engine step hooks ------------------------------------------------
    def on_step(self, mets, n_prefill: int, n_decode: int, queue_depth: int) -> None:
        self.n_steps += 1
        self.prefill_tokens += n_prefill
        self.decode_tokens += n_decode
        self.expert_load += np.asarray(mets["moe_load"], np.float64)
        self.max_vio_per_step.append(float(mets["max_vio"]))
        self.queue_depth.append(int(queue_depth))

    def on_finish(self, req, n_generated: int) -> None:
        """Record a finished request's lifecycle; req carries the timestamps."""
        self.n_finished += 1
        if req.finish_reason in ("shed", "timeout"):
            self.n_shed += 1
        elif req.finish_reason in ("deadline", "expired"):
            self.n_deadline_missed += 1
        ttft = itl = qwait = None
        if req.t_first_token is not None and req.t_submitted is not None:
            ttft = req.t_first_token - req.t_submitted
            self.ttft.add(ttft)
        if req.t_admitted is not None and req.t_submitted is not None:
            qwait = req.t_admitted - req.t_submitted
            self.queue_wait.add(qwait)
        if (
            req.t_done is not None
            and req.t_first_token is not None
            and n_generated > 1
        ):
            itl = (req.t_done - req.t_first_token) / (n_generated - 1)
            self.itl.add(itl)
        if self.sink is not None:
            self.sink.emit(
                {
                    "kind": "serve_request",
                    "rid": req.req_id,
                    "finish_reason": req.finish_reason,
                    "n_generated": n_generated,
                    "t_submitted": req.t_submitted,
                    "t_admitted": req.t_admitted,
                    "t_first_token": req.t_first_token,
                    "t_done": req.t_done,
                    "ttft": ttft,
                    "itl": itl,
                    "queue_wait": qwait,
                }
            )

    # -- derived views ----------------------------------------------------
    def throughput(self, wall_s: float, n_devices: int = 1) -> Dict[str, Any]:
        """Tokens/s views of a measured phase. The telemetry plane has no
        wall clock or device context of its own (steps are timed by the
        caller, the engine may or may not sit on a mesh), so both are
        supplied here; tokens/s/device is the serving roofline axis the
        throughput bench reports per mesh shape."""
        total = self.prefill_tokens + self.decode_tokens
        tps = total / wall_s if wall_s > 0 else 0.0
        return {
            "tokens": int(total),
            "wall_s": float(wall_s),
            "tokens_per_s": tps,
            "n_devices": int(n_devices),
            "tokens_per_s_per_device": tps / max(int(n_devices), 1),
        }

    def live_max_vio(self) -> float:
        """MaxVio of the cumulative per-expert load seen so far."""
        total = self.expert_load.sum()
        if total <= 0:
            return 0.0
        mean = total / self.n_experts
        return float(self.expert_load.max() / mean - 1.0)

    def summary(self) -> Dict[str, Any]:
        return {
            "kind": "serve_summary",
            "n_steps": self.n_steps,
            "n_finished": self.n_finished,
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "n_deadline_missed": self.n_deadline_missed,
            "n_shed": self.n_shed,
            "expert_load": self.expert_load.tolist(),
            "live_max_vio": self.live_max_vio(),
            "mean_step_max_vio": (
                float(np.mean(self.max_vio_per_step)) if self.max_vio_per_step else 0.0
            ),
            "queue_depth_max": max(self.queue_depth, default=0),
            "queue_depth_mean": (
                float(np.mean(self.queue_depth)) if self.queue_depth else 0.0
            ),
            "ttft": self.ttft.to_dict(),
            "itl": self.itl.to_dict(),
            "queue_wait": self.queue_wait.to_dict(),
        }

    def emit_summary(self) -> Dict[str, Any]:
        s = self.summary()
        if self.sink is not None:
            self.sink.emit(s)
        return s


__all__ = ["ServingTelemetry", "StreamingHistogram"]
