"""Metrics plane: in-graph MetricStream + host-side drain (TrainTelemetry).

The contract (DESIGN.md §Observability):

* **In-graph accumulation, zero added syncs.** `MetricStream.accumulate`
  scatters this step's metric values into a ring buffer row
  (`slot = step % flush_every`) inside the jit'd train step. The buffer is
  an ordinary extra argument/output of the compiled step — it is NOT
  donated (the host keeps in-flight async copies of drained windows alive),
  and every value written is one the step already computed, so the
  instrumented program differs from the bare one only by the scatters.
  The train loop already blocks on `mets['loss']` each step; telemetry
  introduces no additional `block_until_ready`.

* **Asynchronous drain.** Every `flush_every` steps the host snapshots the
  device buffer with `copy_to_host_async()` and swaps in the zero template;
  the snapshot is only materialized (np.asarray → sink records) one window
  later (or at `finish()`), by which point the copy has long completed under
  the subsequent steps' compute.

* **Integer load histograms.** Per-expert load keys must arrive as integer
  counts (`LOAD_HIST_KEYS`); `MetricStream.build` asserts it. This is the
  bit-stability contract of the dtype audit: a count histogram psum'd
  across shards in int32 is exact, so local/global sync and any shard
  topology produce identical telemetry.

Rollback interaction: a guard rollback replays steps, so a window drained
before the rollback may contain rows for steps that are later re-emitted.
Replay is deterministic (bit-identical to the skip-in-place run), so
duplicates agree; `metrics_report` dedups by step keeping the last record.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .sinks import Sink
from .trace import named_span

# per-expert load histogram keys: integer counts end-to-end (no float
# round-trip) — the telemetry dtype-audit contract
LOAD_HIST_KEYS = ("load", "moe_load", "load_per_layer")

# per-metric element cap: anything larger than this is not a metric but an
# activation that leaked into the mets dict — refuse to buffer it
MAX_METRIC_ELEMS = 65536


def _is_load_key(name: str) -> bool:
    return name in LOAD_HIST_KEYS


class MetricStream:
    """Layout + in-graph ops for the (flush_every, ...) metric ring buffer."""

    def __init__(self, layout: Dict[str, Tuple[tuple, Any]], flush_every: int):
        assert flush_every >= 1
        self.layout = layout
        self.flush_every = int(flush_every)

    @classmethod
    def build(cls, mets_shapes: Dict[str, Any], flush_every: int) -> "MetricStream":
        """Derive the buffer layout from a mets pytree of ShapeDtypeStructs
        (from `jax.eval_shape` on the un-instrumented step) or live arrays."""
        layout: Dict[str, Tuple[tuple, Any]] = {}
        for name in sorted(mets_shapes):
            v = mets_shapes[name]
            shape, dtype = tuple(v.shape), jnp.dtype(v.dtype)
            if not (
                jnp.issubdtype(dtype, jnp.number) or dtype == jnp.bool_
            ):
                continue
            if int(np.prod(shape, dtype=np.int64)) > MAX_METRIC_ELEMS:
                continue
            if dtype == jnp.bool_:
                dtype = jnp.dtype(jnp.int32)
            if _is_load_key(name):
                assert jnp.issubdtype(dtype, jnp.integer), (
                    f"load histogram {name!r} must be integer counts "
                    f"end-to-end (got {dtype}); see LOAD_HIST_KEYS"
                )
            layout[name] = (shape, dtype)
        return cls(layout, flush_every)

    def init_buffer(self) -> Dict[str, jnp.ndarray]:
        buf = {
            k: jnp.zeros((self.flush_every,) + shape, dtype)
            for k, (shape, dtype) in self.layout.items()
        }
        # slot occupancy marker: -1 = never written (skipped on drain)
        buf["_step"] = jnp.full((self.flush_every,), -1, jnp.int32)
        return buf

    def accumulate(
        self,
        buf: Dict[str, jnp.ndarray],
        mets: Dict[str, jnp.ndarray],
        step_idx: jnp.ndarray,
    ) -> Dict[str, jnp.ndarray]:
        """Scatter this step's metrics into the ring row (traced, jit-safe)."""
        with named_span("telemetry/accumulate"):
            slot = jnp.mod(step_idx, self.flush_every)
            new = dict(buf)
            for k, (_, dtype) in self.layout.items():
                new[k] = buf[k].at[slot].set(mets[k].astype(dtype))
            new["_step"] = buf["_step"].at[slot].set(step_idx.astype(jnp.int32))
        return new


class TrainTelemetry:
    """Host driver: owns the stream, the device buffer, and the async drain.

    Usage (train_loop wires this):
        tel = TrainTelemetry(sink, flush_every=10)
        tel.ensure_built(jax.eval_shape(step, ...)[1])   # mets structs
        ...
        state, mets, buf = step_fn(state, batch, tel.buf, step_idx)
        tel.note_step_time(i, dt)
        tel.after_step(i, buf)     # drains when the window closes
        ...
        tel.finish()               # partial window + remaining pendings
    """

    def __init__(
        self,
        sink: Optional[Sink] = None,
        flush_every: int = 10,
        run_meta: Optional[Dict[str, Any]] = None,
        profiler=None,
    ):
        self.sink = sink
        self.profiler = profiler  # optional trace.Profiler ([N, M] windowed)
        self.flush_every = int(flush_every)
        self.stream: Optional[MetricStream] = None
        self.buf: Optional[Dict[str, jnp.ndarray]] = None
        self._buf0: Optional[Dict[str, jnp.ndarray]] = None
        self._pending: List[Dict[str, jnp.ndarray]] = []
        self._step_times: Dict[int, float] = {}
        self.n_records = 0
        if run_meta is not None and sink is not None:
            sink.emit({"kind": "run_meta", **run_meta})

    @property
    def built(self) -> bool:
        return self.stream is not None

    def ensure_built(self, mets_shapes: Dict[str, Any]) -> None:
        if self.stream is None:
            self.stream = MetricStream.build(mets_shapes, self.flush_every)
            self._buf0 = self.stream.init_buffer()
            self.buf = self._buf0

    def before_step(self, step: int) -> None:
        """Pre-step hook: drives the profiler's capture window."""
        if self.profiler is not None:
            self.profiler.step(step)

    def note_step_time(self, step: int, dt: float) -> None:
        self._step_times[step] = dt

    def after_step(self, step: int, buf: Dict[str, jnp.ndarray]) -> None:
        """Adopt the step's returned buffer; drain at window boundaries."""
        self.buf = buf
        if (step + 1) % self.flush_every == 0:
            self._start_drain()

    def event(self, record: Dict[str, Any]) -> None:
        """Emit a guard/fault/lifecycle event record immediately."""
        if self.sink is not None:
            rec = dict(record)
            rec.setdefault("kind", "event")
            self.sink.emit(rec)

    def _start_drain(self) -> None:
        if self.buf is None or self.buf is self._buf0:
            return
        snap = self.buf
        for v in snap.values():
            try:
                v.copy_to_host_async()
            except AttributeError:
                pass  # np arrays under eager/test harnesses
        self._pending.append(snap)
        self.buf = self._buf0
        # materialize older snapshots only — the newest keeps overlapping
        # with the next window's compute
        while len(self._pending) > 1:
            self._materialize(self._pending.pop(0))

    def _materialize(self, snap: Dict[str, jnp.ndarray]) -> None:
        host = {k: np.asarray(v) for k, v in snap.items()}
        steps = host.pop("_step")
        for j in np.argsort(steps, kind="stable"):
            s = int(steps[j])
            if s < 0:
                continue  # never-written slot of a partial window
            rec: Dict[str, Any] = {"kind": "train_step", "step": s}
            dt = self._step_times.pop(s, None)
            if dt is not None:
                rec["step_time"] = dt
            for k, col in host.items():
                rec[k] = col[j]
            self.n_records += 1
            if self.sink is not None:
                self.sink.emit(rec)

    def finish(self) -> None:
        """Drain the partial window and every outstanding snapshot."""
        self._start_drain()
        while self._pending:
            self._materialize(self._pending.pop(0))
        if self.profiler is not None:
            self.profiler.close()


class MetricSeries:
    """Append-only host-side column store (backs TrainLog's list views).

    Columns are created on first sight and back-padded with None so every
    column always has one entry per appended record; `truncate` supports
    the rollback rewind.
    """

    def __init__(self):
        self._cols: Dict[str, List[Any]] = {}
        self._n = 0

    def append(self, record: Dict[str, Any]) -> None:
        for k in self._cols:
            self._cols[k].append(record.get(k))
        for k, v in record.items():
            if k not in self._cols:
                self._cols[k] = [None] * self._n + [v]
        self._n += 1

    def column(self, name: str) -> List[Any]:
        return self._cols.get(name, [])

    def truncate(self, n: int) -> None:
        n = max(0, int(n))
        for k in self._cols:
            self._cols[k] = self._cols[k][:n]
        self._n = min(self._n, n)

    def __len__(self) -> int:
        return self._n


__all__ = [
    "LOAD_HIST_KEYS",
    "MetricSeries",
    "MetricStream",
    "TrainTelemetry",
]
