"""Pluggable metric sinks (DESIGN.md §Observability).

A sink consumes flat dict records — one per train step, guard event,
serving request, or summary — and owns its own durability. The contract is
deliberately tiny so every telemetry producer (MetricStream drains, the
serving SLO tracker, guard events) shares one export path:

    sink.emit(record)   # record: JSON-serializable dict with a 'kind' key
    sink.close()        # flush + release; emit after close raises

`JSONLSink` is the canonical format (one JSON object per line, append-only,
crash-tolerant: a torn final line is ignorable). `CSVSink` flattens records
onto a fixed header inferred from the first record of each kind (one file
per kind, since train steps and serve requests share no columns).
`MemorySink` backs tests and the terminal reporter. `MultiSink` fans out.

`open_sink(path)` resolves a writer by extension so launchers need one flag.
"""
from __future__ import annotations

import csv
import json
import os
from typing import Any, Dict, List, Optional

import numpy as np


def _jsonable(v):
    """Coerce numpy/jax scalars and arrays into JSON-native types."""
    if isinstance(v, (np.generic,)):
        return v.item()
    if hasattr(v, "tolist"):  # np.ndarray / jax.Array
        return np.asarray(v).tolist()
    if isinstance(v, dict):
        return {k: _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return v


class Sink:
    """Base sink: emit() records, close() when done."""

    closed: bool = False

    def emit(self, record: Dict[str, Any]) -> None:
        raise NotImplementedError

    def close(self) -> None:
        self.closed = True

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class MemorySink(Sink):
    """Collects records in a list — tests and the terminal reporter."""

    def __init__(self):
        self.records: List[Dict[str, Any]] = []

    def emit(self, record: Dict[str, Any]) -> None:
        assert not self.closed, "emit() after close()"
        self.records.append(_jsonable(record))


class JSONLSink(Sink):
    """One JSON object per line, append-friendly and crash-tolerant."""

    def __init__(self, path: str):
        self.path = path
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._f = open(path, "w")

    def emit(self, record: Dict[str, Any]) -> None:
        assert not self.closed, "emit() after close()"
        self._f.write(json.dumps(_jsonable(record)) + "\n")

    def close(self) -> None:
        if not self.closed:
            self._f.flush()
            self._f.close()
        super().close()


class CSVSink(Sink):
    """Flat CSV, one file per record kind (<stem>.<kind>.csv).

    Array-valued fields are JSON-encoded into their cell so the row stays
    one line; the header is fixed by the first record of each kind and
    later records are projected onto it (missing fields empty, extras
    dropped) — CSV is the lossy convenience format, JSONL the faithful one.
    """

    def __init__(self, path: str):
        self.path = path
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._stem = path[:-4] if path.endswith(".csv") else path
        self._files: Dict[str, Any] = {}
        self._writers: Dict[str, csv.DictWriter] = {}

    def _cell(self, v):
        v = _jsonable(v)
        if isinstance(v, (list, dict)):
            return json.dumps(v)
        return v

    def emit(self, record: Dict[str, Any]) -> None:
        assert not self.closed, "emit() after close()"
        kind = str(record.get("kind", "record"))
        if kind not in self._writers:
            f = open(f"{self._stem}.{kind}.csv", "w", newline="")
            w = csv.DictWriter(f, fieldnames=list(record), extrasaction="ignore")
            w.writeheader()
            self._files[kind], self._writers[kind] = f, w
        self._writers[kind].writerow(
            {k: self._cell(record.get(k, "")) for k in self._writers[kind].fieldnames}
        )

    def close(self) -> None:
        if not self.closed:
            for f in self._files.values():
                f.flush()
                f.close()
        super().close()


class MultiSink(Sink):
    """Fan one emit out to several sinks."""

    def __init__(self, *sinks: Sink):
        self.sinks = [s for s in sinks if s is not None]

    def emit(self, record: Dict[str, Any]) -> None:
        for s in self.sinks:
            s.emit(record)

    def close(self) -> None:
        for s in self.sinks:
            s.close()
        super().close()


def open_sink(path: Optional[str]) -> Optional[Sink]:
    """Resolve a sink from a launcher --telemetry path (None passes through)."""
    if path is None:
        return None
    if path.endswith(".csv"):
        return CSVSink(path)
    return JSONLSink(path)


__all__ = [
    "CSVSink",
    "JSONLSink",
    "MemorySink",
    "MultiSink",
    "Sink",
    "open_sink",
]
