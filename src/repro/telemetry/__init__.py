"""Unified telemetry subsystem (DESIGN.md §Observability).

Three planes behind one sink API:

* metrics — in-graph `MetricStream` ring buffer accumulated inside the
  jit'd train step, drained to host asynchronously every `flush_every`
  steps (`TrainTelemetry`); integer per-expert load histograms, MaxVio,
  BIP dual health, dispatch stats, guard events.
* tracing — `named_span` (jax.named_scope, in-graph) / `trace_span`
  (profiler annotation, host-side) + `Profiler` windows for `--profile N:M`.
* serving SLOs — `ServingTelemetry` streaming TTFT / inter-token-latency /
  queue-wait histograms, per-expert live load, shed/deadline counters.

`metrics_report` renders a sink file on the terminal or as HTML.
"""
from repro.telemetry.metrics import (
    LOAD_HIST_KEYS,
    MetricSeries,
    MetricStream,
    TrainTelemetry,
)
from repro.telemetry.sinks import (
    CSVSink,
    JSONLSink,
    MemorySink,
    MultiSink,
    Sink,
    open_sink,
)
from repro.telemetry.slo import ServingTelemetry, StreamingHistogram
from repro.telemetry.trace import Profiler, named_span, profile_window, trace_span

__all__ = [
    "CSVSink",
    "JSONLSink",
    "LOAD_HIST_KEYS",
    "MemorySink",
    "MetricSeries",
    "MetricStream",
    "MultiSink",
    "Profiler",
    "ServingTelemetry",
    "Sink",
    "StreamingHistogram",
    "TrainTelemetry",
    "named_span",
    "open_sink",
    "profile_window",
    "trace_span",
]
