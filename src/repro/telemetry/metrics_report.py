"""Summarize a telemetry JSONL sink on the terminal or as HTML.

    python -m repro.telemetry.metrics_report run.jsonl [--html report.html]

Reads the records a training/serving run emitted (train_step / event /
serve_request / serve_summary / run_meta), dedups replayed train steps
(rollback re-emits deterministic duplicates — last record wins), and prints:

* step-time p50/p99 (post-warmup), final loss/ppl
* per-layer AvgMaxVio / SupMaxVio and the per-expert load observatory
  (total counts per expert per layer, imbalance = max/mean)
* BIP dual health (q magnitude, forecaster error / window-hit rate)
* guard/fault events
* serving TTFT / ITL / queue-wait quantiles and shed/deadline counters

The HTML report is self-contained (inline SVG bars, no external assets).
"""
from __future__ import annotations

import argparse
import html
import json
import sys
from typing import Any, Dict, List, Optional

import numpy as np


def load_records(path: str) -> List[Dict[str, Any]]:
    records = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError:
                continue  # torn final line of a crashed run
    return records


def dedup_steps(records: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Keep the LAST record per step (rollback replays re-emit steps)."""
    by_step: Dict[int, Dict[str, Any]] = {}
    for r in records:
        if r.get("kind") == "train_step":
            by_step[int(r["step"])] = r
    return [by_step[s] for s in sorted(by_step)]


def _col(steps: List[Dict[str, Any]], key: str) -> List[Any]:
    return [r[key] for r in steps if key in r and r[key] is not None]


def _q(vals, p):
    return float(np.percentile(vals, p)) if len(vals) else None


def summarize(records: List[Dict[str, Any]]) -> Dict[str, Any]:
    steps = dedup_steps(records)
    events = [r for r in records if r.get("kind") == "event"]
    serve = [r for r in records if r.get("kind") == "serve_summary"]
    out: Dict[str, Any] = {"n_steps": len(steps), "n_events": len(events)}

    times = _col(steps, "step_time")
    if len(times) > 2:
        times = times[2:]  # drop compile steps
    if times:
        out["step_time_p50"] = _q(times, 50)
        out["step_time_p99"] = _q(times, 99)

    losses = _col(steps, "ce_loss") or _col(steps, "loss")
    if losses:
        out["final_loss"] = float(losses[-1])
    ppl = _col(steps, "perplexity")
    if ppl:
        out["final_ppl"] = float(ppl[-1])

    vios = _col(steps, "max_vio_per_layer")
    if vios:
        v = np.asarray(vios, np.float64)  # (T, L)
        if v.ndim == 2 and v.shape[1]:
            out["AvgMaxVio_per_layer"] = v.mean(axis=0).tolist()
            out["SupMaxVio_per_layer"] = v.max(axis=0).tolist()
            out["AvgMaxVio"] = float(v.max(axis=1).mean())
            out["SupMaxVio"] = float(v.max())

    loads = _col(steps, "load_per_layer")
    if loads:
        ld = np.asarray(loads, np.int64)  # (T, L, m)
        if ld.ndim == 3 and ld.size:
            total = ld.sum(axis=0)  # (L, m)
            out["load_total_per_layer"] = total.tolist()
            mean = np.maximum(total.mean(axis=1, keepdims=True), 1e-9)
            out["load_imbalance_per_layer"] = (
                total.max(axis=1) / mean[:, 0]
            ).tolist()

    for key in ("q_abs_max_per_layer", "forecast_err_per_layer"):
        col = _col(steps, key)
        if col:
            out[key.replace("_per_layer", "_final")] = np.asarray(
                col[-1], np.float64
            ).tolist()
    hits = _col(steps, "forecast_hit_per_layer")
    if hits:
        out["forecast_hit_rate"] = float(np.mean(np.asarray(hits, np.float64)))

    dropped = _col(steps, "dropped_frac_cap1_per_layer")
    if dropped:
        out["dropped_frac_cap1_mean"] = float(
            np.mean(np.asarray(dropped, np.float64))
        )

    if events:
        out["events"] = [dict(e) for e in events]
    if serve:
        out["serve"] = serve[-1]
    return out


def print_summary(s: Dict[str, Any], file=sys.stdout) -> None:
    p = lambda *a: print(*a, file=file)
    p(f"telemetry: {s['n_steps']} train steps, {s['n_events']} events")
    if "step_time_p50" in s:
        p(
            f"  step time  p50 {s['step_time_p50'] * 1e3:8.2f} ms   "
            f"p99 {s['step_time_p99'] * 1e3:8.2f} ms"
        )
    if "final_loss" in s:
        line = f"  final loss {s['final_loss']:.4f}"
        if "final_ppl" in s:
            line += f"   ppl {s['final_ppl']:.2f}"
        p(line)
    if "AvgMaxVio" in s:
        p(f"  AvgMaxVio {s['AvgMaxVio']:.4f}   SupMaxVio {s['SupMaxVio']:.4f}")
        per = s.get("AvgMaxVio_per_layer", [])
        for i, (a, m) in enumerate(zip(per, s.get("SupMaxVio_per_layer", per))):
            p(f"    layer {i:2d}  avg {a:7.4f}  sup {m:7.4f}")
    if "load_imbalance_per_layer" in s:
        p("  per-expert load (total counts; imbalance = max/mean):")
        for i, imb in enumerate(s["load_imbalance_per_layer"]):
            p(f"    layer {i:2d}  imbalance {imb:6.3f}")
    if "q_abs_max_final" in s:
        q = s["q_abs_max_final"]
        p(f"  dual |q| max (final): {max(q):.4f}")
    if "forecast_hit_rate" in s:
        p(f"  forecaster window-hit rate: {s['forecast_hit_rate']:.3f}")
    for e in s.get("events", []):
        p(f"  event: {e}")
    if "serve" in s:
        sv = s["serve"]
        p(
            f"  serving: {sv.get('n_finished', 0)} finished / "
            f"{sv.get('n_shed', 0)} shed / "
            f"{sv.get('n_deadline_missed', 0)} deadline-missed"
        )
        for name in ("ttft", "itl", "queue_wait"):
            h = sv.get(name)
            if h and h.get("n"):
                p(
                    f"    {name:10s} p50 {h['p50'] * 1e3:8.2f} ms  "
                    f"p99 {h['p99'] * 1e3:8.2f} ms  (n={h['n']})"
                )
        p(f"    live MaxVio {sv.get('live_max_vio', 0.0):.4f}")


def _svg_bars(values, width=640, height=60, color="#4a7") -> str:
    if not values:
        return ""
    vmax = max(max(values), 1e-9)
    n = len(values)
    bw = width / n
    bars = []
    for i, v in enumerate(values):
        h = (v / vmax) * (height - 2)
        bars.append(
            f'<rect x="{i * bw:.1f}" y="{height - h:.1f}" '
            f'width="{max(bw - 1, 1):.1f}" height="{h:.1f}" fill="{color}"/>'
        )
    return (
        f'<svg width="{width}" height="{height}" '
        f'xmlns="http://www.w3.org/2000/svg">' + "".join(bars) + "</svg>"
    )


def write_html(s: Dict[str, Any], path: str) -> None:
    parts = [
        "<!doctype html><meta charset='utf-8'><title>telemetry report</title>",
        "<style>body{font-family:monospace;margin:2em}td,th{padding:2px 8px;"
        "text-align:right}table{border-collapse:collapse}th{border-bottom:"
        "1px solid #999}</style>",
        "<h1>telemetry report</h1>",
    ]
    rows = "".join(
        f"<tr><td>{html.escape(str(k))}</td>"
        f"<td>{html.escape(str(v))}</td></tr>"
        for k, v in s.items()
        if not isinstance(v, (list, dict))
    )
    parts.append(f"<table><tr><th>metric</th><th>value</th></tr>{rows}</table>")
    for i, layer in enumerate(s.get("load_total_per_layer", [])):
        parts.append(f"<h3>layer {i} per-expert load</h3>{_svg_bars(layer)}")
    if "serve" in s:
        parts.append("<h2>serving</h2>")
        for name in ("ttft", "itl", "queue_wait"):
            h = s["serve"].get(name)
            if h and h.get("n"):
                parts.append(
                    f"<h3>{name}: p50 {h['p50'] * 1e3:.2f} ms / "
                    f"p99 {h['p99'] * 1e3:.2f} ms</h3>"
                    + _svg_bars(h.get("bucket_count", []))
                )
    with open(path, "w") as f:
        f.write("\n".join(parts))


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("path", help="telemetry JSONL file")
    ap.add_argument("--html", default=None, help="also write an HTML report")
    args = ap.parse_args(argv)
    s = summarize(load_records(args.path))
    print_summary(s)
    if args.html:
        write_html(s, args.html)
        print(f"wrote {args.html}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
