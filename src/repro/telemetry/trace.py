"""Tracing plane: span annotations + profiler capture windows.

Two span flavors, one naming convention ("area/phase", lowercase, slash
separated — e.g. "router/score_adjust", "moe/gemm", "train/fwd_bwd"):

  - `named_span(name)` — `jax.named_scope`: names the ops emitted under it
    in the HLO/jaxpr, so XLA profiles and compiler dumps attribute cost to
    the right phase. Safe inside jit/scan/shard_map; zero runtime cost.
  - `trace_span(name)` — `jax.profiler.TraceAnnotation`: a host-side span
    on the profiler timeline for Python-level phases (compile, flush,
    engine step). Must NOT wrap traced code — use named_span there.

`profile_window("N:M")` parses the launcher `--profile` flag; `Profiler`
starts `jax.profiler.start_trace` when the step counter enters [N, M] and
stops after M, so a capture costs nothing outside the window.
"""
from __future__ import annotations

import contextlib
import os
from typing import Optional, Tuple

import jax


def named_span(name: str):
    """In-graph scope: names HLO ops for profile attribution (jit-safe)."""
    return jax.named_scope(name)


def trace_span(name: str):
    """Host-side profiler span for un-traced Python phases."""
    try:
        return jax.profiler.TraceAnnotation(name)
    except Exception:  # profiler backend unavailable (e.g. stripped builds)
        return contextlib.nullcontext()


def profile_window(spec: Optional[str]) -> Optional[Tuple[int, int]]:
    """Parse a --profile 'N:M' flag into an inclusive (start, stop) window."""
    if not spec:
        return None
    try:
        lo_s, hi_s = spec.split(":")
        lo, hi = int(lo_s), int(hi_s)
    except ValueError as e:
        raise ValueError(f"--profile expects 'N:M' (got {spec!r})") from e
    if lo < 0 or hi < lo:
        raise ValueError(f"--profile window must satisfy 0 <= N <= M (got {spec!r})")
    return lo, hi


class Profiler:
    """Capture a jax profiler trace for steps N..M (inclusive).

    Call `step(i)` with the current step index each iteration; the trace
    starts on entering the window and stops after leaving it (or at
    `close()` if the run ends mid-window). Idempotent and inert when
    window is None.
    """

    def __init__(self, window: Optional[Tuple[int, int]], log_dir: str = "profile"):
        self.window = window
        self.log_dir = log_dir
        self.active = False

    def step(self, i: int) -> None:
        if self.window is None:
            return
        lo, hi = self.window
        if not self.active and lo <= i <= hi:
            os.makedirs(self.log_dir, exist_ok=True)
            jax.profiler.start_trace(self.log_dir)
            self.active = True
        elif self.active and i > hi:
            jax.profiler.stop_trace()
            self.active = False

    def close(self) -> None:
        if self.active:
            jax.profiler.stop_trace()
            self.active = False


__all__ = ["Profiler", "named_span", "profile_window", "trace_span"]
