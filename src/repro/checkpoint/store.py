"""Checkpointing: flatten a pytree to an .npz with path-encoded keys.

Design notes for the production mesh: arrays are fetched with
jax.device_get, which gathers sharded arrays to host — fine for the model
sizes we *train* here. The format keeps dtype (incl. bfloat16 via a view
trick) and the exact tree structure, so save->load roundtrips through jit
boundaries and across strategy changes (router state q is a plain leaf).

Async saves (`save_train_state(..., block=False)`): the main thread takes
a *device-side copy* of every leaf (safe against the next step donating
the original buffers), kicks off the device→host transfers, and hands the
copies to a writer thread that gathers + writes the npz while the step
loop keeps running. Saves are serialized — the next save (and `wait()`)
barriers on the previous writer, so at most one write is in flight and
checkpoints land in step order.

Data-stream cursors (`data/loader.py` state_dict) ride in a JSON sidecar
`step_N.data.json` next to the TrainState npz, kept/garbage-collected as
one unit with it.

Integrity (DESIGN.md §Robustness): every leaf's crc32 is recorded in the
npz meta at save time and re-checked by `load_pytree(..., verify=True)`;
the manager additionally writes a `step_N.manifest.json` sidecar (file
size + whole-file crc32) so truncation/bitrot is detectable WITHOUT
parsing the archive. `restore(step=None)` walks checkpoints newest-first
and returns the newest one that deep-verifies; `_gc` counts only
manifest-valid checkpoints toward `keep`, so a corrupt in-flight save can
never evict the last good state.
"""
from __future__ import annotations

import json
import os
import re
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "|"


class CheckpointCorruptError(RuntimeError):
    """A checkpoint failed integrity verification (crc/size mismatch, or
    the npz itself is unreadable)."""


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{_SEP}d:{k}" if prefix else f"d:{k}"))
    elif isinstance(tree, (list, tuple)):
        tag = "l" if isinstance(tree, list) else "t"
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{_SEP}{tag}:{i}" if prefix else f"{tag}:{i}"))
    elif tree is None:
        out[prefix or "root"] = None  # marked via meta dtype 'NoneType'
    else:
        out[prefix or "root"] = tree
    return out


def _set_path(root, parts, value):
    node = root
    for i, (tag, key) in enumerate(parts[:-1]):
        nxt_tag, nxt_key = parts[i + 1]
        container = node.setdefault if isinstance(node, dict) else None
        k = key if tag == "d" else int(key)
        default = {} if nxt_tag == "d" else []
        if isinstance(node, dict):
            node = node.setdefault(k, default)
        else:
            while len(node) <= k:
                node.append(None)
            if node[k] is None:
                node[k] = default
            node = node[k]
    tag, key = parts[-1]
    k = key if tag == "d" else int(key)
    if tag == "n":
        value = None
    if isinstance(node, dict):
        node[k] = value
    else:
        while len(node) <= k:
            node.append(None)
        node[k] = value


def save_pytree(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(jax.device_get(tree))
    arrays, meta = {}, {}
    for i, (key, val) in enumerate(flat.items()):
        name = f"a{i}"
        if val is None:
            arrays[name] = np.zeros((0,), np.int8)
            meta[name] = {"path": key, "dtype": "NoneType"}
            continue
        arr = np.asarray(val)
        if arr.dtype == jnp.bfloat16:
            arrays[name] = arr.view(np.uint16)
            meta[name] = {"path": key, "dtype": "bfloat16"}
        else:
            arrays[name] = arr
            meta[name] = {"path": key, "dtype": str(arr.dtype)}
        # per-leaf integrity: crc32 of the stored (viewed) bytes — checked
        # by load_pytree(verify=True) after the zip layer's own checks
        meta[name]["crc32"] = zlib.crc32(
            np.ascontiguousarray(arrays[name]).tobytes()
        )
    arrays["__meta__"] = np.frombuffer(
        json.dumps(meta).encode("utf-8"), dtype=np.uint8
    )
    # tmp + rename: readers (latest_step / async-save overlap) never see a
    # partially-written archive
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def load_pytree(path: str, verify: bool = False) -> Any:
    """Load a saved pytree. With verify=True, every leaf whose save
    recorded a crc32 is re-checked; any mismatch (or an unreadable npz)
    raises CheckpointCorruptError instead of silently restoring garbage."""
    try:
        with np.load(path) as z:
            meta = json.loads(bytes(z["__meta__"]).decode("utf-8"))
            items = []
            for name, info in meta.items():
                if info["dtype"] == "NoneType":
                    items.append((info["path"], None))
                    continue
                arr = z[name]
                if verify and "crc32" in info:
                    crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                    if crc != info["crc32"]:
                        raise CheckpointCorruptError(
                            f"{path}: leaf {info['path']!r} crc mismatch "
                            f"(stored {info['crc32']}, computed {crc})"
                        )
                if info["dtype"] == "bfloat16":
                    arr = arr.view(jnp.bfloat16)
                items.append((info["path"], arr))
    except CheckpointCorruptError:
        raise
    except Exception as e:
        if verify:
            # zipfile/np.load-level damage (truncation, bad zip crc, ...)
            raise CheckpointCorruptError(f"{path}: unreadable npz ({e})") from e
        raise
    # rebuild: parse path segments "tag:key"
    tree: Any = None
    parsed = []
    for key, arr in items:
        parts = [tuple(seg.split(":", 1)) for seg in key.split(_SEP)]
        parsed.append((parts, arr))
    # root container type from first segment
    first_tag = parsed[0][0][0][0]
    tree = {} if first_tag == "d" else []
    for parts, arr in parsed:
        _set_path(tree, parts, arr)
    # convert list-tagged nodes back to tuples where tagged 't'
    return _fix_tuples(tree, parsed)


def _fix_tuples(tree, parsed):
    # collect which paths are tuples
    tuple_paths = set()
    for parts, _ in parsed:
        for i, (tag, _key) in enumerate(parts):
            if tag == "t":
                tuple_paths.add(tuple(p for p in map(lambda x: x[1], parts[:i])))

    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, path + (k,)) for k, v in node.items()}
        if isinstance(node, list):
            items = [walk(v, path + (str(i),)) for i, v in enumerate(node)]
            return tuple(items) if path in tuple_paths else items
        return node

    return walk(tree, ())


def checkpoint_steps(ckpt_dir: str) -> List[int]:
    """All step indices with a step_N.npz present, ascending."""
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(
        int(m.group(1))
        for f in os.listdir(ckpt_dir)
        if (m := re.match(r"step_(\d+)\.npz$", f))
    )


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = checkpoint_steps(ckpt_dir)
    return steps[-1] if steps else None


# ------------------------------------------------------------- integrity


def _file_crc32(path: str, chunk: int = 1 << 20) -> int:
    crc = 0
    with open(path, "rb") as f:
        while block := f.read(chunk):
            crc = zlib.crc32(block, crc)
    return crc


def _manifest_path(npz_path: str) -> str:
    return re.sub(r"\.npz$", ".manifest.json", npz_path)


def write_manifest(npz_path: str) -> str:
    """Record the finished npz's size + whole-file crc32 in an (atomic,
    fsync'd) sidecar, so later readers can detect truncation/bitrot
    without parsing the archive."""
    manifest = {
        "version": 1,
        "file": os.path.basename(npz_path),
        "size": os.path.getsize(npz_path),
        "crc32": _file_crc32(npz_path),
    }
    out = _manifest_path(npz_path)
    tmp = out + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, out)
    return out


def manifest_valid(npz_path: str) -> Optional[bool]:
    """Cheap integrity check against the manifest sidecar: False on
    size/crc mismatch (or missing npz), True on match, None when no
    manifest exists (pre-integrity checkpoint — unknown, caller decides)."""
    mpath = _manifest_path(npz_path)
    if not os.path.exists(mpath):
        return None
    try:
        with open(mpath) as f:
            m = json.load(f)
        if os.path.getsize(npz_path) != m["size"]:
            return False
        return _file_crc32(npz_path) == m["crc32"]
    except (OSError, ValueError, KeyError):
        return False


def verify_checkpoint(npz_path: str, deep: bool = False) -> bool:
    """True when the checkpoint passes integrity checks. Shallow = manifest
    size+crc (missing manifest counts as pass, for pre-integrity files);
    deep additionally re-reads every leaf against its stored crc32."""
    if not os.path.exists(npz_path):
        return False
    if manifest_valid(npz_path) is False:
        return False
    if deep:
        try:
            load_pytree(npz_path, verify=True)
        except CheckpointCorruptError:
            return False
    return True


class CheckpointManager:
    """Keeps the most recent `keep` *valid* checkpoints under
    `dir/step_N.npz` (validity = manifest size/crc; a corrupt later save
    never counts toward `keep`, so GC cannot evict the last good state)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        self._writer: Optional[threading.Thread] = None
        self._writer_err: Optional[BaseException] = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step}.npz")

    def save(self, step: int, tree: Any) -> str:
        path = self._path(step)
        save_pytree(path, tree)
        write_manifest(path)
        self._gc()
        return path

    def wait(self) -> None:
        """Barrier on the in-flight async write (no-op when none)."""
        if self._writer is not None:
            self._writer.join()
            self._writer = None
        if self._writer_err is not None:
            err, self._writer_err = self._writer_err, None
            raise err

    def restore(self, step: Optional[int] = None) -> Tuple[int, Any]:
        """Load a checkpoint, deep-verifying integrity. With an explicit
        `step`, corruption raises CheckpointCorruptError; with step=None
        the manager walks newest -> oldest and returns the newest VALID
        checkpoint, so a truncated/bit-flipped latest save degrades to the
        previous good state instead of killing the run."""
        self.wait()  # an in-flight async write may hold the newest step
        if step is not None:
            return step, self._verified_load(self._path(step))
        last_err: Optional[BaseException] = None
        for s in reversed(checkpoint_steps(self.dir)):
            try:
                return s, self._verified_load(self._path(s))
            except CheckpointCorruptError as e:
                last_err = e
                import warnings

                warnings.warn(
                    f"checkpoint step_{s}.npz failed verification "
                    f"({e}); falling back to the previous checkpoint"
                )
        if last_err is not None:
            raise CheckpointCorruptError(
                f"no valid checkpoint in {self.dir}"
            ) from last_err
        raise FileNotFoundError(f"no checkpoints in {self.dir}")

    def _verified_load(self, path: str) -> Any:
        """Manifest (whole-file size+crc) check, then leaf-crc verifying
        load. The manifest catches damage the npz layers can miss (e.g. a
        flip inside an npy member header, which neither the zip member crc
        nor the leaf crcs cover)."""
        if manifest_valid(path) is False:
            raise CheckpointCorruptError(
                f"{path}: manifest size/crc mismatch (truncated or bit-rotted)"
            )
        return load_pytree(path, verify=True)

    # ------------------------------------------------- full training state

    def save_train_state(
        self, state, data_state: Optional[Dict] = None, block: bool = True
    ) -> str:
        """Persist a full TrainState — params, Adam moments + step counter,
        and the router states (the BIP dual q / Loss-Free bias) — under the
        step index recorded in the optimizer, so a restored run continues
        bit-exactly where this one stopped.

        `data_state` (a BatchStream cursor) lands in `step_N.data.json`.
        `block=False` overlaps the host gather + npz write with the caller's
        next steps: leaves are device-copied up front (donation-safe), then
        written on a background thread; the next save / `wait()` barriers."""
        self.wait()  # double-buffer: at most one write in flight
        step = int(jax.device_get(state.opt_state["step"]))
        tree = {
            "params": state.params,
            "opt_state": state.opt_state,
            "router_states": state.router_states,
        }
        path = self._path(step)
        if block:
            save_pytree(path, tree)
            write_manifest(path)
            self._write_data_state(step, data_state)
            self._gc()
            return path

        # device-side copy: the originals may be donated by the very next
        # train step, so the writer must never touch them
        def snap_leaf(a):
            if isinstance(a, jax.Array):
                c = jnp.copy(a)
                try:
                    c.copy_to_host_async()
                except Exception:
                    pass  # backends without async host copy just gather later
                return c
            return np.asarray(a)

        snap = jax.tree.map(snap_leaf, tree)

        def write():
            try:
                save_pytree(path, snap)
                write_manifest(path)
                self._write_data_state(step, data_state)
                self._gc()
            except BaseException as e:  # re-raised at the next wait()
                self._writer_err = e

        self._writer = threading.Thread(
            target=write, name=f"repro-ckpt-{step}", daemon=True
        )
        self._writer.start()
        return path

    def _write_data_state(self, step: int, data_state: Optional[Dict]) -> None:
        if data_state is None:
            return
        tmp = os.path.join(self.dir, f".step_{step}.data.json.tmp")
        with open(tmp, "w") as f:
            json.dump(data_state, f)
            f.flush()
            os.fsync(f.fileno())  # durable before the rename publishes it
        os.replace(tmp, os.path.join(self.dir, f"step_{step}.data.json"))

    def restore_data_state(self, step: Optional[int] = None) -> Optional[Dict]:
        """The BatchStream cursor saved with `step` (None = newest), or None
        when that checkpoint predates the data pipeline / used a plain
        iterable."""
        step = step if step is not None else latest_step(self.dir)
        if step is None:
            return None
        path = os.path.join(self.dir, f"step_{step}.data.json")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return json.load(f)

    def restore_train_state(self, step: Optional[int] = None) -> Tuple[int, Any]:
        """Inverse of save_train_state. Returns (step, TrainState) with every
        leaf at its checkpointed dtype (bf16 moments survive the npz
        roundtrip via the uint16 view)."""
        from repro.training.loop import TrainState  # avoid import cycle

        step, tree = self.restore(step)
        return step, TrainState(
            params=tree["params"],
            opt_state=tree["opt_state"],
            router_states=tree["router_states"],
        )

    def _gc(self):
        """Delete checkpoints older than the newest `keep` VALID ones.

        Validity is the cheap manifest check (missing manifest = legacy
        file, counted as valid). Walking newest->oldest and deleting only
        once `keep` valid checkpoints are newer guarantees that a corrupt
        later save — e.g. an async write that will fail verification —
        can never cause the eviction of the only good checkpoint."""
        n_valid = 0
        for s in reversed(checkpoint_steps(self.dir)):
            path = self._path(s)
            if n_valid >= self.keep:
                os.remove(path)
                for sidecar in (
                    os.path.join(self.dir, f"step_{s}.data.json"),
                    _manifest_path(path),
                ):
                    if os.path.exists(sidecar):
                        os.remove(sidecar)
            elif manifest_valid(path) is not False:
                n_valid += 1
