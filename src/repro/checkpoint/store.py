"""Checkpointing: flatten a pytree to an .npz with path-encoded keys.

Design notes for the production mesh: arrays are fetched with
jax.device_get, which gathers sharded arrays to host — fine for the model
sizes we *train* here. The format keeps dtype (incl. bfloat16 via a view
trick) and the exact tree structure, so save->load roundtrips through jit
boundaries and across strategy changes (router state q is a plain leaf).

Async saves (`save_train_state(..., block=False)`): the main thread takes
a *device-side copy* of every leaf (safe against the next step donating
the original buffers), kicks off the device→host transfers, and hands the
copies to a writer thread that gathers + writes the npz while the step
loop keeps running. Saves are serialized — the next save (and `wait()`)
barriers on the previous writer, so at most one write is in flight and
checkpoints land in step order.

Data-stream cursors (`data/loader.py` state_dict) ride in a JSON sidecar
`step_N.data.json` next to the TrainState npz, kept/garbage-collected as
one unit with it.
"""
from __future__ import annotations

import json
import os
import re
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "|"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{_SEP}d:{k}" if prefix else f"d:{k}"))
    elif isinstance(tree, (list, tuple)):
        tag = "l" if isinstance(tree, list) else "t"
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{_SEP}{tag}:{i}" if prefix else f"{tag}:{i}"))
    elif tree is None:
        out[prefix or "root"] = None  # marked via meta dtype 'NoneType'
    else:
        out[prefix or "root"] = tree
    return out


def _set_path(root, parts, value):
    node = root
    for i, (tag, key) in enumerate(parts[:-1]):
        nxt_tag, nxt_key = parts[i + 1]
        container = node.setdefault if isinstance(node, dict) else None
        k = key if tag == "d" else int(key)
        default = {} if nxt_tag == "d" else []
        if isinstance(node, dict):
            node = node.setdefault(k, default)
        else:
            while len(node) <= k:
                node.append(None)
            if node[k] is None:
                node[k] = default
            node = node[k]
    tag, key = parts[-1]
    k = key if tag == "d" else int(key)
    if tag == "n":
        value = None
    if isinstance(node, dict):
        node[k] = value
    else:
        while len(node) <= k:
            node.append(None)
        node[k] = value


def save_pytree(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(jax.device_get(tree))
    arrays, meta = {}, {}
    for i, (key, val) in enumerate(flat.items()):
        name = f"a{i}"
        if val is None:
            arrays[name] = np.zeros((0,), np.int8)
            meta[name] = {"path": key, "dtype": "NoneType"}
            continue
        arr = np.asarray(val)
        if arr.dtype == jnp.bfloat16:
            arrays[name] = arr.view(np.uint16)
            meta[name] = {"path": key, "dtype": "bfloat16"}
        else:
            arrays[name] = arr
            meta[name] = {"path": key, "dtype": str(arr.dtype)}
    arrays["__meta__"] = np.frombuffer(
        json.dumps(meta).encode("utf-8"), dtype=np.uint8
    )
    # tmp + rename: readers (latest_step / async-save overlap) never see a
    # partially-written archive
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)


def load_pytree(path: str) -> Any:
    with np.load(path) as z:
        meta = json.loads(bytes(z["__meta__"]).decode("utf-8"))
        root: Dict = {}
        items = []
        for name, info in meta.items():
            if info["dtype"] == "NoneType":
                items.append((info["path"], None))
                continue
            arr = z[name]
            if info["dtype"] == "bfloat16":
                arr = arr.view(jnp.bfloat16)
            items.append((info["path"], arr))
    # rebuild: parse path segments "tag:key"
    tree: Any = None
    parsed = []
    for key, arr in items:
        parts = [tuple(seg.split(":", 1)) for seg in key.split(_SEP)]
        parsed.append((parts, arr))
    # root container type from first segment
    first_tag = parsed[0][0][0][0]
    tree = {} if first_tag == "d" else []
    for parts, arr in parsed:
        _set_path(tree, parts, arr)
    # convert list-tagged nodes back to tuples where tagged 't'
    return _fix_tuples(tree, parsed)


def _fix_tuples(tree, parsed):
    # collect which paths are tuples
    tuple_paths = set()
    for parts, _ in parsed:
        for i, (tag, _key) in enumerate(parts):
            if tag == "t":
                tuple_paths.add(tuple(p for p in map(lambda x: x[1], parts[:i])))

    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, path + (k,)) for k, v in node.items()}
        if isinstance(node, list):
            items = [walk(v, path + (str(i),)) for i, v in enumerate(node)]
            return tuple(items) if path in tuple_paths else items
        return node

    return walk(tree, ())


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(m.group(1))
        for f in os.listdir(ckpt_dir)
        if (m := re.match(r"step_(\d+)\.npz$", f))
    ]
    return max(steps) if steps else None


class CheckpointManager:
    """Keeps the most recent `keep` checkpoints under `dir/step_N.npz`."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        self._writer: Optional[threading.Thread] = None
        self._writer_err: Optional[BaseException] = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def save(self, step: int, tree: Any) -> str:
        path = os.path.join(self.dir, f"step_{step}.npz")
        save_pytree(path, tree)
        self._gc()
        return path

    def wait(self) -> None:
        """Barrier on the in-flight async write (no-op when none)."""
        if self._writer is not None:
            self._writer.join()
            self._writer = None
        if self._writer_err is not None:
            err, self._writer_err = self._writer_err, None
            raise err

    def restore(self, step: Optional[int] = None) -> Tuple[int, Any]:
        self.wait()  # an in-flight async write may hold the newest step
        step = step if step is not None else latest_step(self.dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        return step, load_pytree(os.path.join(self.dir, f"step_{step}.npz"))

    # ------------------------------------------------- full training state

    def save_train_state(
        self, state, data_state: Optional[Dict] = None, block: bool = True
    ) -> str:
        """Persist a full TrainState — params, Adam moments + step counter,
        and the router states (the BIP dual q / Loss-Free bias) — under the
        step index recorded in the optimizer, so a restored run continues
        bit-exactly where this one stopped.

        `data_state` (a BatchStream cursor) lands in `step_N.data.json`.
        `block=False` overlaps the host gather + npz write with the caller's
        next steps: leaves are device-copied up front (donation-safe), then
        written on a background thread; the next save / `wait()` barriers."""
        self.wait()  # double-buffer: at most one write in flight
        step = int(jax.device_get(state.opt_state["step"]))
        tree = {
            "params": state.params,
            "opt_state": state.opt_state,
            "router_states": state.router_states,
        }
        path = os.path.join(self.dir, f"step_{step}.npz")
        if block:
            save_pytree(path, tree)
            self._write_data_state(step, data_state)
            self._gc()
            return path

        # device-side copy: the originals may be donated by the very next
        # train step, so the writer must never touch them
        def snap_leaf(a):
            if isinstance(a, jax.Array):
                c = jnp.copy(a)
                try:
                    c.copy_to_host_async()
                except Exception:
                    pass  # backends without async host copy just gather later
                return c
            return np.asarray(a)

        snap = jax.tree.map(snap_leaf, tree)

        def write():
            try:
                save_pytree(path, snap)
                self._write_data_state(step, data_state)
                self._gc()
            except BaseException as e:  # re-raised at the next wait()
                self._writer_err = e

        self._writer = threading.Thread(
            target=write, name=f"repro-ckpt-{step}", daemon=True
        )
        self._writer.start()
        return path

    def _write_data_state(self, step: int, data_state: Optional[Dict]) -> None:
        if data_state is None:
            return
        tmp = os.path.join(self.dir, f".step_{step}.data.json.tmp")
        with open(tmp, "w") as f:
            json.dump(data_state, f)
        os.replace(tmp, os.path.join(self.dir, f"step_{step}.data.json"))

    def restore_data_state(self, step: Optional[int] = None) -> Optional[Dict]:
        """The BatchStream cursor saved with `step` (None = newest), or None
        when that checkpoint predates the data pipeline / used a plain
        iterable."""
        step = step if step is not None else latest_step(self.dir)
        if step is None:
            return None
        path = os.path.join(self.dir, f"step_{step}.data.json")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return json.load(f)

    def restore_train_state(self, step: Optional[int] = None) -> Tuple[int, Any]:
        """Inverse of save_train_state. Returns (step, TrainState) with every
        leaf at its checkpointed dtype (bf16 moments survive the npz
        roundtrip via the uint16 view)."""
        from repro.training.loop import TrainState  # avoid import cycle

        step, tree = self.restore(step)
        return step, TrainState(
            params=tree["params"],
            opt_state=tree["opt_state"],
            router_states=tree["router_states"],
        )

    def _gc(self):
        steps = sorted(
            int(m.group(1))
            for f in os.listdir(self.dir)
            if (m := re.match(r"step_(\d+)\.npz$", f))
        )
        for s in steps[: -self.keep]:
            os.remove(os.path.join(self.dir, f"step_{s}.npz"))
            sidecar = os.path.join(self.dir, f"step_{s}.data.json")
            if os.path.exists(sidecar):
                os.remove(sidecar)
