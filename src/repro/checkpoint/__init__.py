"""repro.checkpoint — pytree <-> npz persistence."""
from repro.checkpoint.store import load_pytree, save_pytree, latest_step, CheckpointManager

__all__ = ["CheckpointManager", "latest_step", "load_pytree", "save_pytree"]
