"""repro.checkpoint — pytree <-> npz persistence with integrity checks."""
from repro.checkpoint.store import (
    CheckpointCorruptError,
    CheckpointManager,
    latest_step,
    load_pytree,
    save_pytree,
    verify_checkpoint,
)

__all__ = [
    "CheckpointCorruptError",
    "CheckpointManager",
    "latest_step",
    "load_pytree",
    "save_pytree",
    "verify_checkpoint",
]
