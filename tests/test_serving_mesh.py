"""EP-sharded serving parity (DESIGN.md §Serving).

The engine's `mesh=` path reuses the training shardings (params/cache specs
from distributed/sharding.py) and runs MoE FFN through the expert-parallel
dispatch paths with masked global-sync duals. Parity vs the unsharded
engine on a forced 4x2 host mesh follows the PR-5 degeneracy-aware
contract (tests/test_train_sharded.py):

  - topk routing is score-deterministic -> tokens AND per-expert load
    histograms must be bit-equal;
  - bip routing sits its dual within ~1e-7 of marginal scores, and the
    sharded trunk's fp32 reassociation flips LP-degenerate tokens -> assert
    tokens equal, load totals equal, and a small L1 drift bound instead.

XLA pins the host device count per process, so the body runs through the
shared forced-device subprocess runner.
"""
from tests._forced_devices import PRELUDE, run_code

BODY = PRELUDE + r"""
from repro import configs
from repro.models import build_model
from repro.serving import ContinuousBatchingEngine
from repro.launch.mesh import make_host_mesh


def run_pair(strategy):
    cfg = configs.reduced_for_smoke("minimind_moe_16e", vocab_size=128)
    cfg = dataclasses.replace(cfg, routing=dataclasses.replace(
        cfg.routing, sync="global", strategy=strategy, capacity_factor=4.0))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, 128, (int(rng.integers(3, 20)),)).tolist()
        for _ in range(6)
    ]
    outs = []
    for mesh in [None, make_host_mesh(4, 2)]:
        eng = ContinuousBatchingEngine(
            model, params, n_slots=4, chunk_size=8, max_seq_len=64, mesh=mesh)
        reqs = []
        for p in prompts:
            r = eng.submit(p, 5, ignore_eos=True)
            while r is None:
                eng.step()
                r = eng.submit(p, 5, ignore_eos=True)
            reqs.append(r)
        while eng.scheduler.has_work:
            eng.step()
        outs.append(([r.output for r in reqs], eng.expert_load.copy()))
    return outs


# topk: same scores on both decompositions -> bit-equal everything
(tok_u, load_u), (tok_s, load_s) = run_pair("topk")
assert tok_u == tok_s, "topk: sharded tokens diverged"
assert np.array_equal(load_u, load_s), (
    "topk: sharded load histogram diverged", load_u, load_s)

# bip: degeneracy-aware — tokens equal, totals equal, small L1 drift
(tok_u, load_u), (tok_s, load_s) = run_pair("bip")
assert tok_u == tok_s, "bip: sharded tokens diverged"
assert load_u.sum() == load_s.sum(), (load_u.sum(), load_s.sum())
l1 = float(np.abs(load_u - load_s).sum())
assert l1 <= 8.0, ("bip: load drift beyond degeneracy bound", l1)
print("SERVING MESH PARITY OK", l1)
"""


def test_ep_sharded_serving_parity():
    out = run_code(BODY)
    assert "SERVING MESH PARITY OK" in out
