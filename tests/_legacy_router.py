"""Frozen pre-registry `route()` — the parity oracle for the balancer API.

This is a verbatim snapshot of `repro.core.router.route` (and its private
helpers) as it stood BEFORE the pluggable-balancer refactor: the four-way
strategy if/elif over topk / aux_loss / lossfree / bip, including the
masked serving path, the sync='global' threshold branch, the forecaster
EMA updates, and the dual-health watchdog. tests/test_balancers.py runs
this next to the registry-backed route() and asserts bitwise-identical
RouterOutput fields and state trajectories. Do not "fix" or modernize this
file — its value is being the old code.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import ref_bip
from repro.core.metrics import balance_metrics
from repro.core.types import RouterConfig, RouterOutput


def compute_scores(logits: jnp.ndarray, cfg: RouterConfig) -> jnp.ndarray:
    logits = logits.astype(cfg.router_dtype)
    if cfg.score_fn == "softmax":
        return jax.nn.softmax(logits, axis=-1)
    return jax.nn.sigmoid(logits)


def _topk_select(
    s: jnp.ndarray, corrected: jnp.ndarray, cfg: RouterConfig
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    _, idx = lax.top_k(corrected, cfg.top_k)
    w = jnp.take_along_axis(s, idx, axis=-1)
    if cfg.norm_topk_prob:
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return w, idx.astype(jnp.int32)


def _aux_loss(
    s: jnp.ndarray, idx: jnp.ndarray, cfg: RouterConfig, token_mask=None
) -> jnp.ndarray:
    n, m = s.shape
    onehot = jax.nn.one_hot(idx, m, dtype=s.dtype)  # (n, k, m)
    if token_mask is not None:
        w = token_mask.astype(s.dtype)
        n_eff = jnp.maximum(jnp.sum(w), 1.0)
        f = lax.stop_gradient((onehot * w[:, None, None]).sum(axis=(0, 1))) * (
            m / (cfg.top_k * n_eff)
        )
        p_mean = jnp.sum(s * w[:, None], axis=0) / n_eff
    else:
        f = lax.stop_gradient(onehot.sum(axis=(0, 1))) * (m / (cfg.top_k * n))
        p_mean = s.mean(axis=0)
    return cfg.aux_loss_alpha * jnp.sum(f * p_mean)


def _bip_q(s: jnp.ndarray, q0: jnp.ndarray, cfg: RouterConfig) -> jnp.ndarray:
    if cfg.use_kernel:
        from repro.kernels import ops as kernel_ops

        return kernel_ops.bip_dual_update(
            s, q0, top_k=cfg.top_k, n_iters=cfg.bip_iters
        )
    q, _ = ref_bip.bip_dual_update(s, q0, top_k=cfg.top_k, n_iters=cfg.bip_iters)
    return q


def legacy_route(
    logits: jnp.ndarray,
    state: Dict[str, jnp.ndarray],
    cfg: RouterConfig,
    *,
    local_shards: int = 1,
    token_mask=None,
) -> RouterOutput:
    """The pre-refactor route() body, verbatim (warn-once calls dropped)."""
    n, m = logits.shape
    assert m == cfg.n_experts, (m, cfg.n_experts)
    s = compute_scores(logits, cfg)
    q0 = state["q"]
    aux = jnp.zeros((), dtype=cfg.router_dtype)
    new_q = q0
    new_state = dict(state)

    if cfg.guard_duals:
        fkeys = [k for k in ("q_ema", "q_err") if k in state]
        stacked = jnp.concatenate([q0] + [state[k] for k in fkeys]) if fkeys else q0
        _, dual_healthy = ref_bip.sanitize_duals(stacked, cfg.dual_abs_limit)
        q0 = jnp.where(dual_healthy, q0, jnp.zeros_like(q0))
        for k in fkeys:
            new_state[k] = jnp.where(
                dual_healthy, state[k], jnp.zeros_like(state[k])
            )
        state = new_state
        new_q = q0

    global_axes = tuple(cfg.data_axes) if cfg.sync == "global" else ()

    if cfg.strategy == "bip":
        if cfg.sync == "global" and cfg.use_kernel and token_mask is None:
            from repro.kernels import ops as kernel_ops

            q = kernel_ops.bip_dual_update(
                lax.stop_gradient(s), q0,
                top_k=cfg.top_k, n_iters=cfg.bip_iters,
                axis_names=global_axes,
            )
            corrected = s - q[None, :]
            new_q = q
        elif cfg.sync == "global" or token_mask is not None:
            use_forecast = cfg.forecast and not cfg.use_kernel and "q_ema" in state
            window = None
            if use_forecast:
                half = cfg.forecast_margin * state["q_err"] + cfg.forecast_floor
                window = (state["q_ema"] - half, state["q_ema"] + half)
            q, _, t = ref_bip.bip_dual_update_global(
                lax.stop_gradient(s), q0,
                top_k=cfg.top_k, n_iters=cfg.bip_iters,
                token_mask=token_mask, axis_names=global_axes,
                n_bisect=cfg.n_bisect, fanout=cfg.bisect_fanout,
                score_bounds=(0.0, 1.0), window=window, with_stats=True,
            )
            if use_forecast:
                d = cfg.forecast_decay
                err = jnp.abs(t - state["q_ema"])
                new_state["q_ema"] = d * state["q_ema"] + (1.0 - d) * t
                new_state["q_err"] = d * state["q_err"] + (1.0 - d) * err
            corrected = s - q[None, :]
            new_q = q
        elif local_shards > 1 and cfg.sync == "local":
            s_grp = lax.stop_gradient(s).reshape(local_shards, n // local_shards, m)
            q_grp = jax.vmap(lambda sg: _bip_q(sg, q0, cfg))(s_grp)  # (S, m)
            corrected = (
                s.reshape(local_shards, -1, m) - q_grp[:, None, :]
            ).reshape(n, m)
            new_q = q_grp.mean(axis=0)
        else:
            q = _bip_q(lax.stop_gradient(s), q0, cfg)
            corrected = s - q[None, :]
            new_q = q
        w, idx = _topk_select(s, corrected, cfg)
        if not cfg.bip_warm_start:
            new_q = jnp.zeros_like(q0)

    elif cfg.strategy == "lossfree":
        corrected = s + q0[None, :]
        w, idx = _topk_select(s, corrected, cfg)
        onehot = jax.nn.one_hot(idx, m, dtype=cfg.router_dtype)
        if token_mask is not None:
            onehot = onehot * token_mask.astype(cfg.router_dtype)[:, None, None]
        load = lax.stop_gradient(onehot.sum(axis=(0, 1)))
        if global_axes:
            load = lax.psum(load, global_axes)
        err = load.mean() - load
        new_q = q0 + cfg.lossfree_lr * jnp.sign(err)

    elif cfg.strategy == "aux_loss":
        w, idx = _topk_select(s, s, cfg)
        aux = _aux_loss(s, idx, cfg, token_mask)

    else:  # 'topk'
        w, idx = _topk_select(s, s, cfg)

    metrics = balance_metrics(idx, m, cfg.top_k)
    new_state["q"] = new_q
    return RouterOutput(
        combine_weights=w,
        expert_index=idx,
        state={k: lax.stop_gradient(v) for k, v in new_state.items()},
        aux_loss=aux,
        metrics=metrics,
    )
