"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import balance_metrics, bip_topk
from repro.core.ref_bip import bip_dual_update as exact_dual
from repro.kernels import bip_admm, moe_gemm, ops, ref


def _scores(seed, n, m, skew=1.0):
    rng = np.random.default_rng(seed)
    logits = rng.standard_normal((n, m)) + skew * np.linspace(2, -2, m)[None, :]
    e = np.exp(logits - logits.max(-1, keepdims=True))
    return jnp.asarray((e / e.sum(-1, keepdims=True)).astype(np.float32))


# ------------------------------------------------------------- BIP kernel


@pytest.mark.parametrize("n,m,k", [(256, 8, 2), (512, 16, 4), (300, 4, 1), (1024, 64, 8)])
def test_bip_iteration_p_matches_exact(n, m, k):
    """The kernel's row-price p must match the exact (k+1)-th largest."""
    s = _scores(0, n, m)
    q = jnp.asarray(np.random.default_rng(1).uniform(0, 0.3, (m,)), jnp.float32)
    p_kern, cnt = bip_admm.bip_admm_iteration(s, q, top_k=k, block_n=128)
    p_ref = ref.bip_iteration_ref(s, q, top_k=k)
    np.testing.assert_allclose(np.asarray(p_kern), np.asarray(p_ref), atol=1e-6)
    # histogram counts match the oracle
    cnt_ref = ref.histogram_counts_ref(s, p_ref, n_bins=512)
    np.testing.assert_allclose(np.asarray(cnt), np.asarray(cnt_ref), atol=0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bip_iteration_dtype_sweep(dtype):
    s = _scores(2, 384, 16).astype(dtype)
    q = jnp.zeros((16,), jnp.float32)
    p_kern, cnt = bip_admm.bip_admm_iteration(s, q, top_k=4, block_n=128)
    p_ref = ref.bip_iteration_ref(s.astype(jnp.float32), q, top_k=4)
    np.testing.assert_allclose(np.asarray(p_kern), np.asarray(p_ref), atol=5e-3)


@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.sampled_from([128, 257, 512, 1000]),
    m=st.sampled_from([4, 8, 16, 64]),
    k=st.sampled_from([1, 2, 4]),
    t=st.sampled_from([2, 4]),
)
@settings(max_examples=12, deadline=None)
def test_bip_dual_update_kernel_close_to_exact(seed, n, m, k, t):
    """Full T-iteration kernel q vs exact oracle: within histogram resolution,
    and — the property that actually matters — the resulting ROUTING is as
    balanced as the exact router's."""
    k = min(k, m)
    s = _scores(seed, n, m, skew=1.5)
    q0 = jnp.zeros((m,), jnp.float32)
    q_kern = ops.bip_dual_update(s, q0, top_k=k, n_iters=t, block_n=256)
    q_ref, _ = exact_dual(s, q0, top_k=k, n_iters=t)
    np.testing.assert_allclose(
        np.asarray(q_kern), np.asarray(q_ref), atol=2.0 / 512 + 5e-3
    )
    _, idx_k = bip_topk(s, q_kern, k)
    _, idx_r = bip_topk(s, q_ref, k)
    vio_k = float(balance_metrics(idx_k, m, k)["max_vio"])
    vio_r = float(balance_metrics(idx_r, m, k)["max_vio"])
    # cold starts at tiny T can leave both unbalanced; the kernel must simply
    # track the oracle's balance, not beat it.
    assert vio_k <= 1.3 * vio_r + 0.3, (vio_k, vio_r)


def test_bip_kernel_in_router_end_to_end():
    """RouterConfig(use_kernel=True) routes as balanced as the oracle path."""
    from repro.core import RouterConfig, init_router_state, route

    s_logits = jnp.asarray(
        np.random.default_rng(3).standard_normal((512, 16)).astype(np.float32)
        + 1.5 * np.linspace(2, -2, 16)[None, :]
    )
    cfg_k = RouterConfig(n_experts=16, top_k=4, strategy="bip", bip_iters=8, use_kernel=True)
    cfg_r = RouterConfig(n_experts=16, top_k=4, strategy="bip", bip_iters=8)
    out_k = route(s_logits, init_router_state(cfg_k), cfg_k)
    out_r = route(s_logits, init_router_state(cfg_r), cfg_r)
    assert float(out_k.metrics["max_vio"]) < 0.3
    assert abs(float(out_k.metrics["max_vio"]) - float(out_r.metrics["max_vio"])) < 0.2


def test_route_global_kernel_single_device_matches_kernel_dual():
    """route(use_kernel=True, sync='global') off-mesh carries the kernel's
    duals (the collective branch with axis_names=()), not the threshold
    solver's."""
    from repro.core import RouterConfig, init_router_state, route

    logits = jnp.asarray(
        np.random.default_rng(5).standard_normal((512, 16)).astype(np.float32)
        + 1.5 * np.linspace(2, -2, 16)[None, :]
    )
    cfg = RouterConfig(
        n_experts=16, top_k=4, strategy="bip", bip_iters=4,
        sync="global", use_kernel=True,
    )
    out = route(logits, init_router_state(cfg), cfg)
    s = jax.nn.softmax(logits, axis=-1)
    q_direct = ops.bip_dual_update(
        jax.lax.stop_gradient(s), jnp.zeros((16,)), top_k=4, n_iters=4
    )
    np.testing.assert_allclose(
        np.asarray(out.state["q"]), np.asarray(q_direct), atol=1e-7
    )


def test_bip_kernel_collective_matches_reference_on_mesh():
    """Collective kernel (psum'd histogram counts) on a forced 4x2 mesh:
    q must be BITWISE equal to the single-device kernel on the gathered
    batch (the global histogram is identical — small exact integers), and
    within histogram resolution of the reference global dual."""
    from _forced_devices import PRELUDE, run_code as _run

    _run(PRELUDE + r"""
from repro.core.ref_bip import bip_dual_update_global
from repro.kernels import ops
from repro.models.moe import _shard_map

mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))

for n, m, k, t in ((512, 16, 4, 4), (1024, 64, 8, 2)):
    rng = np.random.default_rng(0)
    logits = rng.standard_normal((n, m)) + 1.5 * np.linspace(2, -2, m)[None, :]
    e = np.exp(logits - logits.max(-1, keepdims=True))
    s = jnp.asarray((e / e.sum(-1, keepdims=True)).astype(np.float32))
    q0 = jnp.zeros((m,), jnp.float32)

    def collective(s_loc, q, k=k, t=t):
        return ops.bip_dual_update(s_loc, q, top_k=k, n_iters=t,
                                   axis_names=("data",))

    fn = _shard_map(collective, mesh=mesh,
                    in_specs=(P("data", None), P(None)), out_specs=P(None))
    with mesh:
        q_mesh = np.asarray(jax.device_get(jax.jit(fn)(s, q0)))

    q_single = np.asarray(ops.bip_dual_update(s, q0, top_k=k, n_iters=t))
    np.testing.assert_array_equal(q_mesh, q_single,
                                  err_msg=f"m={m}: mesh vs single kernel")

    q_ref, _ = bip_dual_update_global(s, q0, top_k=k, n_iters=t, n_bisect=40)
    np.testing.assert_allclose(q_mesh, np.asarray(q_ref), atol=2.0 / 512 + 5e-3,
                               err_msg=f"m={m}: mesh kernel vs reference")
print("OK")
""")


def test_bip_kernel_capacity_slack():
    """k >= m: the token constraint selects everything and the capacity
    index runs past the column length -> q stays zero (true slack)."""
    s = _scores(4, 8, 4)
    q = ops.bip_dual_update(s, jnp.zeros((4,)), top_k=4, n_iters=4)
    np.testing.assert_array_equal(np.asarray(q), 0.0)


def test_bip_kernel_fractional_capacity_matches_exact():
    """n*k < m (fractional capacity < 1): kernel must track the exact dual,
    which puts q at the column max (rank 0) — not zero."""
    s = _scores(4, 8, 16)
    q_k = ops.bip_dual_update(s, jnp.zeros((16,)), top_k=1, n_iters=4)
    q_r, _ = exact_dual(s, jnp.zeros((16,)), top_k=1, n_iters=4)
    np.testing.assert_allclose(np.asarray(q_k), np.asarray(q_r), atol=1e-4)


# ----------------------------------------------------------- MoE GEMMs


@pytest.mark.parametrize(
    "e,c,d,f", [(4, 128, 64, 128), (2, 256, 128, 256), (8, 128, 32, 64)]
)
def test_grouped_gated_ffn_in_allclose(e, c, d, f):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((e, c, d)).astype(np.float32)) * 0.3
    wg = jnp.asarray(rng.standard_normal((e, d, f)).astype(np.float32)) * 0.1
    wu = jnp.asarray(rng.standard_normal((e, d, f)).astype(np.float32)) * 0.1
    got = moe_gemm.grouped_gated_ffn_in(x, wg, wu, block_c=64, block_f=64, block_d=32)
    want = ref.gated_ffn_in_ref(x, wg, wu)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize(
    "e,c,f,d", [(4, 128, 64, 128), (2, 64, 128, 64)]
)
def test_grouped_matmul_allclose(e, c, f, d):
    rng = np.random.default_rng(1)
    h = jnp.asarray(rng.standard_normal((e, c, f)).astype(np.float32)) * 0.3
    w = jnp.asarray(rng.standard_normal((e, f, d)).astype(np.float32)) * 0.1
    got = moe_gemm.grouped_matmul(h, w, block_c=64, block_d=64, block_f=32)
    want = ref.grouped_matmul_ref(h, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize(
    "e,c,d,f",
    [(3, 40, 96, 200), (2, 128, 64, 128), (1, 1, 32, 48), (4, 130, 50, 260)],
)
def test_ops_expert_ffn_autopad_allclose(e, c, d, f):
    """ops.expert_ffn pads arbitrary (c, d, f) to MXU-aligned multiples,
    runs the kernel pair, and slices back — zero padding must be exact."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((e, c, d)).astype(np.float32)) * 0.3
    wg = jnp.asarray(rng.standard_normal((e, d, f)).astype(np.float32)) * 0.1
    wu = jnp.asarray(rng.standard_normal((e, d, f)).astype(np.float32)) * 0.1
    wd = jnp.asarray(rng.standard_normal((e, f, d)).astype(np.float32)) * 0.1
    got = ops.expert_ffn(x, wg, wu, wd)
    want = ref.expert_ffn_ref(x, wg, wu, wd)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


def test_ops_expert_ffn_custom_vjp_grads_match_einsum():
    """The custom_vjp backward (grouped dgrad/wgrad GEMMs) must match einsum
    autodiff to fp32 tolerance for every operand."""
    rng = np.random.default_rng(8)
    e, c, d, f = 3, 40, 96, 200
    args = (
        jnp.asarray(rng.standard_normal((e, c, d)).astype(np.float32)) * 0.3,
        jnp.asarray(rng.standard_normal((e, d, f)).astype(np.float32)) * 0.1,
        jnp.asarray(rng.standard_normal((e, d, f)).astype(np.float32)) * 0.1,
        jnp.asarray(rng.standard_normal((e, f, d)).astype(np.float32)) * 0.1,
    )
    g_k = jax.grad(lambda *a: jnp.sum(jnp.sin(ops.expert_ffn(*a))), argnums=(0, 1, 2, 3))(*args)
    g_r = jax.grad(lambda *a: jnp.sum(jnp.sin(ref.expert_ffn_ref(*a))), argnums=(0, 1, 2, 3))(*args)
    for a, b in zip(g_k, g_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 3e-5), (jnp.bfloat16, 3e-2)])
def test_expert_ffn_dtype_sweep(dtype, atol):
    rng = np.random.default_rng(2)
    e, c, d, f = 2, 128, 64, 128
    x = jnp.asarray(rng.standard_normal((e, c, d)), dtype) * 0.3
    wg = jnp.asarray(rng.standard_normal((e, d, f)), dtype) * 0.1
    wu = jnp.asarray(rng.standard_normal((e, d, f)), dtype) * 0.1
    wd = jnp.asarray(rng.standard_normal((e, f, d)), dtype) * 0.1
    got = moe_gemm.expert_ffn(x, wg, wu, wd, block_c=64, block_f=64, block_d=32)
    want = ref.expert_ffn_ref(x, wg, wu, wd)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=atol
    )
