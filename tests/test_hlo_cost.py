"""Loop-aware HLO cost model: validated against XLA on loop-free programs
and against hand-computed trip-count math on scanned programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import (
    analyze,
    analyze_compiled,
    parse_hlo,
    xla_cost_analysis,
)


def _compile(fn, *specs, **jit_kw):
    return jax.jit(fn, **jit_kw).lower(*specs).compile()


def test_matches_xla_on_loopfree_matmul():
    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    c = _compile(lambda x: x @ x, a)
    got = analyze_compiled(c)
    want = xla_cost_analysis(c)["flops"]
    assert abs(got.flops - want) / want < 1e-6


def test_scan_trip_count_multiplies():
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def scanned(x):
        def body(c, _):
            return c @ x, None

        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    c = _compile(scanned, a)
    got = analyze_compiled(c)
    per_mm = 2 * 256 * 256 * 256
    np.testing.assert_allclose(got.flops, 7 * per_mm, rtol=1e-6)


def test_nested_scan_trip_counts():
    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def nested(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ x, None

            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None

        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    c = _compile(nested, a)
    got = analyze_compiled(c)
    per_mm = 2 * 128 * 128 * 128
    np.testing.assert_allclose(got.flops, 15 * per_mm, rtol=1e-6)


def test_collectives_counted_with_trip_counts():
    import os
    import subprocess
    import sys

    # needs >1 device: run in a subprocess with forced host devices
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np, sys
sys.path.insert(0, "src")
from repro.launch.hlo_cost import analyze_compiled
from jax.sharding import NamedSharding, PartitionSpec as P
mesh = jax.make_mesh((4,), ("m",))
s = NamedSharding(mesh, P("m", None))
a = jax.ShapeDtypeStruct((64, 64), jnp.float32, sharding=s)

def f(x):
    def body(c, _):
        return c + jax.lax.with_sharding_constraint(
            jnp.broadcast_to(jnp.sum(x), x.shape), s), None
    y, _ = jax.lax.scan(body, x, None, length=6)
    return y

c = jax.jit(f, in_shardings=s, out_shardings=s).lower(a).compile()
cost = analyze_compiled(c)
assert cost.collective_total > 0, cost.collectives
# the sum's all-reduce sits inside the 6-trip loop OR is hoisted; either
# way the analysis must produce a finite positive count
print("OK", cost.collective_total)
"""
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_traffic_includes_dot_operands():
    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    c = _compile(lambda x: x @ x, a)
    got = analyze_compiled(c)
    # >= result + 2 reads of the operand (one buffer, read twice): 3 MB
    assert got.traffic >= 3 * 512 * 512 * 4


def test_parse_hlo_structure():
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = _compile(lambda x: jnp.tanh(x @ x), a)
    comps = parse_hlo(c.as_text())
    assert "__entry__" in comps
    all_ops = [op.opcode for comp in comps.values() for op in comp.ops]
    assert "dot" in all_ops or any("fusion" in o for o in all_ops)
