"""Expert-Choice routing: perfect balance by construction, coverage cost."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.expert_choice import expert_choice_route


@given(
    n=st.sampled_from([64, 128, 256]),
    m=st.sampled_from([4, 8, 16]),
    k=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_expert_choice_invariants(n, m, k, seed):
    k = min(k, m)
    rng = np.random.default_rng(seed)
    logits = rng.standard_normal((n, m))
    e = np.exp(logits - logits.max(-1, keepdims=True))
    s = jnp.asarray((e / e.sum(-1, keepdims=True)).astype(np.float32))
    gates, mets = expert_choice_route(s, k)
    load = np.asarray(mets["load"])
    c = max(n * k // m, 1)
    # perfect balance: every expert serves exactly C tokens
    np.testing.assert_array_equal(load, c)
    assert float(mets["max_vio"]) == 0.0
    # gate values are the raw scores on selected pairs
    g = np.asarray(gates)
    sel = g > 0
    np.testing.assert_allclose(g[sel], np.asarray(s)[sel], rtol=1e-6)
    # selected tokens per expert are that expert's top-C by score
    for j in range(min(m, 4)):
        chosen = set(np.nonzero(sel[:, j])[0].tolist())
        top = set(np.argsort(-np.asarray(s)[:, j])[:c].tolist())
        assert chosen == top


def test_expert_choice_coverage_drops_under_skew():
    """Skew strands tokens: popular tokens hog every expert's top-C."""
    rng = np.random.default_rng(0)
    n, m, k = 256, 8, 2
    hot = rng.standard_normal((n, 1)) * 2.0  # per-TOKEN popularity
    logits = rng.standard_normal((n, m)) + hot
    e = np.exp(logits - logits.max(-1, keepdims=True))
    s = jnp.asarray((e / e.sum(-1, keepdims=True)).astype(np.float32))
    # per-token softmax normalizes rows, so skew must come through columns:
    # use raw scores instead for column selection pressure
    s = jnp.asarray((np.exp(logits) / np.exp(logits).sum(0, keepdims=True)).astype(np.float32))
    _, mets = expert_choice_route(s, k)
    assert float(mets["coverage_full"]) < 1.0
