"""Shared test configuration.

Where `hypothesis` is installed, the property tests run as written. Where it
is absent (minimal CI images ship only jax+numpy+pytest), a deterministic
stub is installed into sys.modules *before* the test modules import it: each
@given test runs exactly once with a fixed midpoint sample from every
strategy. Property coverage degrades to a smoke check, but collection never
aborts and the non-property tests keep their full coverage.
"""
from __future__ import annotations

import sys
import types

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    def _integers(min_value=0, max_value=10):
        return _Strategy(int((min_value + max_value) // 2))

    def _floats(min_value=0.0, max_value=1.0, **_):
        return _Strategy((min_value + max_value) / 2.0)

    def _sampled_from(elements):
        return _Strategy(list(elements)[0])

    def _given(**strategies):
        def deco(fn):
            def wrapper():
                return fn(**{k: v.sample for k, v in strategies.items()})

            # no functools.wraps: pytest would unwrap to the original
            # signature and demand fixtures for every strategy argument
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

    def _settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.sampled_from = _sampled_from
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
