"""Tests for Algorithm 3 (online, exact) and Algorithm 4 (histogram approx)."""
import numpy as np
import pytest

from repro.core import ApproxBIPGate, OnlineBIPGate


def _stream(rng, n, m, skew):
    logits = rng.standard_normal((n, m)) + skew * np.linspace(2.0, -2.0, m)[None, :]
    e = np.exp(logits - logits.max(-1, keepdims=True))
    return e / e.sum(-1, keepdims=True)


def _raw_vio(s, k, m):
    n = s.shape[0]
    raw = np.argsort(-s, axis=-1)[:, :k]
    load = np.bincount(raw.reshape(-1), minlength=m)
    return load.max() / (n * k / m) - 1.0


@pytest.mark.parametrize("gate_cls", [OnlineBIPGate, ApproxBIPGate])
def test_adaptive_gate_balances_skewed_stream(gate_cls):
    rng = np.random.default_rng(0)
    n, m, k = 2048, 8, 2
    s = _stream(rng, n, m, skew=1.5)
    gate = gate_cls(n_tokens=n, n_experts=m, top_k=k, n_iters=2)
    picks = np.zeros((n, k), dtype=np.int64)
    for i in range(n):
        idx, gates = gate.route(s[i])
        picks[i] = idx
        assert len(set(idx.tolist())) == k
        np.testing.assert_allclose(gates, s[i][idx])
    stats = gate.load_stats(picks)
    raw = _raw_vio(s, k, m)
    assert raw > 0.8  # the stream is genuinely skewed
    assert stats["max_vio"] < 0.35, stats
    assert stats["max_vio"] < raw / 3


def test_adaptive_gate_prefix_balance():
    """Adaptive capacity binds from the start: prefixes are balanced too."""
    rng = np.random.default_rng(1)
    n, m, k = 2048, 8, 2
    s = _stream(rng, n, m, skew=1.5)
    gate = OnlineBIPGate(n_tokens=n, n_experts=m, top_k=k, n_iters=2)
    picks = []
    for i in range(n):
        idx, _ = gate.route(s[i])
        picks.append(idx)
        if i + 1 in (256, 512, 1024):
            load = np.bincount(np.concatenate(picks), minlength=m)
            vio = load.max() / ((i + 1) * k / m) - 1.0
            assert vio < 0.5, (i + 1, vio)


class _BruteForceGate:
    """Faithful Algorithm 3 with explicit multiset storage (O(n) memory)."""

    def __init__(self, n, m, k, n_iters):
        self.n, self.m, self.k, self.t_iters = n, m, k, n_iters
        self.cap = max(n * k // m, 1)
        self.q = np.zeros(m)
        self.Q = []  # list of (m,) shifted-score rows

    def route(self, s):
        idx = np.argsort(-(s - self.q), kind="stable")[: self.k]
        p = 0.0
        for _ in range(self.t_iters):
            part = np.sort(s - self.q)[::-1]
            p = max(0.0, float(part[self.k])) if self.k < self.m else 0.0
            shifted = s - p
            union = np.array(self.Q + [shifted])  # (t+1, m)
            for j in range(self.m):
                col = np.sort(union[:, j])[::-1]
                self.q[j] = max(0.0, col[self.cap]) if len(col) > self.cap else 0.0
        self.Q.append(s - p)
        return idx


def test_faithful_mode_heap_matches_bruteforce():
    """Heap-based (cap+1)-th largest must equal brute-force over the explicit
    multiset, token for token — validating the top-(cap+1) retention trick."""
    rng = np.random.default_rng(2)
    n, m, k = 96, 4, 1
    s = _stream(rng, n, m, skew=1.0)
    gate = OnlineBIPGate(n, m, k, n_iters=2, adaptive_capacity=False)
    brute = _BruteForceGate(n, m, k, n_iters=2)
    for i in range(n):
        idx_fast = gate.route(s[i])[0]
        idx_slow = brute.route(s[i])
        np.testing.assert_allclose(gate.q, brute.q, atol=1e-12, err_msg=f"token {i}")
        np.testing.assert_array_equal(idx_fast, idx_slow)


def test_faithful_mode_respects_total_budget():
    """With the horizon capacity, the SECOND half of the stream must be far
    more balanced than raw routing (the price has bound by then), and total
    load must head toward the cap."""
    rng = np.random.default_rng(3)
    n, m, k = 4096, 8, 2
    s = _stream(rng, n, m, skew=1.5)
    gate = OnlineBIPGate(n, m, k, n_iters=2, adaptive_capacity=False)
    picks = np.zeros((n, k), dtype=np.int64)
    for i in range(n):
        picks[i] = gate.route(s[i])[0]
    second = picks[n // 2 :]
    load2 = np.bincount(second.reshape(-1), minlength=m)
    vio2 = load2.max() / (len(second) * k / m) - 1.0
    raw2 = _raw_vio(s[n // 2 :], k, m)
    assert vio2 < raw2 / 2, (vio2, raw2)


def test_approx_matches_exact_reasonably():
    rng = np.random.default_rng(4)
    n, m, k = 1024, 8, 2
    s = _stream(rng, n, m, skew=1.0)
    exact = OnlineBIPGate(n, m, k, n_iters=2)
    approx = ApproxBIPGate(n, m, k, n_bins=128, n_iters=2)
    pe, pa = [], []
    for i in range(n):
        pe.append(exact.route(s[i])[0])
        pa.append(approx.route(s[i])[0])
    ve = exact.load_stats(np.stack(pe))["max_vio"]
    va = approx.load_stats(np.stack(pa))["max_vio"]
    assert va < max(2.5 * ve, 0.5), (ve, va)
    # dual prices should agree to within histogram resolution
    np.testing.assert_allclose(exact.q, approx.q, atol=2.0 / 128 + 0.02)
