"""Telemetry subsystem tests (DESIGN.md §Observability).

The load-bearing invariant is TRANSPARENCY: enabling the full telemetry
pipeline (in-graph MetricStream buffer threaded through the jit'd step,
async drain, sinks) must leave the TrainState trajectory bitwise identical
— including the hardest configuration (guarded step + BIP forecaster +
global-sync duals). Everything telemetry records is a value the step
already computed; the buffer is write-only and feeds nothing back.
"""
from __future__ import annotations

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.synthetic import SyntheticBatchStream
from repro.models import build_model
from repro.robustness.guards import GuardConfig
from repro.telemetry import (
    CSVSink,
    JSONLSink,
    MemorySink,
    MetricStream,
    ServingTelemetry,
    StreamingHistogram,
    TrainTelemetry,
    open_sink,
    profile_window,
)
from repro.training.loop import train_loop

N_STEPS = 8


@pytest.fixture(scope="module")
def moe():
    cfg = configs.reduced_for_smoke("minimind_moe_16e", vocab_size=256)
    return cfg, build_model(cfg)


@pytest.fixture(scope="module")
def hard_moe():
    # the transparency worst case: guarded step + forecaster + global-sync
    base = configs.reduced_for_smoke("minimind_moe_16e", vocab_size=256)
    cfg = dataclasses.replace(
        base,
        routing=dataclasses.replace(base.routing, sync="global", forecast=True),
    )
    return cfg, build_model(cfg)


def _leaves(tree):
    return [np.asarray(x) for x in jax.tree.leaves(tree)]


def _bitwise_equal(a, b) -> bool:
    la, lb = _leaves(a), _leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(x, y, equal_nan=True) for x, y in zip(la, lb)
    )


def _train(fixture, **kw):
    cfg, model = fixture
    kw.setdefault("batches", SyntheticBatchStream(cfg, 4, 32, N_STEPS))
    kw.setdefault("total_steps", N_STEPS)
    return train_loop(model, kw.pop("batches"), lr=1e-3, log_every=0, **kw)


# ------------------------------------------------------------ transparency


def test_telemetry_transparent_bitwise(hard_moe):
    """Guarded + forecast + global-sync run: MetricStream on vs off gives
    bitwise-identical TrainState trajectories."""
    guard = GuardConfig(policy="skip")
    s_plain, _ = _train(hard_moe, guard=guard)
    sink = MemorySink()
    tel = TrainTelemetry(sink=sink, flush_every=3)  # non-divisor: partial window
    s_tel, _ = _train(hard_moe, guard=guard, telemetry=tel)
    assert _bitwise_equal(s_plain, s_tel)
    steps = sorted(r["step"] for r in sink.records if r["kind"] == "train_step")
    assert steps == list(range(N_STEPS))  # drain lost nothing, dupes none


def test_telemetry_records_well_formed(moe, tmp_path):
    cfg, _ = moe
    path = str(tmp_path / "train.jsonl")
    sink = JSONLSink(path)
    tel = TrainTelemetry(sink=sink, flush_every=4, run_meta={"arch": cfg.name})
    _train(moe, telemetry=tel)
    sink.close()
    records = [json.loads(line) for line in open(path)]  # every line parses
    assert records[0]["kind"] == "run_meta"
    steps = [r for r in records if r["kind"] == "train_step"]
    assert len(steps) == N_STEPS
    n_layers = sum(1 for _, ffn in cfg.layer_kinds() if ffn == "moe")
    tokens_routed = 4 * 32 * cfg.routing.top_k  # batch x seq x k, per layer
    for r in steps:
        assert {"step", "step_time", "ce_loss", "load_per_layer",
                "max_vio_per_layer"} <= set(r)
        load = np.asarray(r["load_per_layer"])
        assert load.shape == (n_layers, cfg.routing.n_experts)
        # integer counts end-to-end: every token lands on exactly k experts
        assert load.dtype.kind in "iu" or np.all(load == load.astype(np.int64))
        assert load.sum() == n_layers * tokens_routed


# ------------------------------------------------------------- dtype audit


def test_expert_load_integer_counts():
    from repro.core.metrics import expert_load

    idx = jnp.asarray([[0, 1], [1, 2], [3, 3]], jnp.int32)
    load = expert_load(idx, 4)
    assert jnp.issubdtype(load.dtype, jnp.integer)
    assert load.tolist() == [1, 2, 1, 2]
    # the sentinel used by masked dispatch is dropped, not wrapped
    masked = jnp.asarray([[0, 4], [4, 4]], jnp.int32)
    assert expert_load(masked, 4).tolist() == [1, 0, 0, 0]


def test_metric_stream_rejects_float_load():
    shapes = {
        "load": jax.ShapeDtypeStruct((8,), jnp.float32),
        "loss": jax.ShapeDtypeStruct((), jnp.float32),
    }
    with pytest.raises(AssertionError, match="integer counts"):
        MetricStream.build(shapes, 4)
    ok = MetricStream.build(
        {"load": jax.ShapeDtypeStruct((8,), jnp.int32)}, 4
    )
    assert ok.layout["load"][1] == jnp.dtype(jnp.int32)


def test_metric_stream_ring_buffer_slots():
    stream = MetricStream({"x": ((), jnp.dtype(jnp.float32))}, 3)
    buf = stream.init_buffer()
    assert buf["_step"].tolist() == [-1, -1, -1]
    for i in range(4):  # wraps: slot 0 overwritten by step 3
        buf = stream.accumulate(
            buf, {"x": jnp.asarray(float(i))}, jnp.asarray(i, jnp.int32)
        )
    assert buf["_step"].tolist() == [3, 1, 2]
    assert buf["x"].tolist() == [3.0, 1.0, 2.0]


# ------------------------------------------------------------------- sinks


def test_sinks_roundtrip(tmp_path):
    rec = {"kind": "train_step", "step": 1, "v": np.float32(2.5),
           "arr": np.arange(3, dtype=np.int32)}
    jpath = str(tmp_path / "a.jsonl")
    with JSONLSink(jpath) as s:
        s.emit(rec)
    got = json.loads(open(jpath).read().strip())
    assert got["v"] == 2.5 and got["arr"] == [0, 1, 2]

    cpath = str(tmp_path / "b.csv")
    with CSVSink(cpath) as s:
        s.emit(rec)
        s.emit({"kind": "event", "step": 2, "what": "x"})
    files = sorted(p.name for p in tmp_path.glob("b.*.csv"))
    assert files == ["b.event.csv", "b.train_step.csv"]

    assert isinstance(open_sink(str(tmp_path / "c.csv")), CSVSink)
    assert isinstance(open_sink(str(tmp_path / "c.jsonl")), JSONLSink)
    assert open_sink(None) is None


# ------------------------------------------------------------------ tracing


def test_profile_window_parse():
    assert profile_window("3:10") == (3, 10)
    with pytest.raises(ValueError):
        profile_window("10:3")
    with pytest.raises(ValueError):
        profile_window("abc")


# ------------------------------------------------------------- serving SLO


def test_streaming_histogram_quantiles():
    h = StreamingHistogram()
    rng = np.random.default_rng(0)
    xs = rng.lognormal(mean=-2.0, sigma=1.0, size=20000)
    for x in xs:
        h.add(x)
    assert h.n == len(xs)
    for p in (0.5, 0.9, 0.99):
        true = np.quantile(xs, p)
        assert abs(h.quantile(p) - true) / true < 0.05
    assert abs(h.mean - xs.mean()) / xs.mean() < 1e-6
    h.add(float("nan"))
    h.add(-1.0)
    assert h.n == len(xs)  # non-finite / negative ignored
    d = h.to_dict()
    assert d["n"] == len(xs) and sum(d["bucket_count"]) == len(xs)


def test_serving_telemetry_slo_plane(moe):
    cfg, model = moe
    from repro.serving.engine import ContinuousBatchingEngine

    class Clock:
        t = 0.0

        def __call__(self):
            return self.t

    clk = Clock()
    sink = MemorySink()
    eng = ContinuousBatchingEngine(
        model, model.init(jax.random.PRNGKey(0)),
        n_slots=2, chunk_size=8, max_seq_len=64, clock=clk, sink=sink,
    )
    reqs = [eng.submit([1, 2, 3, 4, 5], 4, ignore_eos=True) for _ in range(3)]
    assert all(r is not None for r in reqs)
    while eng.scheduler.has_work:
        eng.step()
        clk.t += 0.5
    tel = eng.telemetry
    assert tel.n_finished == 3 and tel.ttft.n == 3 and tel.itl.n == 3
    # fake clock: prefill completes on the first step a slot runs, so the
    # admitted pair sees ttft 0.0 is impossible — submit precedes the step
    # by at least one 0.5s tick for the queued third request
    assert tel.ttft.quantile(0.99) >= 0.5 - 1e-9
    lifecycle = [r for r in sink.records if r["kind"] == "serve_request"]
    assert len(lifecycle) == 3
    assert all(r["finish_reason"] == "max_new_tokens" for r in lifecycle)
    summary = eng.telemetry.emit_summary()
    assert summary["n_finished"] == 3
    assert summary["decode_tokens"] == eng.decode_tokens
    assert sink.records[-1]["kind"] == "serve_summary"
    # engine counters are read-only views over telemetry
    assert eng.n_steps == tel.n_steps
    tel.reset()
    assert eng.n_steps == 0 and tel.ttft.n == 0


def test_serving_telemetry_counts_drops(moe):
    cfg, model = moe
    from repro.serving.engine import ContinuousBatchingEngine

    class Clock:
        t = 0.0

        def __call__(self):
            return self.t

    clk = Clock()
    eng = ContinuousBatchingEngine(
        model, model.init(jax.random.PRNGKey(0)),
        n_slots=1, chunk_size=8, max_seq_len=64,
        queue_timeout=1.0, clock=clk,
    )
    eng.submit([1, 2, 3], 30, ignore_eos=True)  # hogs the slot
    waiter = eng.submit([4, 5, 6], 4, ignore_eos=True)
    for _ in range(4):
        eng.step()
        clk.t += 1.0
    assert waiter.finish_reason == "timeout"
    # pre-existing counter contract: timeouts count as shed, not deadline
    assert eng.telemetry.n_shed == 1
    assert eng.telemetry.n_deadline_missed == 0
    # the timed-out waiter was never admitted: no queue-wait sample, no ttft
    assert eng.telemetry.queue_wait.n == 0
    assert eng.telemetry.ttft.n == 0
    assert eng.telemetry.n_finished == 1  # outcome still reported once


# --------------------------------------------------------------- TrainLog


def test_trainlog_step_time_quantiles(moe):
    _, log = _train(moe)
    s = log.summary()
    times = np.asarray(log.step_times[2:])
    assert s["step_time_p50"] == pytest.approx(np.percentile(times, 50))
    assert s["step_time_p99"] == pytest.approx(np.percentile(times, 99))
    assert s["mean_step_time"] == pytest.approx(times.mean())
    assert len(log.losses) == N_STEPS
    log.truncate(3)
    assert len(log.losses) == 3 and len(log.max_vio_steps) == 3


# ---------------------------------------------------------- metrics report


def test_metrics_report_summarize(moe, tmp_path):
    from repro.telemetry import metrics_report

    path = str(tmp_path / "run.jsonl")
    sink = JSONLSink(path)
    tel = TrainTelemetry(sink=sink, flush_every=4, run_meta={"arch": "x"})
    _train(moe, telemetry=tel)
    sink.close()
    records = metrics_report.load_records(path)
    summary = metrics_report.summarize(records)
    assert summary["n_steps"] == N_STEPS
    assert summary["final_loss"] is not None
    assert len(summary["AvgMaxVio_per_layer"]) >= 1
    assert np.all(np.asarray(summary["load_total_per_layer"]) > 0)
    html = str(tmp_path / "report.html")
    assert metrics_report.main([path, "--html", html]) == 0
    assert "load" in open(html).read()


def test_metrics_report_dedups_replayed_steps():
    from repro.telemetry.metrics_report import dedup_steps

    recs = [
        {"kind": "train_step", "step": 0, "ce_loss": 1.0},
        {"kind": "train_step", "step": 1, "ce_loss": 9.9},
        {"kind": "train_step", "step": 1, "ce_loss": 0.9},  # replay wins
    ]
    out = dedup_steps(recs)
    assert [r["step"] for r in out] == [0, 1]
    assert out[1]["ce_loss"] == 0.9


# ------------------------------------------------------------ bench harness


def test_bench_run_unknown_benchmark_lists_registry(capsys):
    from benchmarks import run as bench_run

    with pytest.raises(SystemExit) as exc:
        bench_run.main(["definitely_not_a_bench"])
    assert exc.value.code == 2
    err = capsys.readouterr().err
    assert "unknown benchmark" in err
    assert "telemetry_overhead" in err and "paper_repro" in err
