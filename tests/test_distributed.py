"""Distribution-layer tests on a forced multi-device host (subprocesses,
because XLA locks the device count per process; shared runner in
tests/_forced_devices.py)."""
from _forced_devices import PRELUDE, run_code


def _run(code: str, timeout: int = 600) -> str:
    return run_code(code, timeout=timeout)


def test_sharded_train_step_matches_single_device():
    """One train step on a 4x2 mesh must produce the same loss/params as the
    unsharded program (GSPMD is semantics-preserving; this catches wrong
    specs that silently change math, e.g. missing psum in the MoE combine)."""
    _run(PRELUDE + r"""
from repro import configs
from repro.models import build_model
from repro.distributed import make_mesh_ctx, train_state_specs, batch_specs, shard_tree
from repro.training.loop import init_train_state, make_train_step
from repro.optim.adamw import from_model_config
from repro.optim.schedules import constant
from repro.data import make_batches

cfg = configs.reduced_for_smoke("minimind_moe_16e", vocab_size=256)
batch = next(iter(make_batches(cfg, 8, 64, 1, seed=0)))
opt_cfg = from_model_config(cfg)

# single device reference
model0 = build_model(cfg)
state0 = init_train_state(model0, jax.random.PRNGKey(0), opt_cfg)
step0 = jax.jit(make_train_step(model0, opt_cfg, constant(1e-3)))
s0, m0 = step0(state0, batch)

# sharded: 4 data x 2 model
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
ctx = make_mesh_ctx(mesh)
model1 = build_model(cfg, ctx)
state1 = init_train_state(model1, jax.random.PRNGKey(0), opt_cfg)
specs = train_state_specs(state1, cfg, mesh)
state1 = shard_tree(state1, specs, mesh)
bs = batch_specs(cfg, mesh, 8)
batch1 = shard_tree(batch, {k: bs[k] for k in batch}, mesh)
with mesh:
    step1 = jax.jit(make_train_step(model1, opt_cfg, constant(1e-3)))
    s1, m1 = step1(state1, batch1)

l0, l1 = float(m0["loss"]), float(m1["loss"])
assert abs(l0 - l1) / abs(l0) < 2e-2, (l0, l1)
# params after one step agree
p0 = jax.tree.leaves(s0.params)
p1 = jax.tree.leaves(jax.device_get(s1.params))
for a, b in zip(p0, p1):
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                               atol=5e-2, rtol=5e-2)
print("OK", l0, l1)
""")


def test_param_specs_shard_everything_big():
    _run(PRELUDE + r"""
from repro import configs
from repro.models import build_model
from repro.distributed import param_specs
cfg = configs.get("llama4_scout_17b_a16e")
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
model = build_model(cfg)
params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
specs = param_specs(params, cfg, mesh)
flat_p, _ = jax.tree_util.tree_flatten_with_path(params)
flat_s = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
assert len(flat_p) == len(flat_s)
import numpy as _np
n_big_unsharded = 0
for (path, leaf), spec in zip(flat_p, flat_s):
    if _np.prod(leaf.shape) >= (1 << 22):  # >= 4M elements
        if all(ax is None for ax in spec):
            n_big_unsharded += 1
            print("UNSHARDED:", jax.tree_util.keystr(path), leaf.shape)
assert n_big_unsharded == 0
print("OK")
""")


def test_dryrun_one_pair_small():
    """End-to-end dryrun path (lower+compile+analyze) on a cheap pair."""
    out = _run(r"""
import sys
sys.path.insert(0, "src")
from repro.launch.dryrun import lower_one
rec = lower_one("mamba2_130m", "decode_32k")
assert rec["flops"] > 0 and rec["peak_bytes"] > 0
assert rec["peak_bytes"] / 2**30 < 16.0
print("OK", rec["compile_s"])
""", timeout=900)
    assert "OK" in out


def test_moe_ep_grad_matches_local():
    """Gradients through the shard_map EP block == local path gradients.

    strategy='topk' so routing is token-independent of sharding (the BIP
    dual is per-shard under sync='local' and would legitimately route a few
    marginal tokens differently), capacity_factor=4 so neither the global
    nor the per-shard capacity drops any token, and f32 compute so
    data-sharded partial sums don't round differently (bf16 partials differ
    by ~0.5%); this isolates the dispatch/combine math and the shard_map
    transposes. All three EP schedules are checked."""
    _run(PRELUDE + r"""
from repro.configs.base import ModelConfig, RoutingSpec
from repro.models import moe
from repro.core.types import init_router_state

cfg = ModelConfig(n_layers=2, d_model=64, d_ff=128, compute_dtype=jnp.float32,
                  routing=RoutingSpec(n_experts=8, top_k=2, strategy="topk",
                                      capacity_factor=4.0),
                  moe_d_ff=96)
params = moe.init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (256, 64))
state = init_router_state(moe.router_config(cfg))
mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))

def loss_local(p):
    y, *_ = moe.moe_ffn_local(p, x, state, cfg)
    return jnp.sum(y ** 2)

g0 = jax.grad(loss_local)(params)
for fn in [moe.moe_ffn_ep, moe.moe_ffn_ep2d, moe.moe_ffn_ep2ds]:
    def loss_ep(p, fn=fn):
        y, *_ = fn(p, xs, state, cfg, mesh,
                   data_axes=("data",), model_axis="model")
        return jnp.sum(y ** 2)
    with mesh:
        g1 = jax.jit(jax.grad(loss_ep))(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(jax.device_get(g1))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4, rtol=2e-4)
print("OK")
""")
