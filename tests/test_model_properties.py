"""Property tests on system invariants (hypothesis + targeted)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.models import build_model
from repro.models.moe import _dispatch_plan, expert_capacity


# ------------------------------------------------------------- causality


@pytest.mark.parametrize("arch", ["stablelm_1_6b", "minimind_moe_16e", "mamba2_130m", "gemma2_27b"])
def test_causality(arch):
    """Changing token t must not change logits at positions < t."""
    cfg = configs.reduced_for_smoke(arch, vocab_size=128)
    # freeze routing so the perturbation cannot re-route earlier tokens via
    # the batch-global dual (BIP routes per batch by design)
    if cfg.is_moe:
        cfg = dataclasses.replace(
            cfg, routing=dataclasses.replace(cfg.routing, strategy="topk")
        )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    states = model.init_router_states()
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 128, (1, 24))
    t = 12
    toks2 = toks.copy()
    toks2[0, t] = (toks2[0, t] + 7) % 128
    l1, *_ = model.forward(params, {"tokens": jnp.asarray(toks, jnp.int32)}, states)
    l2, *_ = model.forward(params, {"tokens": jnp.asarray(toks2, jnp.int32)}, states)
    np.testing.assert_allclose(
        np.asarray(l1[:, :t]), np.asarray(l2[:, :t]), atol=1e-4
    )
    # and the perturbed position itself must differ (model is not degenerate)
    assert np.abs(np.asarray(l1[:, t:]) - np.asarray(l2[:, t:])).max() > 1e-4


# -------------------------------------------------------- dispatch plan


@given(
    n=st.integers(4, 300),
    m=st.sampled_from([2, 4, 8, 16]),
    k=st.integers(1, 4),
    cap=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_dispatch_plan_invariants(n, m, k, cap, seed):
    """(a) positions are unique per (expert, slot); (b) kept slots never
    exceed capacity; (c) earlier tokens win capacity."""
    k = min(k, m)
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(
        np.stack([rng.choice(m, size=k, replace=False) for _ in range(n)]),
        jnp.int32,
    )
    pos, keep = _dispatch_plan(idx, m, cap)
    pos, keep, idx = np.asarray(pos), np.asarray(keep), np.asarray(idx)
    assert (pos[keep] < cap).all()
    # uniqueness of (expert, pos) among kept slots
    pairs = list(zip(idx[keep].tolist(), pos[keep].tolist()))
    assert len(pairs) == len(set(pairs))
    # per expert, kept count == min(total assigned, cap)
    for e in range(m):
        total = int((idx == e).sum())
        kept = int(((idx == e) & keep).sum())
        assert kept == min(total, cap)
    # monotone: positions within an expert increase with token order
    for e in range(m):
        rows, cols = np.nonzero(idx == e)
        p = pos[rows, cols]
        assert (np.diff(p) > 0).all()


def test_capacity_formula():
    cfg = configs.get("minimind_moe_16e")
    # ceil(4 * 1024 / 16 * 1.25) = 320
    assert expert_capacity(1024, cfg) == 320


# ------------------------------------------------- router state semantics


def test_router_state_warm_start_changes_routing():
    """The carried q must influence the next batch (warm start is real)."""
    cfg = configs.reduced_for_smoke("minimind_moe_16e", vocab_size=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    s0 = model.init_router_states()
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(rng.integers(0, 128, (2, 32)), jnp.int32)}
    _, s1, _, _ = model.forward(params, batch, s0)
    changed = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), s0, s1
    )
    assert max(jax.tree.leaves(changed)) > 0.0


def test_training_determinism():
    """Same seed + data => bit-identical loss trajectory."""
    from repro.data import make_batches
    from repro.training import train_loop

    cfg = configs.reduced_for_smoke("minimind_moe_16e", vocab_size=128)
    model = build_model(cfg)
    losses = []
    for _ in range(2):
        batches = make_batches(cfg, 4, 32, 5, seed=3)
        _, log = train_loop(model, batches, lr=1e-3, total_steps=5,
                            key=jax.random.PRNGKey(7))
        losses.append(log.losses)
    np.testing.assert_array_equal(losses[0], losses[1])


def test_resume_from_checkpoint_matches_continuous():
    """Training 10 steps == training 5, checkpointing, restoring, training 5."""
    import os
    import tempfile

    from repro.checkpoint import load_pytree, save_pytree
    from repro.data import make_batches
    from repro.optim.adamw import from_model_config
    from repro.training import train_loop
    from repro.training.loop import TrainState, init_train_state

    cfg = configs.reduced_for_smoke("stablelm_1_6b", vocab_size=128)
    model = build_model(cfg)
    batches = list(make_batches(cfg, 4, 32, 10, seed=5))

    state_a, log_a = train_loop(
        model, batches, lr=1e-3, total_steps=10, key=jax.random.PRNGKey(0)
    )

    state_b, _ = train_loop(
        model, batches[:5], lr=1e-3, total_steps=10, key=jax.random.PRNGKey(0)
    )
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "ck.npz")
        save_pytree(p, {"params": state_b.params, "opt": state_b.opt_state,
                        "router": state_b.router_states})
        back = load_pytree(p)
    resumed = TrainState(
        params=back["params"], opt_state=back["opt"], router_states=back["router"]
    )
    state_c, log_c = train_loop(
        model, batches[5:], lr=1e-3, total_steps=10, state=resumed
    )
    fa = jax.tree.leaves(state_a.params)
    fc = jax.tree.leaves(state_c.params)
    for a, c in zip(fa, fc):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=1e-5)


# --------------------------------------------------------- data pipeline


@given(seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_labels_are_shifted_tokens(seed):
    from repro.data import SyntheticLMDataset

    ds = SyntheticLMDataset(vocab_size=64, seq_len=32, seed=seed)
    b = next(iter(ds.batches(2, 1)))
    toks, labels = np.asarray(b["tokens"]), np.asarray(b["labels"])
    # labels must be the next-token shift of a common underlying stream
    np.testing.assert_array_equal(toks[:, 1:], labels[:, :-1])
