"""Production-harness tests: sharded train step parity, buffer donation,
microbatch gradient accumulation, mixed precision, checkpoint resume.

Multi-device cases run in subprocesses with forced host devices (XLA locks
the device count per process) — shared runner in tests/_forced_devices.py.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _forced_devices import PRELUDE, run_code as _run
from repro import configs
from repro.data import make_batches
from repro.models import build_model
from repro.optim.adamw import from_model_config
from repro.optim.schedules import constant
from repro.training import (
    compile_train_step,
    init_train_state,
    make_train_step,
    train_loop,
)


# ------------------------------------------------- single-process coverage


def _smoke_cfg(**overrides):
    return configs.reduced_for_smoke("minimind_moe_16e", vocab_size=256, **overrides)


def test_grad_accum_matches_big_batch():
    """k sequential microbatches == 1 big batch (same grads, same update).

    strategy='topk' so routing is per-token (no cross-microbatch dual state)
    and capacity_factor=8 so neither granularity drops tokens — any residual
    difference is f32 summation order."""
    cfg = _smoke_cfg()
    cfg = dataclasses.replace(
        cfg,
        routing=dataclasses.replace(
            cfg.routing, strategy="topk", capacity_factor=8.0
        ),
    )
    model = build_model(cfg)
    opt_cfg = from_model_config(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0), opt_cfg)
    batch = next(iter(make_batches(cfg, 8, 32, 1, seed=0)))

    step1 = jax.jit(make_train_step(model, opt_cfg, constant(1e-3)))
    stepk = jax.jit(make_train_step(model, opt_cfg, constant(1e-3), microbatches=4))
    s1, m1 = step1(state, batch)
    sk, mk = stepk(state, batch)

    assert abs(float(m1["loss"]) - float(mk["loss"])) < 1e-5, (m1["loss"], mk["loss"])
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(sk.params)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4
        )
    # microbatched metrics keep the per-layer MaxVio vector
    assert mk["max_vio_per_layer"].shape == m1["max_vio_per_layer"].shape


def test_checkpoint_resume_bit_exact(tmp_path):
    """save -> resume replays the remaining schedule bit-exactly, router
    duals q included (strategy='bip' so q is live state, not a constant)."""
    cfg = _smoke_cfg()
    model = build_model(cfg)
    steps = 6
    kw = dict(lr=1e-3, warmup_steps=2, total_steps=steps)

    # reference: straight 6-step run
    s_ref, log_ref = train_loop(model, make_batches(cfg, 4, 32, steps, seed=0), **kw)

    # part 1: first 3 steps, checkpointing at step 3
    d = str(tmp_path / "ck")
    train_loop(
        model,
        make_batches(cfg, 4, 32, 3, seed=0),
        ckpt_dir=d,
        ckpt_every=3,
        **kw,
    )
    # the checkpointed router state must be the live BIP dual, not init zeros
    from repro.checkpoint import CheckpointManager

    step, restored = CheckpointManager(d).restore_train_state()
    assert step == 3
    qs = [np.asarray(s["q"]) for s in restored.router_states if s is not None]
    assert qs and any(np.abs(q).sum() > 0 for q in qs), "router duals not saved"

    # part 2: resume and finish — losses and final params must match the
    # reference run exactly (the data stream is deterministic per index)
    s_res, log_res = train_loop(
        model,
        make_batches(cfg, 4, 32, steps, seed=0),
        ckpt_dir=d,
        resume=True,
        **kw,
    )
    assert log_res.losses == log_ref.losses[3:], (log_res.losses, log_ref.losses)
    for a, b in zip(jax.tree.leaves(s_ref.params), jax.tree.leaves(s_res.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(
        jax.tree.leaves(s_ref.router_states), jax.tree.leaves(s_res.router_states)
    ):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_resume_bit_exact_forecast(tmp_path):
    """The dual forecaster's EMAs ('q_ema'/'q_err') are live router state
    under cfg.routing.forecast: they must ride the generic router-state
    checkpointing and resume bit-exactly alongside q, so a restored run
    replays identical warm-start brackets."""
    cfg = _smoke_cfg()
    cfg = dataclasses.replace(
        cfg, routing=dataclasses.replace(cfg.routing, sync="global", forecast=True)
    )
    model = build_model(cfg)
    steps = 6
    kw = dict(lr=1e-3, warmup_steps=2, total_steps=steps)

    s_ref, log_ref = train_loop(model, make_batches(cfg, 4, 32, steps, seed=0), **kw)

    d = str(tmp_path / "ck")
    train_loop(
        model, make_batches(cfg, 4, 32, 3, seed=0), ckpt_dir=d, ckpt_every=3, **kw
    )
    from repro.checkpoint import CheckpointManager

    step, restored = CheckpointManager(d).restore_train_state()
    assert step == 3
    live = [s for s in restored.router_states if s is not None]
    assert live
    for st in live:
        assert "q_ema" in st and "q_err" in st, sorted(st)
    assert any(np.abs(np.asarray(s["q_ema"])).sum() > 0 for s in live), (
        "forecaster EMAs not saved"
    )

    s_res, log_res = train_loop(
        model, make_batches(cfg, 4, 32, steps, seed=0), ckpt_dir=d, resume=True, **kw
    )
    assert log_res.losses == log_ref.losses[3:], (log_res.losses, log_ref.losses)
    for a, b in zip(jax.tree.leaves(s_ref.params), jax.tree.leaves(s_res.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(
        jax.tree.leaves(s_ref.router_states), jax.tree.leaves(s_res.router_states)
    ):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_mixed_precision_policy():
    """bf16 compute, fp32 master params + Adam moments (DESIGN.md §Training)."""
    cfg = _smoke_cfg(compute_dtype=jnp.bfloat16)
    model = build_model(cfg)

    # forward computes in bf16 ...
    opt_cfg = from_model_config(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0), opt_cfg)
    batch = next(iter(make_batches(cfg, 4, 32, 1, seed=0)))
    x, _ = model._embed_inputs(state.params, batch)
    assert x.dtype == jnp.bfloat16  # activations in bf16 (logits upcast for CE)

    # ... while the train step keeps fp32 masters and fp32 moments
    step = jax.jit(make_train_step(model, opt_cfg, constant(1e-3)))
    new_state, mets = step(state, batch)
    assert np.isfinite(float(mets["loss"]))
    for p in jax.tree.leaves(new_state.params):
        assert p.dtype == jnp.float32, p.dtype
    for m in jax.tree.leaves((new_state.opt_state["mu"], new_state.opt_state["nu"])):
        assert m.dtype == jnp.float32, m.dtype


def test_donation_aliases_state_buffers():
    """The jitted step donates TrainState: the compiled program aliases
    inputs to outputs, and repeated stepping doesn't accumulate live buffers
    (the OOM-across-steps failure mode donation exists to prevent)."""
    cfg = _smoke_cfg()
    model = build_model(cfg)
    opt_cfg = from_model_config(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0), opt_cfg)
    batches = list(make_batches(cfg, 4, 32, 6, seed=0))

    step = make_train_step(model, opt_cfg, constant(1e-3))
    fn = jax.jit(step, donate_argnums=(0,))
    txt = fn.lower(state, batches[0]).compile().as_text()
    assert "input_output_alias" in txt

    state, mets = fn(state, batches[0])
    state, mets = fn(state, batches[1])
    jax.block_until_ready(state.params)
    n_live_warm = len(jax.live_arrays())
    for b in batches[2:]:
        state, mets = fn(state, b)
        jax.block_until_ready(mets["loss"])
    assert len(jax.live_arrays()) <= n_live_warm + 4, (
        n_live_warm,
        len(jax.live_arrays()),
    )


# ----------------------------------------------------- multi-device (8-way)


def test_sharded_train_loop_matches_single_device():
    """train_loop on a 4x2 host mesh (explicit in/out shardings + donation)
    reproduces the single-device losses/params, and the sharded compiled
    step both aliases its state buffers and holds live-buffer count flat
    across steps."""
    _run(PRELUDE + r"""
from repro import configs
from repro.data import make_batches
from repro.distributed import make_mesh_ctx
from repro.models import build_model
from repro.optim.adamw import from_model_config
from repro.optim.schedules import constant
from repro.training import compile_train_step, init_train_state, train_loop

cfg = configs.reduced_for_smoke("minimind_moe_16e", vocab_size=256)
steps = 3
kw = dict(lr=1e-3, warmup_steps=1, total_steps=steps)

model0 = build_model(cfg)
s0, log0 = train_loop(model0, make_batches(cfg, 8, 64, steps, seed=0), **kw)

mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
model1 = build_model(cfg, make_mesh_ctx(mesh))
s1, log1 = train_loop(model1, make_batches(cfg, 8, 64, steps, seed=0), mesh=mesh, **kw)

for a, b in zip(log0.losses, log1.losses):
    assert abs(a - b) / abs(a) < 2e-2, (log0.losses, log1.losses)
for a, b in zip(jax.tree.leaves(s0.params), jax.tree.leaves(jax.device_get(s1.params))):
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                               atol=5e-2, rtol=5e-2)

# donation under explicit shardings: aliased buffers, flat live-array count
opt_cfg = from_model_config(cfg)
state = init_train_state(model1, jax.random.PRNGKey(0), opt_cfg)
batches = list(make_batches(cfg, 8, 64, 6, seed=0))
fn = compile_train_step(model1, opt_cfg, constant(1e-3), state, batches[0], mesh=mesh)
with mesh:
    txt = fn.lower(state, batches[0]).compile().as_text()
    assert "input_output_alias" in txt
    state, mets = fn(state, batches[0])
    state, mets = fn(state, batches[1])
    jax.block_until_ready(state.params)
    n_live_warm = len(jax.live_arrays())
    for b in batches[2:]:
        state, mets = fn(state, b)
        jax.block_until_ready(mets["loss"])
    n_live_end = len(jax.live_arrays())
assert n_live_end <= n_live_warm + 8 * 4, (n_live_warm, n_live_end)
print("OK", log0.losses[-1], log1.losses[-1])
""")


def test_global_sync_dual_trajectory_matches_unsharded_route():
    """Cross-shard parity at the router level, where it is EXACT: a 4x2 mesh
    carrying warm-started sync='global' BIP duals through >= 10 steps of
    per-layer routing must reproduce single-device route() on the gathered
    batch — q bitwise-tight (the psum'd bisection sees the same f32-exact
    counts) and per-layer MaxVio identical, for BOTH paper expert tables
    (16e k=4 and 64e k=8). The per-shard 'local' duals on the same stream
    must NOT match (per-shard order statistics), proving the comparison
    discriminates. Both sides consume the same logits stream: this isolates
    the dual semantics from fp32 reassociation jitter of the trunk, which
    the end-to-end test below bounds separately."""
    _run(PRELUDE + r"""
from jax import lax
from repro.core import RouterConfig, init_router_state, route
from repro.models.moe import _shard_map

mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
STEPS, N, LAYERS = 10, 512, 2

for m, k, iters in ((16, 4, 4), (64, 8, 14)):
    cfg_g = RouterConfig(n_experts=m, top_k=k, strategy="bip", bip_iters=iters,
                         sync="global", data_axes=("data",))
    cfg_1 = RouterConfig(n_experts=m, top_k=k, strategy="bip", bip_iters=iters,
                         sync="global")  # same threshold solver, no collectives
    cfg_l = RouterConfig(n_experts=m, top_k=k, strategy="bip", bip_iters=iters,
                         sync="local")

    def sharded_step(logits, q, cfg=cfg_g):
        def block(lg_loc, q_in):
            out = route(lg_loc, {"q": q_in}, cfg)
            return out.state["q"], lax.psum(out.metrics["load"], "data")
        return _shard_map(
            block, mesh=mesh,
            in_specs=(P("data", None), P(None)),
            out_specs=(P(None), P(None)),
        )(logits, q)

    step_g = jax.jit(sharded_step)
    rng = np.random.default_rng(7)
    q_g = [jnp.zeros((m,)) for _ in range(LAYERS)]
    q_1 = [jnp.zeros((m,)) for _ in range(LAYERS)]
    q_l = [jnp.zeros((m,)) for _ in range(LAYERS)]
    local_diverged = False
    for t in range(STEPS):
        for layer in range(LAYERS):
            # drifting skew mimics router-weight training drift
            logits = jnp.asarray(
                (rng.standard_normal((N, m))
                 + (1.0 + 0.2 * t) * np.linspace(2, -2, m)[None, :]).astype(np.float32))
            with mesh:
                qg, load_g = step_g(logits, q_g[layer])
            out1 = route(logits, {"q": q_1[layer]}, cfg_1)
            outl = route(logits, {"q": q_l[layer]}, cfg_l, local_shards=4)
            q_g[layer], q_1[layer], q_l[layer] = qg, out1.state["q"], outl.state["q"]
            np.testing.assert_allclose(
                np.asarray(jax.device_get(qg)), np.asarray(out1.state["q"]),
                atol=1e-6, err_msg=f"m={m} step {t} layer {layer}: global q")
            np.testing.assert_array_equal(
                np.asarray(jax.device_get(load_g)), np.asarray(out1.metrics["load"]),
                err_msg=f"m={m} step {t} layer {layer}: load histogram")
            # identical loads -> identical per-layer MaxVio
            vio_g = float(np.asarray(jax.device_get(load_g)).max() / (N * k / m) - 1.0)
            vio_1 = float(out1.metrics["max_vio"])
            assert abs(vio_g - vio_1) < 1e-6, (m, t, layer, vio_g, vio_1)
            if np.abs(np.asarray(outl.state["q"]) - np.asarray(out1.state["q"])).max() > 1e-4:
                local_diverged = True
    assert local_diverged, f"m={m}: local-sync duals tracked global exactly?!"
print("OK")
""")


@pytest.mark.parametrize("arch,check_local", [
    ("minimind_moe_16e", True),   # + sync='local' discrimination run
    ("minimind_moe_64e", False),  # paper's 64e table (k=8, T=14)
])
def test_global_sync_train_loop_tracks_single_device(arch, check_local):
    """End-to-end: train_loop on a 4x2 mesh with sync='global' tracks the
    single-device run over >= 10 steps, at both paper expert tables. The
    trunk's fp32 reassociation differs across decompositions (~4e-6 in
    logits), and BIP's capacity boundary is LP-degenerate — the converged
    dual sits within ~6e-8 of the marginal token's score, leaving that
    token indifferent between two experts — so a handful of marginal
    tokens legitimately flip per step. A flip moves one token between two
    experts, i.e. per-layer MaxVio moves by a few load quanta
    (1/mean_load), and over 10 steps the flips feed back through the
    params — the two decompositions' flip patterns compound to several
    quanta by the last step (observed up to 7 with the fused-ladder
    thresholds), but the MEAN per-step drift stays small (~1 quantum)
    where per-shard local duals drift every step (~4 quanta mean at this
    scale, ~0.01 in q); q stays within the marginal-score scale. (The
    router-level trajectory test above proves bit-equal loads when the two
    decompositions see identical scores, so everything here is trunk
    reassociation, not a sync bug.) For 16e, sync='local' on the same
    stream must exceed the global mean-drift and q tolerances, so the
    bounds are discriminating."""
    _run(PRELUDE + f"ARCH={arch!r}; CHECK_LOCAL={check_local}\n" + r"""
from repro import configs
from repro.data import make_batches
from repro.distributed import make_mesh_ctx
from repro.models import build_model
from repro.training import train_loop

full = configs.get(ARCH)
# capacity_factor=8: no token drops at either granularity, so the only
# cross-decomposition differences are reassociation + marginal-tie flips
cfg = configs.reduced_for_smoke(
    ARCH,
    routing=dataclasses.replace(full.routing, sync="global", capacity_factor=8.0),
    vocab_size=256)
steps = 10
kw = dict(lr=1e-3, warmup_steps=2, total_steps=steps)

s0, log0 = train_loop(build_model(cfg), make_batches(cfg, 8, 64, steps, seed=0), **kw)
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
s1, log1 = train_loop(build_model(cfg, make_mesh_ctx(mesh)),
                      make_batches(cfg, 8, 64, steps, seed=0), mesh=mesh, **kw)

quantum = 1.0 / (8 * 64 * cfg.routing.top_k / cfg.routing.n_experts)  # 1/mean_load
v0, v1 = np.stack(log0.max_vio_steps), np.stack(log1.max_vio_steps)
assert v0.shape == v1.shape and v0.shape[0] == steps
dstep = np.abs(v0 - v1).max(axis=1)  # worst layer, per step
gdiff = dstep.max()
assert gdiff <= 8 * quantum + 1e-5, (gdiff, quantum, v0.tolist(), v1.tolist())
assert dstep.mean() <= 2 * quantum + 1e-5, (dstep.tolist(), quantum)
for a, b in zip(log0.losses, log1.losses):
    assert abs(a - b) < 5e-3, (log0.losses, log1.losses)
q0 = np.concatenate([np.asarray(s["q"]).ravel()
                     for s in s0.router_states if s is not None])
q1 = np.concatenate([np.asarray(jax.device_get(s["q"])).ravel()
                     for s in s1.router_states if s is not None])
assert np.abs(q0 - q1).max() < 5e-3, np.abs(q0 - q1).max()

if CHECK_LOCAL:
    # discrimination: per-shard local duals must drift past the global bound
    cfg_l = dataclasses.replace(
        cfg, routing=dataclasses.replace(cfg.routing, sync="local"))
    s2, log2 = train_loop(build_model(cfg_l, make_mesh_ctx(mesh)),
                          make_batches(cfg_l, 8, 64, steps, seed=0), mesh=mesh, **kw)
    lstep = np.abs(v0 - np.stack(log2.max_vio_steps)).max(axis=1)
    assert lstep.mean() > 2 * quantum + 1e-5, (lstep.tolist(), dstep.tolist())
    ql = np.concatenate([np.asarray(jax.device_get(s["q"])).ravel()
                         for s in s2.router_states if s is not None])
    assert np.abs(q0 - ql).max() > 5e-3, np.abs(q0 - ql).max()
print("OK", gdiff)
""")


def test_forecast_warm_start_sharded_matches_single_device():
    """sync='global' + forecast on a forced 4x2 mesh: the predictive
    warm-start must not change the dual trajectory (valid windows only
    tighten round 0 of the fused bisection; stale ones fail the in-count
    validity check and are ignored), and the forecaster EMAs must evolve
    identically on the mesh and on a single device — windows are validated
    inside the psum'd count, so shard-local data never skews the bracket."""
    _run(PRELUDE + r"""
from repro.core import RouterConfig, init_router_state, route
from repro.models.moe import _shard_map

mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
m, k, N, STEPS = 16, 4, 512, 8
cfg_g = RouterConfig(n_experts=m, top_k=k, strategy="bip", bip_iters=4,
                     sync="global", data_axes=("data",), forecast=True)
cfg_1 = RouterConfig(n_experts=m, top_k=k, strategy="bip", bip_iters=4,
                     sync="global", forecast=True)
cfg_off = RouterConfig(n_experts=m, top_k=k, strategy="bip", bip_iters=4,
                       sync="global")

state0 = init_router_state(cfg_g)
specs = jax.tree.map(lambda _: P(None), state0)

def sharded_step(logits, state):
    def block(lg_loc, st):
        return route(lg_loc, st, cfg_g).state
    return _shard_map(block, mesh=mesh,
                      in_specs=(P("data", None), specs), out_specs=specs,
                      )(logits, state)

step_g = jax.jit(sharded_step)
rng = np.random.default_rng(3)
st_g, st_1, st_off = state0, init_router_state(cfg_1), init_router_state(cfg_off)
for t in range(STEPS):
    logits = jnp.asarray(
        (rng.standard_normal((N, m))
         + (1.0 + 0.2 * t) * np.linspace(2, -2, m)[None, :]).astype(np.float32))
    with mesh:
        st_g = jax.device_get(step_g(logits, st_g))
    st_1 = route(logits, st_1, cfg_1).state
    st_off = route(logits, st_off, cfg_off).state
    for key in ("q", "q_ema", "q_err"):
        np.testing.assert_allclose(
            np.asarray(st_g[key]), np.asarray(st_1[key]), atol=1e-6,
            err_msg=f"step {t}: {key} mesh vs single")
    np.testing.assert_allclose(
        np.asarray(st_1["q"]), np.asarray(st_off["q"]), atol=1e-6,
        err_msg=f"step {t}: forecast warm-start perturbed the dual")
assert np.abs(np.asarray(st_1["q_ema"])).max() > 0
assert np.abs(np.asarray(st_1["q_err"])).max() > 0
print("OK")
""")


def test_sharded_grad_accum_on_mesh():
    """Microbatched sharded step == unmicrobatched sharded step (topk, no
    drops): grad accumulation composes with FSDP/TP shardings."""
    _run(PRELUDE + r"""
from repro import configs
from repro.data import make_batches
from repro.distributed import make_mesh_ctx, shard_tree, train_state_specs, batch_specs
from repro.models import build_model
from repro.optim.adamw import from_model_config
from repro.optim.schedules import constant
from repro.training import compile_train_step, init_train_state

cfg = configs.reduced_for_smoke("minimind_moe_16e", vocab_size=256)
cfg = dataclasses.replace(
    cfg, routing=dataclasses.replace(cfg.routing, strategy="topk", capacity_factor=8.0))
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
model = build_model(cfg, make_mesh_ctx(mesh))
opt_cfg = from_model_config(cfg)
batch = next(iter(make_batches(cfg, 8, 32, 1, seed=0)))

outs = []
for micro in (1, 2):
    # fresh state per run: donation consumes the sharded buffers, and
    # device_put may alias rather than copy, so never reuse a donated tree
    state = init_train_state(model, jax.random.PRNGKey(0), opt_cfg)
    st = shard_tree(state, train_state_specs(state, cfg, mesh), mesh)
    fn = compile_train_step(model, opt_cfg, constant(1e-3), st, batch,
                            mesh=mesh, microbatches=micro)
    with mesh:
        s_new, mets = fn(st, batch)
    outs.append((jax.device_get(s_new.params), float(mets["loss"])))

assert abs(outs[0][1] - outs[1][1]) < 1e-5, (outs[0][1], outs[1][1])
for a, b in zip(jax.tree.leaves(outs[0][0]), jax.tree.leaves(outs[1][0])):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4)
print("OK")
""")
