"""Continuous-batching serving subsystem: chunked-prefill parity against the
old per-token path, scheduler lifecycle units, and engine end-to-end."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.serving import ContinuousBatchingEngine, Request, Scheduler, greedy_generate
from repro.serving.engine import _legacy_generate


def _per_token_prefill(model, params, toks, seq_len):
    """Seed ServeEngine.prefill semantics: one decode_step per position."""
    st = model.init_router_states()
    cache = model.init_cache(params, {"tokens": toks[:, :1]}, seq_len)
    outs = []
    for t in range(toks.shape[1]):
        lg, cache, st = model.decode_step(params, toks[:, t : t + 1], cache, st)
        outs.append(lg)
    return jnp.concatenate(outs, axis=1), cache, st


def _chunked_prefill(model, params, toks, seq_len, chunk):
    b, s = toks.shape
    assert s % chunk == 0
    st = model.init_router_states()
    cache = model.init_slot_cache(params, b, seq_len)
    outs = []
    for t in range(0, s, chunk):
        lg, cache, st, _ = model.prefill_chunk(
            params, toks[:, t : t + chunk], cache, st, jnp.full((b,), chunk, jnp.int32)
        )
        outs.append(lg)
    return jnp.concatenate(outs, axis=1), cache, st


# ------------------------------------------------------------------ parity


@pytest.mark.parametrize(
    "arch", ["stablelm_1_6b", "gemma2_27b", "mamba2_130m", "zamba2_7b"]
)
def test_chunked_prefill_matches_per_token(arch):
    """Chunked prefill must produce the same logits AND the same cache as
    the seed's one-token-at-a-time prefill (fp reassociation noise only)."""
    cfg = configs.reduced_for_smoke(arch, vocab_size=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(
        np.random.default_rng(0).integers(0, 128, (2, 12)), jnp.int32
    )
    ref, ref_cache, _ = _per_token_prefill(model, params, toks, 32)
    got, got_cache, _ = _chunked_prefill(model, params, toks, 32, chunk=4)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got), atol=1e-4, rtol=1e-4)
    for a, b in zip(jax.tree.leaves(ref_cache), jax.tree.leaves(got_cache)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-4, rtol=1e-4
        )


def test_chunked_prefill_matches_per_token_moe_stateless():
    """With a stateless gate (topk) MoE routing is per-token independent, so
    chunking must not change anything (capacity kept slack)."""
    cfg = configs.reduced_for_smoke("minimind_moe_16e", vocab_size=128)
    cfg = dataclasses.replace(
        cfg, routing=dataclasses.replace(cfg.routing, strategy="topk", capacity_factor=8.0)
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(1).integers(0, 128, (2, 12)), jnp.int32)
    ref, _, _ = _per_token_prefill(model, params, toks, 32)
    got, _, _ = _chunked_prefill(model, params, toks, 32, chunk=4)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got), atol=1e-4, rtol=1e-4)


def test_single_chunk_prefill_matches_forward_moe_bip():
    """One chunk covering the whole prompt routes the exact token set the
    training forward pass routes -> identical logits and identical BIP dual
    vector q, even with the stateful gate."""
    cfg = configs.reduced_for_smoke("minimind_moe_16e", vocab_size=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    states = model.init_router_states()
    toks = jnp.asarray(np.random.default_rng(2).integers(0, 128, (2, 8)), jnp.int32)
    fwd, fwd_states, _, _ = model.forward(params, {"tokens": toks}, states)
    cache = model.init_slot_cache(params, 2, 32)
    got, _, got_states, _ = model.prefill_chunk(
        params, toks, cache, states, jnp.full((2,), 8, jnp.int32)
    )
    np.testing.assert_allclose(np.asarray(fwd), np.asarray(got), atol=1e-4, rtol=1e-4)
    for a, b in zip(jax.tree.leaves(fwd_states), jax.tree.leaves(got_states)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5)


def test_chunked_prefill_sliding_window_ring_wrap():
    """Prompts longer than the sliding window: the ring buffer wraps DURING
    a chunk, so in-chunk writes clobber keys earlier queries still need —
    the chunk path must attend against the pre-update ring (regression for
    a write-then-attend bug found in review)."""
    cfg = configs.reduced_for_smoke("gemma2_27b", vocab_size=128)
    cfg = dataclasses.replace(cfg, window_size=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    toks = jnp.asarray(np.random.default_rng(7).integers(0, 128, (2, 24)), jnp.int32)
    ref, _, _ = _per_token_prefill(model, params, toks, 32)
    for chunk in (4, 8):
        got, _, _ = _chunked_prefill(model, params, toks, 32, chunk=chunk)
        np.testing.assert_allclose(
            np.asarray(ref), np.asarray(got), atol=1e-4, rtol=1e-4,
            err_msg=f"chunk={chunk}",
        )


def test_ragged_lengths_and_idle_slots_are_isolated():
    """Rows advancing by different amounts (incl. 0) must match the same
    rows run in lockstep — padding may never leak across slots."""
    cfg = configs.reduced_for_smoke("gemma2_27b", vocab_size=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    st = model.init_router_states()
    toks = jnp.asarray(np.random.default_rng(3).integers(0, 128, (2, 8)), jnp.int32)

    ref, _, _, _ = model.prefill_chunk(
        params, toks[:, :4], model.init_slot_cache(params, 2, 32), st,
        jnp.full((2,), 4, jnp.int32),
    )
    cache = model.init_slot_cache(params, 2, 32)
    t1 = jnp.stack([toks[0, :4], toks[1, :4]])
    lg1, cache, st1, _ = model.prefill_chunk(
        params, t1, cache, st, jnp.asarray([2, 4], jnp.int32)
    )
    t2 = jnp.stack([toks[0, 2:6], toks[1, 4:8]])
    lg2, cache, _, _ = model.prefill_chunk(
        params, t2, cache, st1, jnp.asarray([2, 0], jnp.int32)
    )
    # local layers attend [pre-update ring | in-chunk keys]; where the chunk
    # boundary falls changes the fp summation split, so tight allclose, not
    # bitwise
    np.testing.assert_allclose(
        np.asarray(ref[1]), np.asarray(lg1[1]), atol=1e-5, rtol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(ref[0, 2:4]), np.asarray(lg2[0, :2]), atol=1e-5, rtol=1e-5
    )


def test_reset_slot_equals_fresh_cache():
    """A recycled slot must behave exactly like a never-used one."""
    cfg = configs.reduced_for_smoke("stablelm_1_6b", vocab_size=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    st = model.init_router_states()
    toks = jnp.asarray(np.random.default_rng(4).integers(0, 128, (2, 4)), jnp.int32)
    used = model.init_slot_cache(params, 2, 32)
    _, used, _, _ = model.prefill_chunk(
        params, toks, used, st, jnp.full((2,), 4, jnp.int32)
    )
    recycled = model.reset_slot(used, jnp.asarray(1))
    fresh = model.init_slot_cache(params, 2, 32)
    lg_r, _, _, _ = model.prefill_chunk(
        params, toks, recycled, st, jnp.asarray([0, 4], jnp.int32)
    )
    lg_f, _, _, _ = model.prefill_chunk(
        params, toks, fresh, st, jnp.asarray([0, 4], jnp.int32)
    )
    np.testing.assert_array_equal(np.asarray(lg_r[1]), np.asarray(lg_f[1]))


def test_padding_does_not_move_router_state():
    """Decode-heavy serving chunks are mostly padding; the BIP dual q must
    be a function of the real rows only. Same real tokens with and without
    heavy padding -> same q (threshold-statistic resolution); an all-padding
    step must leave q untouched."""
    from repro.core import RouterConfig, init_router_state, route

    rng = np.random.default_rng(8)
    rcfg = RouterConfig(n_experts=8, top_k=2, strategy="bip", bip_iters=4)
    state = init_router_state(rcfg)
    real = jnp.asarray(rng.standard_normal((6, 8)), jnp.float32)

    out_ref = route(real, state, rcfg, token_mask=jnp.ones((6,), bool))
    padded = jnp.concatenate([real, jnp.zeros((42, 8))], axis=0)
    mask = jnp.arange(48) < 6
    out_pad = route(padded, state, rcfg, token_mask=mask)
    np.testing.assert_allclose(
        np.asarray(out_ref.state["q"]), np.asarray(out_pad.state["q"]), atol=1e-5
    )
    np.testing.assert_array_equal(
        np.asarray(out_ref.expert_index), np.asarray(out_pad.expert_index[:6])
    )

    out_idle = route(padded, out_pad.state, rcfg, token_mask=jnp.zeros((48,), bool))
    np.testing.assert_array_equal(
        np.asarray(out_pad.state["q"]), np.asarray(out_idle.state["q"])
    )


# --------------------------------------------------------------- scheduler


def _req(plen=4, gen=4, **kw):
    return Request(prompt=list(range(1, plen + 1)), max_new_tokens=gen, **kw)


def test_scheduler_fifo_admission_order():
    s = Scheduler(n_slots=2)
    r1, r2, r3 = _req(), _req(), _req()
    assert s.submit(r1) and s.submit(r2) and s.submit(r3)
    admitted = s.admit()
    assert [r.req_id for _, r in admitted] == [r1.req_id, r2.req_id]
    assert s.n_free_slots == 0 and len(s.waiting) == 1
    # r3 waits until a slot frees, then takes it FIFO
    s.finish(admitted[1][0], "eos")
    (idx, nxt), = s.admit()
    assert nxt.req_id == r3.req_id and idx == admitted[1][0]


def test_scheduler_backpressure():
    s = Scheduler(n_slots=1, max_waiting=2)
    assert s.submit(_req()) and s.submit(_req())
    assert not s.submit(_req()), "queue full must refuse, not drop"
    s.admit()
    assert s.submit(_req()), "admission drains the queue and reopens intake"


def test_scheduler_slot_reuse_and_lifecycle():
    s = Scheduler(n_slots=1)
    a, b = _req(), _req()
    s.submit(a), s.submit(b)
    (i1, got), = s.admit()
    assert got is a and a.phase == "prefill"
    done = s.finish(i1, "max_new_tokens")
    assert done is a and a.phase == "done" and a.finish_reason == "max_new_tokens"
    (i2, got2), = s.admit()
    assert got2 is b and i2 == i1, "freed slot must be reused"
    assert s.has_work and s.n_active == 1


# ------------------------------------------------------------------ engine


def test_engine_eviction_on_eos():
    """A request hitting EOS frees its slot early; the waiting request is
    admitted into it and completes."""
    cfg = configs.reduced_for_smoke("stablelm_1_6b", vocab_size=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, 64, (5,))

    # find the greedy token this prompt emits first, use it as EOS
    probe = ContinuousBatchingEngine(model, params, n_slots=1, chunk_size=8, max_seq_len=32)
    r = probe.submit(prompt, 1, ignore_eos=True)
    probe.run()
    eos = r.output[0]

    eng = ContinuousBatchingEngine(
        model, params, n_slots=1, chunk_size=8, max_seq_len=32, eos_id=eos
    )
    r1 = eng.submit(prompt, 8)
    r2 = eng.submit(rng.integers(0, 64, (3,)), 2, ignore_eos=True)
    eng.run()
    assert r1.finish_reason == "eos" and r1.output[-1] == eos and len(r1.output) == 1
    assert r2.finish_reason == "max_new_tokens" and len(r2.output) == 2


def test_engine_matches_legacy_generation():
    """More requests than slots, equal prompts: every completed request must
    reproduce the legacy per-token greedy continuation exactly (dense arch:
    rows are independent, so batching cannot change the math)."""
    cfg = configs.reduced_for_smoke("stablelm_1_6b", vocab_size=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    prompts = jnp.asarray(rng.integers(0, 128, (4, 6)), jnp.int32)
    ref = np.asarray(_legacy_generate(model, params, prompts, 5, 64, None))

    eng = ContinuousBatchingEngine(model, params, n_slots=2, chunk_size=4, max_seq_len=64)
    reqs = [eng.submit(np.asarray(prompts[i]), 5, ignore_eos=True) for i in range(4)]
    eng.run()
    got = np.asarray([r.output for r in reqs])
    np.testing.assert_array_equal(ref, got)


def test_engine_moe_stream_stays_balanced():
    """Mixed prefill/decode traffic through the BIP gate: loads accumulate
    and stay balanced (MaxVio well under collapse)."""
    cfg = configs.reduced_for_smoke("minimind_moe_16e", vocab_size=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(6)
    eng = ContinuousBatchingEngine(model, params, n_slots=3, chunk_size=8, max_seq_len=64)
    reqs = [
        eng.submit(rng.integers(0, 128, (int(rng.integers(3, 20)),)), 6, ignore_eos=True)
        for _ in range(6)
    ]
    done = eng.run()
    assert len(done) == 6 and all(len(r.output) == 6 for r in reqs)
    load = eng.expert_load
    assert load.sum() > 0
    maxvio = load.max() / max(load.mean(), 1e-9) - 1.0
    assert maxvio < 1.0, f"expert loads collapsed: {load}"


def test_greedy_generate_wrapper_shapes():
    cfg = configs.reduced_for_smoke("minimind_moe_16e", vocab_size=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    out = greedy_generate(model, params, prompts, n_steps=4, max_seq_len=32)
    assert out.shape == (2, 4)
    assert np.all(np.asarray(out) >= 0) and np.all(np.asarray(out) < 64)
