"""Unit + property tests for the routing core (Algorithm 1/2 reference)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    RouterConfig,
    balance_metrics,
    bip_dual_update,
    bip_dual_update_global,
    bip_dual_update_masked,
    bip_dual_update_threshold,
    bip_route_reference,
    init_router_state,
    kth_largest,
    kth_largest_threshold,
    route,
)
from repro.core.lp_oracle import greedy_balanced_objective, routing_objective, solve_plp

jax.config.update("jax_enable_x64", False)


def _scores(rng, n, m, skew=0.0):
    """Softmax scores with an optional popularity skew (collapse pressure)."""
    logits = rng.standard_normal((n, m)).astype(np.float32)
    logits += skew * np.linspace(2.0, -2.0, m)[None, :]
    return jax.nn.softmax(jnp.asarray(logits), axis=-1)


# ---------------------------------------------------------------- kth largest


@given(
    n=st.integers(4, 200),
    kth=st.integers(0, 10),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_kth_largest_matches_numpy(n, kth, seed):
    kth = min(kth, n - 1)
    x = np.random.default_rng(seed).standard_normal((n,)).astype(np.float32)
    got = kth_largest(jnp.asarray(x), kth)
    want = np.sort(x)[::-1][kth]
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6)


@given(
    n=st.integers(8, 300),
    kth=st.integers(0, 40),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_threshold_kth_partitions_correctly(n, kth, seed):
    """The bisected threshold must admit <= kth elements strictly above it,
    and the set {x > thr} must be exactly the top-kth set when values are
    distinct (which standard normals are, a.s.)."""
    kth = min(kth, n - 1)
    x = np.random.default_rng(seed).standard_normal((n,)).astype(np.float32)
    thr = np.asarray(kth_largest_threshold(jnp.asarray(x), kth, n_bisect=40))
    above = int((x > thr).sum())
    assert above <= kth
    # distinct values: everything strictly greater than the true kth+1-th
    # largest must stay above the threshold.
    want = np.sort(x)[::-1][kth]
    assert int((x > want + 1e-5).sum()) <= above + kth  # sanity
    np.testing.assert_allclose(thr, want, atol=2e-5)


# ------------------------------------------------------------- dual update


def test_dual_update_balances_skewed_scores():
    """Under heavy popularity skew, raw top-k collapses but s - q is balanced."""
    rng = np.random.default_rng(0)
    n, m, k = 512, 16, 4
    s = _scores(rng, n, m, skew=2.0)
    # raw top-k: badly unbalanced
    raw = balance_metrics(jax.lax.top_k(s, k)[1].astype(jnp.int32), m, k)
    assert float(raw["max_vio"]) > 1.0
    w, idx, q = bip_route_reference(s, jnp.zeros((m,)), top_k=k, n_iters=8)
    bal = balance_metrics(idx, m, k)
    assert float(bal["max_vio"]) < 0.15, float(bal["max_vio"])
    # gate values must be the raw scores of selected experts
    np.testing.assert_allclose(
        np.asarray(w), np.take_along_axis(np.asarray(s), np.asarray(idx), -1)
    )
    assert np.all(np.asarray(q) >= 0.0)


@given(seed=st.integers(0, 2**31 - 1), t=st.sampled_from([2, 4, 8]))
@settings(max_examples=10, deadline=None)
def test_dual_update_threshold_matches_topk_variant(seed, t):
    rng = np.random.default_rng(seed)
    n, m, k = 256, 8, 2
    s = _scores(rng, n, m, skew=1.0)
    q_ref, p_ref = bip_dual_update(s, jnp.zeros((m,)), top_k=k, n_iters=t)
    q_thr, p_thr = bip_dual_update_threshold(
        s, jnp.zeros((m,)), top_k=k, n_iters=t, n_bisect=40
    )
    np.testing.assert_allclose(np.asarray(q_ref), np.asarray(q_thr), atol=3e-5)
    np.testing.assert_allclose(np.asarray(p_ref), np.asarray(p_thr), atol=3e-5)


def _selection_sets(s, q, k):
    """Per-row top-k index sets under corrected scores, plus the boundary
    gap (k-th minus (k+1)-th corrected value) that prices tie fragility."""
    corrected = np.asarray(s) - np.asarray(q)[None, :]
    order = np.argsort(-corrected, axis=-1, kind="stable")
    sets = [frozenset(row[:k]) for row in order]
    kth = np.take_along_axis(corrected, order, -1)
    gaps = kth[:, k - 1] - kth[:, k]
    return sets, gaps


@given(
    seed=st.integers(0, 2**31 - 1),
    t=st.sampled_from([1, 2, 4, 8]),
    warm=st.floats(0.0, 0.3),
    skew=st.floats(0.0, 2.0),
)
@settings(max_examples=25, deadline=None)
def test_threshold_vs_sort_dual_selection_set_equivalence(seed, t, warm, skew):
    """The threshold (bisection) dual update is the sync='global' building
    block: the expert SETS it selects must match the sort-based oracle's
    for every token whose top-k boundary gap exceeds the bisection
    resolution (~6e-8 at n_bisect=40 over softmax ranges; tokens inside
    that band are capacity-marginal and LP-degenerate — either choice is
    an optimal assignment). Warm-start duals exercise the carried-q path."""
    rng = np.random.default_rng(seed)
    n, m, k = 256, 16, 4
    s = _scores(rng, n, m, skew=skew)
    q0 = jnp.asarray(rng.uniform(0, warm, (m,)).astype(np.float32))
    q_ref, _ = bip_dual_update(s, q0, top_k=k, n_iters=t)
    q_thr, _ = bip_dual_update_threshold(s, q0, top_k=k, n_iters=t, n_bisect=40)
    np.testing.assert_allclose(np.asarray(q_ref), np.asarray(q_thr), atol=3e-5)
    sets_ref, gaps = _selection_sets(s, q_ref, k)
    sets_thr, _ = _selection_sets(s, q_thr, k)
    robust = gaps > 3e-4  # >=10x the dual atol: no margin flake
    assert robust.sum() > 0  # the property must not be vacuous
    mismatched = [
        i for i in range(n) if robust[i] and sets_ref[i] != sets_thr[i]
    ]
    assert not mismatched, (mismatched[:5], gaps[mismatched[:5]])


@given(
    seed=st.integers(0, 2**31 - 1),
    t=st.sampled_from([2, 4]),
    frac=st.floats(0.2, 0.9),
)
@settings(max_examples=25, deadline=None)
def test_masked_dual_update_equals_dense_subset(seed, t, frac):
    """Masked padding rows (the serving path) must be invisible: the dual
    from the masked update over (real + padding) rows equals the sort-based
    update over just the real rows, and the selection sets on real rows
    agree outside the degenerate boundary band. Also pins the all-True
    mask to the unmasked threshold variant."""
    rng = np.random.default_rng(seed)
    n, m, k = 192, 8, 2
    s = _scores(rng, n, m, skew=1.0)
    q0 = jnp.asarray(rng.uniform(0, 0.2, (m,)).astype(np.float32))
    mask = rng.random(n) < frac
    mask[0] = True  # never all-padding
    jmask = jnp.asarray(mask)

    q_m, _ = bip_dual_update_masked(s, q0, jmask, top_k=k, n_iters=t, n_bisect=40)
    q_dense, _ = bip_dual_update(
        jnp.asarray(np.asarray(s)[mask]), q0, top_k=k, n_iters=t
    )
    np.testing.assert_allclose(np.asarray(q_m), np.asarray(q_dense), atol=3e-5)

    s_real = np.asarray(s)[mask]
    sets_m, gaps = _selection_sets(s_real, q_m, k)
    sets_d, _ = _selection_sets(s_real, q_dense, k)
    robust = gaps > 3e-4
    mismatched = [
        i for i in range(len(sets_m)) if robust[i] and sets_m[i] != sets_d[i]
    ]
    assert not mismatched, mismatched[:5]

    # all-True mask == the unmasked threshold variant (same bisection)
    q_all, _ = bip_dual_update_masked(
        s, q0, jnp.ones((n,), bool), top_k=k, n_iters=t, n_bisect=40
    )
    q_thr, _ = bip_dual_update_threshold(s, q0, top_k=k, n_iters=t, n_bisect=40)
    np.testing.assert_allclose(np.asarray(q_all), np.asarray(q_thr), atol=1e-6)


# ------------------------------------------- fused multi-threshold bisection


@given(
    n=st.integers(8, 300),
    kth=st.integers(0, 40),
    fanout=st.sampled_from([2, 7, 15, 32]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=30, deadline=None)
def test_fused_fanout_threshold_matches_classic_bisection(n, kth, fanout, seed):
    """fanout>1 probes F thresholds per fused count and must land on the
    same order statistic as classic bisection (fanout=1): each within its
    bracket resolution of the true sort value, and both must keep the
    partition property (<= kth elements strictly above the threshold)."""
    kth = min(kth, n - 1)
    x = np.random.default_rng(seed).standard_normal((n,)).astype(np.float32)
    want = np.sort(x)[::-1][kth]
    for f in (1, fanout):
        thr = np.asarray(
            kth_largest_threshold(jnp.asarray(x), kth, n_bisect=26, fanout=f)
        )
        assert int((x > thr).sum()) <= kth, (f, thr, want)
        np.testing.assert_allclose(thr, want, atol=2e-5, err_msg=f"fanout={f}")


@given(
    seed=st.integers(0, 2**31 - 1),
    fanout=st.sampled_from([1, 4, 32]),
    good=st.sampled_from([True, False]),
)
@settings(max_examples=25, deadline=None)
def test_forecast_window_valid_and_stale(seed, fanout, good):
    """A valid predicted bracket must not change the answer (it only
    tightens round 0); a stale bracket — shifted entirely off the
    statistic — must fail the in-round validity check (count(w_lo) > kth
    >= count(w_hi)) and fall back to the full range, also unchanged."""
    rng = np.random.default_rng(seed)
    n, kth = 200, 10
    x = rng.standard_normal((n,)).astype(np.float32)
    want = np.sort(x)[::-1][kth]
    if good:
        w = (jnp.float32(want - 0.05), jnp.float32(want + 0.05))
    else:
        w = (jnp.float32(want + 1.0), jnp.float32(want + 2.0))
    thr = np.asarray(
        kth_largest_threshold(
            jnp.asarray(x), kth, n_bisect=26, fanout=fanout, window=w
        )
    )
    assert int((x > thr).sum()) <= kth
    np.testing.assert_allclose(thr, want, atol=2e-5)


@given(
    seed=st.integers(0, 2**31 - 1),
    t=st.sampled_from([2, 4]),
    fanout=st.sampled_from([2, 8, 32]),
)
@settings(max_examples=15, deadline=None)
def test_global_dual_fused_fanout_matches_sort_oracle(seed, t, fanout):
    """The production sync='global' configuration — fanout>1, static
    softmax score bounds, cold forecaster window (zeros: stale, must be
    ignored) — tracks the sort-based oracle across warm-started duals."""
    rng = np.random.default_rng(seed)
    n, m, k = 256, 16, 4
    s = _scores(rng, n, m, skew=1.5)
    q0 = jnp.asarray(rng.uniform(0, 0.1, (m,)).astype(np.float32))
    q_ref, p_ref = bip_dual_update(s, q0, top_k=k, n_iters=t)
    zeros = jnp.zeros((m,), jnp.float32)
    q_g, p_g = bip_dual_update_global(
        s, q0, top_k=k, n_iters=t, n_bisect=26, fanout=fanout,
        score_bounds=(0.0, 1.0), window=(zeros, zeros),
    )
    np.testing.assert_allclose(np.asarray(q_g), np.asarray(q_ref), atol=3e-5)
    np.testing.assert_allclose(np.asarray(p_g), np.asarray(p_ref), atol=3e-5)


@given(
    seed=st.integers(0, 2**31 - 1),
    fanout=st.sampled_from([4, 32]),
    frac=st.floats(0.2, 0.9),
)
@settings(max_examples=15, deadline=None)
def test_masked_dual_update_fanout_matches_dense_subset(seed, fanout, frac):
    """Fused fanout composes with the token mask (the serving path): the
    masked update at fanout>1 still equals the sort-based update over just
    the real rows."""
    rng = np.random.default_rng(seed)
    n, m, k = 192, 8, 2
    s = _scores(rng, n, m, skew=1.0)
    q0 = jnp.asarray(rng.uniform(0, 0.2, (m,)).astype(np.float32))
    mask = rng.random(n) < frac
    mask[0] = True
    q_m, _ = bip_dual_update_masked(
        s, q0, jnp.asarray(mask), top_k=k, n_iters=2, n_bisect=26, fanout=fanout
    )
    q_dense, _ = bip_dual_update(
        jnp.asarray(np.asarray(s)[mask]), q0, top_k=k, n_iters=2
    )
    np.testing.assert_allclose(np.asarray(q_m), np.asarray(q_dense), atol=3e-5)


def test_global_dual_with_stats_returns_preclamp_statistic():
    """with_stats=True returns the pre-clamp order statistic t consistent
    with q = max(0, t), and leaves the (q, p) values unchanged — the
    forecaster EMA update in route() relies on both."""
    rng = np.random.default_rng(13)
    n, m, k = 256, 16, 4
    s = _scores(rng, n, m, skew=1.5)
    q0 = jnp.zeros((m,))
    q2, p2 = bip_dual_update_global(s, q0, top_k=k, n_iters=4, fanout=32,
                                    score_bounds=(0.0, 1.0))
    q3, p3, t3 = bip_dual_update_global(s, q0, top_k=k, n_iters=4, fanout=32,
                                        score_bounds=(0.0, 1.0), with_stats=True)
    np.testing.assert_array_equal(np.asarray(q2), np.asarray(q3))
    np.testing.assert_array_equal(np.asarray(p2), np.asarray(p3))
    np.testing.assert_array_equal(
        np.asarray(q3), np.maximum(0.0, np.asarray(t3))
    )


def test_forecast_route_state_evolves_and_preserves_duals():
    """route(sync='global', forecast=True) must carry 'q_ema'/'q_err' in
    its state, update them every call, and leave the dual trajectory
    within bisection resolution of the forecast-off path."""
    rng = np.random.default_rng(14)
    n, m, k = 256, 8, 2
    cfg_on = RouterConfig(n_experts=m, top_k=k, strategy="bip", bip_iters=4,
                          sync="global", forecast=True)
    cfg_off = RouterConfig(n_experts=m, top_k=k, strategy="bip", bip_iters=4,
                           sync="global")
    st_on, st_off = init_router_state(cfg_on), init_router_state(cfg_off)
    assert set(st_on) == {"q", "q_ema", "q_err"}
    for step in range(5):
        logits = jnp.asarray(
            (rng.standard_normal((n, m))
             + 1.5 * np.linspace(2, -2, m)[None, :]).astype(np.float32))
        st_on = route(logits, st_on, cfg_on).state
        st_off = route(logits, st_off, cfg_off).state
        np.testing.assert_allclose(
            np.asarray(st_on["q"]), np.asarray(st_off["q"]), atol=1e-6,
            err_msg=f"step {step}: forecast warm-start perturbed the dual")
    assert float(jnp.abs(st_on["q_ema"]).max()) > 0.0
    assert float(jnp.abs(st_on["q_err"]).max()) > 0.0


def test_global_dual_update_single_shard_matches_sort_oracle():
    """bip_dual_update_global with axis_names=() and no mask reproduces the
    independent sort-based oracle up to bisection resolution (the
    sync='global' route branch relies on this for the unsharded reference
    trajectory; bip_dual_update_threshold is an alias of the global
    implementation, so the oracle is the only independent check)."""
    rng = np.random.default_rng(11)
    n, m, k = 256, 16, 4
    s = _scores(rng, n, m, skew=1.5)
    q0 = jnp.asarray(rng.uniform(0, 0.1, (m,)).astype(np.float32))
    q_g, p_g = bip_dual_update_global(s, q0, top_k=k, n_iters=4, n_bisect=40)
    q_s, p_s = bip_dual_update(s, q0, top_k=k, n_iters=4)
    np.testing.assert_allclose(np.asarray(q_g), np.asarray(q_s), atol=3e-5)
    np.testing.assert_allclose(np.asarray(p_g), np.asarray(p_s), atol=3e-5)


def test_route_global_sync_single_device_matches_threshold_duals():
    """route(sync='global') off-mesh must carry the threshold-solver duals
    (not the sort-based ones): the warm-start state equals a direct
    bip_dual_update_global call on the same scores."""
    rng = np.random.default_rng(12)
    n, m, k = 256, 8, 2
    cfg = RouterConfig(n_experts=m, top_k=k, strategy="bip", bip_iters=4,
                       sync="global")
    logits = jnp.asarray(rng.standard_normal((n, m)).astype(np.float32))
    out = route(logits, init_router_state(cfg), cfg)
    s = jax.nn.softmax(logits, axis=-1)
    q_direct, _ = bip_dual_update_global(s, jnp.zeros((m,)), top_k=k, n_iters=4)
    np.testing.assert_allclose(
        np.asarray(out.state["q"]), np.asarray(q_direct), atol=1e-7
    )
    assert float(out.metrics["max_vio"]) < 0.3


def test_objective_near_lp_optimum():
    """BIP-routed assignment objective should approach the LP upper bound and
    beat the greedy balanced heuristic."""
    rng = np.random.default_rng(1)
    n, m, k = 128, 8, 2
    s = np.asarray(_scores(rng, n, m, skew=1.5))
    _, lp_opt = solve_plp(s, k)
    _, idx, _ = bip_route_reference(jnp.asarray(s), jnp.zeros((m,)), top_k=k, n_iters=8)
    obj = routing_objective(s, np.asarray(idx))
    greedy = greedy_balanced_objective(s, k)
    vio = float(balance_metrics(idx, m, k)["max_vio"])
    # The ADMM routing is only approximately capacity-feasible (MaxVio > 0),
    # so its objective may exceed the LP optimum by at most the mass of the
    # overflow tokens; it must sit in a tight band around the LP optimum and
    # beat the greedy balanced heuristic.
    assert vio < 0.2, vio
    assert 0.93 * lp_opt <= obj <= (1.0 + vio) * lp_opt, (obj, lp_opt, vio)
    assert obj >= 0.98 * greedy, (obj, greedy)


def test_warm_start_persists_and_improves_first_step():
    """Paper's headline: balance from the FIRST batch, and q warm-start keeps
    subsequent batches balanced with tiny T."""
    rng = np.random.default_rng(2)
    n, m, k = 512, 16, 4
    q = jnp.zeros((m,))
    vios = []
    for step in range(8):
        s = _scores(rng, n, m, skew=2.0)
        _, idx, q = bip_route_reference(s, q, top_k=k, n_iters=4)
        vios.append(float(balance_metrics(idx, m, k)["max_vio"]))
    # cold adversarial start needs a couple of batches of warm-up at T=4; the
    # paper's T in {2,4} works because init-time router scores are near-uniform.
    assert max(vios[2:]) < 0.35, vios
    assert np.mean(vios[2:]) < 0.2, vios  # AvgMaxVio-like, steady state


# ------------------------------------------------------------------- router


@pytest.mark.parametrize("strategy", ["topk", "aux_loss", "lossfree", "bip"])
def test_route_api_all_strategies(strategy):
    rng = np.random.default_rng(3)
    n, m, k = 256, 8, 2
    cfg = RouterConfig(n_experts=m, top_k=k, strategy=strategy, bip_iters=4)
    state = init_router_state(cfg)
    logits = jnp.asarray(rng.standard_normal((n, m)).astype(np.float32))
    out = jax.jit(lambda l, s: route(l, s, cfg))(logits, state)
    assert out.combine_weights.shape == (n, k)
    assert out.expert_index.shape == (n, k)
    assert out.expert_index.dtype == jnp.int32
    assert np.all(np.asarray(out.expert_index) >= 0)
    assert np.all(np.asarray(out.expert_index) < m)
    assert np.isfinite(np.asarray(out.combine_weights)).all()
    # expert indices unique per token
    idx = np.asarray(out.expert_index)
    assert all(len(set(r)) == k for r in idx)
    if strategy == "aux_loss":
        assert float(out.aux_loss) > 0.0
    else:
        assert float(out.aux_loss) == 0.0


def test_route_bip_beats_others_on_skew():
    rng = np.random.default_rng(4)
    n, m, k = 512, 16, 4
    logits = jnp.asarray(
        (rng.standard_normal((n, m)) + 2.0 * np.linspace(2, -2, m)[None, :]).astype(
            np.float32
        )
    )
    vios = {}
    for strat in ["topk", "aux_loss", "lossfree", "bip"]:
        cfg = RouterConfig(n_experts=m, top_k=k, strategy=strat, bip_iters=8)
        out = route(logits, init_router_state(cfg), cfg)
        vios[strat] = float(out.metrics["max_vio"])
    assert vios["bip"] < 0.25
    assert vios["bip"] < vios["topk"]
    assert vios["bip"] < vios["aux_loss"]  # on the FIRST batch
    assert vios["bip"] < vios["lossfree"]  # lossfree needs many batches


def test_route_local_shards_mode():
    rng = np.random.default_rng(5)
    n, m, k = 512, 8, 2
    cfg = RouterConfig(n_experts=m, top_k=k, strategy="bip", bip_iters=8, sync="local")
    logits = jnp.asarray(rng.standard_normal((n, m)).astype(np.float32))
    out = route(logits, init_router_state(cfg), cfg, local_shards=4)
    assert float(out.metrics["max_vio"]) < 0.3
    assert out.state["q"].shape == (m,)


def test_gradients_flow_only_through_scores():
    """d(loss)/d(logits) must exist and be finite; q must be stop-gradient."""
    rng = np.random.default_rng(6)
    n, m, k = 64, 8, 2
    cfg = RouterConfig(n_experts=m, top_k=k, strategy="bip", bip_iters=2)
    logits = jnp.asarray(rng.standard_normal((n, m)).astype(np.float32))

    def loss(l):
        out = route(l, init_router_state(cfg), cfg)
        return jnp.sum(out.combine_weights ** 2)

    g = jax.grad(loss)(logits)
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.abs(g).sum()) > 0.0
