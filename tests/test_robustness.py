"""Fault tolerance: anomaly guards, checkpoint integrity, data-plane
retries, request deadlines, and the fault-injection harness.

Covers the DESIGN.md §Robustness invariants:
  * guarded train step: a non-finite loss/grad leaves the TrainState
    bit-untouched; enabling the guard does not perturb a healthy run
  * recovery determinism: a run that NaNs at step k, rolls back to the
    last checkpoint and replays is BIT-IDENTICAL to an uninterrupted run
    that skipped step k in place
  * checkpoint integrity: per-leaf CRCs + whole-file manifest detect
    bitrot/truncation; restore falls back to the newest VALID checkpoint;
    GC keeps the last K valid (corrupt files don't count toward K)
  * SIGTERM triggers one final synchronous checkpoint
  * data plane: transient shard open/read failures retry with backoff and
    reproduce the exact same batches; undecodable .jsonl lines are
    skipped rank-consistently; a crashed prefetch producer restarts
    within its retry budget; next() after close() raises, not wedges
  * serving: per-request deadlines evict/expire, queue timeouts and
    shed-on-full degrade gracefully, every request's outcome is reported
    exactly once, and the drain loop never wedges
  * router: the dual-health watchdog resets poisoned q / forecaster EMAs
    to safe init and is bitwise-transparent on healthy carries
"""
from __future__ import annotations

import itertools
import json
import os
import signal
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import (
    CheckpointCorruptError,
    CheckpointManager,
    load_pytree,
    save_pytree,
    verify_checkpoint,
)
from repro.checkpoint.store import checkpoint_steps, latest_step
from repro.core.router import route
from repro.core.types import RouterConfig, init_router_state
from repro.data.loader import ShardedTextLoader, resolve_shards
from repro.data.prefetch import Prefetcher
from repro.data.synthetic import SyntheticBatchStream
from repro.data.tokenizer import ByteBPETokenizer, iter_corpus_texts
from repro.models import build_model
from repro.robustness import (
    FaultPlan,
    GuardConfig,
    TrainGuard,
    TrainingDiverged,
    corrupt_file,
    parse_fault,
)
from repro.robustness.faults import FlakyOpen, FlakyStream
from repro.robustness.guards import OK, ROLLBACK, SKIP
from repro.training.loop import train_loop

CORPUS = os.path.join(os.path.dirname(__file__), "fixtures", "corpus")


@pytest.fixture(scope="module")
def moe():
    cfg = configs.reduced_for_smoke("minimind_moe_16e", vocab_size=256)
    return cfg, build_model(cfg)


@pytest.fixture(scope="module")
def tok():
    return ByteBPETokenizer.train(
        iter_corpus_texts(resolve_shards(CORPUS)), vocab_size=280
    )


def _leaves(tree):
    return [np.asarray(x) for x in jax.tree.leaves(tree)]


def _bitwise_equal(a, b) -> bool:
    la, lb = _leaves(a), _leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(x, y, equal_nan=True) for x, y in zip(la, lb)
    )


# ------------------------------------------------------- fault registry


def test_fault_registry_parse_and_ranges():
    f = parse_fault("nan_grad@step=3")
    assert f.fires(3) and not f.fires(4)
    f = parse_fault("nan_grad@step=2:5")
    assert [s for s in range(8) if f.fires(s)] == [2, 3, 4]
    f = parse_fault("flaky_open@p=0.25,max_consecutive=3,seed=9")
    assert f.p == 0.25 and f.max_consecutive == 3 and f.seed == 9
    assert "ckpt_corrupt" in parse_fault("ckpt_corrupt@step=0,mode=truncate").describe()
    with pytest.raises(ValueError, match="unknown fault"):
        parse_fault("not_a_fault@x=1")
    with pytest.raises(ValueError, match="bad fault parameter"):
        parse_fault("nan_grad@step")


def test_fault_determinism_across_replay():
    # firing is a pure function of fault state + step index: a replay of
    # the same steps sees the same faults
    f1, f2 = parse_fault("nan_grad@step=3,7"), parse_fault("nan_grad@step=3,7")
    assert [f1.fires(s) for s in range(10)] == [f2.fires(s) for s in range(10)]


# --------------------------------------------------------- guard ladder


def test_guard_ladder_skip_lr_drop_rollback():
    g = TrainGuard(
        GuardConfig(policy="skip", skips_before_lr_drop=2, lr_drop=0.5,
                    min_lr_scale=0.3),
        can_rollback=True,
    )
    assert g.observe(0, 1.0, True) == OK
    assert g.observe(1, float("nan"), False) == SKIP      # 1st anomaly
    assert g.lr_scale == 1.0
    assert g.observe(2, float("nan"), False) == SKIP      # 2nd -> LR drop
    assert g.lr_scale == 0.5
    assert g.observe(3, float("nan"), False) == SKIP
    action = g.observe(4, float("nan"), False)            # 0.25 < 0.3 floor
    assert action == ROLLBACK and g.n_rollbacks == 1
    assert {1, 2, 3, 4} <= g.skip_steps
    # a healthy step resets the consecutive counter
    g2 = TrainGuard(GuardConfig(policy="skip", skips_before_lr_drop=2))
    g2.observe(0, float("nan"), False)
    g2.observe(1, 1.0, True)
    g2.observe(2, float("nan"), False)
    assert g2.lr_scale == 1.0  # never two consecutive


def test_guard_raise_policy_and_budget():
    with pytest.raises(TrainingDiverged):
        TrainGuard(GuardConfig(policy="raise")).observe(0, float("nan"), False)
    g = TrainGuard(GuardConfig(policy="rollback", max_rollbacks=1), can_rollback=True)
    assert g.observe(0, float("nan"), False) == ROLLBACK
    with pytest.raises(TrainingDiverged, match="budget"):
        g.observe(1, float("nan"), False)
    # rollback without the means to roll back -> raise, not hang
    with pytest.raises(TrainingDiverged, match="no checkpoint"):
        TrainGuard(GuardConfig(policy="rollback"), can_rollback=False).observe(
            0, float("nan"), False
        )


def test_guard_spike_detection():
    g = TrainGuard(
        GuardConfig(policy="skip", spike_factor=3.0, spike_window=4),
        can_rollback=True,
    )
    for i, loss in enumerate([1.0, 1.1, 0.9, 1.0]):
        assert g.observe(i, loss, True) == OK
    assert g.observe(4, 9.0, True) == ROLLBACK  # 9 > 3 x median(~1)
    assert any(e["kind"] == "spike" for e in g.events)
    with pytest.raises(ValueError, match="spike_factor"):
        GuardConfig(spike_factor=0.5)


# -------------------------------------------------- checkpoint integrity


def _tree(seed=0):
    r = np.random.RandomState(seed)
    return {
        "params": {"w": r.randn(16, 8).astype(np.float32)},
        "step": np.int64(seed),
    }


def test_checkpoint_crc_and_manifest_detect_corruption(tmp_path):
    for mode in ("bitflip", "truncate"):
        path = str(tmp_path / f"{mode}.npz")
        save_pytree(path, _tree(3))
        from repro.checkpoint.store import write_manifest

        write_manifest(path)
        assert verify_checkpoint(path, deep=True)
        corrupt_file(path, mode=mode)
        assert not verify_checkpoint(path, deep=True)


def test_restore_falls_back_to_newest_valid(tmp_path, moe):
    cfg, model = moe
    d = str(tmp_path / "ck")
    mgr = CheckpointManager(d, keep=4)
    trees = {s: _tree(s) for s in (1, 2, 3)}
    for s in (1, 2, 3):
        mgr.save(s, trees[s])
    corrupt_file(os.path.join(d, "step_3.npz"), mode="bitflip")
    step, tree = mgr.restore()
    assert step == 2 and _bitwise_equal(tree, trees[2])
    # explicit step never silently falls back
    with pytest.raises(CheckpointCorruptError):
        mgr.restore(step=3)
    # all corrupt -> a clear error, not a misload
    corrupt_file(os.path.join(d, "step_2.npz"), mode="truncate")
    corrupt_file(os.path.join(d, "step_1.npz"), mode="truncate")
    with pytest.raises(CheckpointCorruptError, match="no valid checkpoint"):
        mgr.restore()


def test_gc_keeps_last_k_valid(tmp_path):
    d = str(tmp_path / "ck")
    mgr = CheckpointManager(d, keep=2)
    mgr.save(1, _tree(1))
    mgr.save(2, _tree(2))
    corrupt_file(os.path.join(d, "step_2.npz"), mode="bitflip")
    mgr.save(3, _tree(3))  # gc runs: corrupt 2 must not count as kept
    assert checkpoint_steps(d) == [1, 2, 3]  # 1 still kept (2nd VALID)
    mgr.save(4, _tree(4))
    steps = checkpoint_steps(d)
    assert 4 in steps and 3 in steps and 1 not in steps


# ------------------------------------------- train-loop guards (tentpole)


N_STEPS = 8


def _train(moe, **kw):
    cfg, model = moe
    kw.setdefault("batches", SyntheticBatchStream(cfg, 4, 32, N_STEPS))
    kw.setdefault("total_steps", N_STEPS)
    return train_loop(model, kw.pop("batches"), lr=1e-3, log_every=0, **kw)


def test_guard_transparent_on_healthy_run(moe):
    s_plain, _ = _train(moe)
    s_guard, log = _train(moe, guard=GuardConfig(policy="skip"))
    assert _bitwise_equal(s_plain, s_guard)
    assert not log.events


def test_nan_skip_preserves_state_bitwise(moe):
    # NaN at step 3 with policy 'skip': state after step 3 == state after
    # step 2 (the in-graph select kept every leaf), and the run completes
    faults = FaultPlan([parse_fault("nan_grad@step=3")])
    state, log = _train(moe, guard=GuardConfig(policy="skip"), faults=faults)
    nonfinite = [e for e in log.events if e["kind"] == "nonfinite"]
    assert [e["step"] for e in nonfinite] == [3]
    assert np.isnan(nonfinite[0]["loss"])  # the poisoned TOTAL loss
    # the logged ce_loss stays finite: the injection rides the
    # differentiated scalar (hence the grads), not the forward metrics
    assert np.all(np.isfinite(log.losses))
    assert all(np.all(np.isfinite(x)) for x in _leaves(state))


def test_rollback_recovery_is_bit_identical(moe, tmp_path):
    """The tentpole invariant: NaN at step k -> rollback to the last
    checkpoint -> replay with k force-skipped is BIT-IDENTICAL to an
    uninterrupted run that skipped k in place (same faults, policy skip).
    """
    spec = "nan_grad@step=5"
    s_skip, log_a = _train(
        moe, guard=GuardConfig(policy="skip"),
        faults=FaultPlan([parse_fault(spec)]),
    )
    s_rb, log_b = _train(
        moe, guard=GuardConfig(policy="rollback"),
        faults=FaultPlan([parse_fault(spec)]),
        ckpt_dir=str(tmp_path / "rb"), ckpt_every=2, async_ckpt=False,
    )
    kinds = [e["kind"] for e in log_b.events]
    assert "rollback" in kinds and "forced_skip" in kinds
    assert _bitwise_equal(s_skip, s_rb)
    # the replayed per-step (finite ce) losses match the skip run at EVERY
    # index — the poisoned step's forward runs identically in both, its
    # update is dropped in both
    assert log_a.losses == log_b.losses


def test_sigterm_triggers_final_sync_checkpoint(moe, tmp_path):
    cfg, model = moe

    class KillAt:
        """Raise SIGTERM in-line just before yielding batch k (the handler
        runs immediately in the main thread, deterministically)."""

        def __init__(self, stream, k):
            self.stream, self.k = stream, k

        def __iter__(self):
            for i, b in enumerate(iter(self.stream)):
                if i == self.k:
                    signal.raise_signal(signal.SIGTERM)
                yield b

        def state_dict(self):
            return self.stream.state_dict()

        def load_state_dict(self, s):
            self.stream.load_state_dict(s)

    prev = signal.getsignal(signal.SIGTERM)
    d = str(tmp_path / "sig")
    state, log = train_loop(
        model, KillAt(SyntheticBatchStream(cfg, 4, 32, 20), 4),
        lr=1e-3, total_steps=20, log_every=0, ckpt_dir=d, ckpt_every=50,
    )
    assert signal.getsignal(signal.SIGTERM) is prev  # handler restored
    assert any(e["kind"] == "sigterm_checkpoint" for e in log.events)
    assert len(log.losses) == 5  # stopped right after the signal's step
    assert latest_step(d) == 5  # durable synchronous save
    _, tree = CheckpointManager(d).restore()
    assert _bitwise_equal(tree["params"], state.params)


def test_corrupt_checkpoint_resume_falls_back_and_replays(moe, tmp_path):
    cfg, model = moe
    d = str(tmp_path / "cc")
    faults = FaultPlan([parse_fault("ckpt_corrupt@step=2,mode=bitflip")])
    train_loop(model, SyntheticBatchStream(cfg, 4, 32, 6), lr=1e-3,
               total_steps=6, log_every=0, ckpt_dir=d, ckpt_every=2,
               async_ckpt=False, faults=faults)
    assert checkpoint_steps(d) == [2, 4, 6]  # newest (3rd save) is corrupt
    with pytest.warns(UserWarning, match="falling back"):
        _, log = train_loop(model, SyntheticBatchStream(cfg, 4, 32, 8),
                            lr=1e-3, total_steps=8, log_every=0,
                            ckpt_dir=d, ckpt_every=100, resume=True)
    assert len(log.losses) == 4  # resumed from valid step 4, ran 4..7


# ------------------------------------------------------------ data plane


def test_loader_retries_flaky_io_bit_exactly(tok):
    shards = resolve_shards(CORPUS)
    clean = list(itertools.islice(
        iter(ShardedTextLoader(shards, tok, batch_size=4, seq_len=32, seed=5)), 5
    ))
    fault = FlakyOpen(p=0.4, p_read=0.2, max_consecutive=2, seed=7)
    flaky = ShardedTextLoader(
        shards, tok, batch_size=4, seq_len=32, seed=5,
        io_retries=3, io_backoff=0.0, open_fn=fault,
    )
    got = list(itertools.islice(iter(flaky), 5))
    for a, b in zip(clean, got):
        for k in a:
            assert np.array_equal(a[k], b[k])
    sd = flaky.state_dict()
    assert sd["io_retries"] == fault.n_open_failures + fault.n_read_failures > 0


def test_loader_raises_after_retry_budget(tok):
    always = FlakyOpen(p=1.0, max_consecutive=10**9)
    loader = ShardedTextLoader(
        resolve_shards(CORPUS), tok, batch_size=4, seq_len=32,
        io_retries=2, io_backoff=0.0, open_fn=always,
    )
    with pytest.raises(OSError, match="injected"):
        next(iter(loader))
    assert always.n_open_failures == 3  # initial try + 2 retries


def test_loader_skips_undecodable_jsonl_rank_consistently(tok, tmp_path):
    p = str(tmp_path / "s.jsonl")
    with open(p, "w") as f:
        for i in range(40):
            if i in (5, 17):
                f.write("{not json}\n")
            else:
                f.write(json.dumps({"text": f"document number {i} " * 6}) + "\n")
    mk = lambda r, w: ShardedTextLoader(
        [p], tok, batch_size=2, seq_len=32, seed=1, epochs=1, rank=r, world_size=w
    )
    single = mk(0, 1)
    n_single = sum(len(b["tokens"]) for b in single)
    assert single.state_dict()["skipped_lines"] == 2
    # two ranks together see the same documents; the bad lines consume a
    # document index everywhere, so sharding stays aligned
    n_pair, skipped = 0, 0
    for r in (0, 1):
        l = mk(r, 2)
        n_pair += sum(len(b["tokens"]) for b in l)
        skipped += l.state_dict()["skipped_lines"]
    assert skipped == 2
    assert abs(n_pair - n_single) <= 2  # per-rank batch remainder only


def test_prefetch_producer_crash_retries_within_budget(tok):
    shards = resolve_shards(CORPUS)
    mk = lambda: ShardedTextLoader(shards, tok, batch_size=4, seq_len=32, seed=5)
    clean = list(itertools.islice(iter(mk()), 5))
    pf = Prefetcher(FlakyStream(at="1,3").wrap(mk()), depth=2, retries=2)
    got = list(itertools.islice(iter(pf), 5))
    pf.close()
    assert pf.n_producer_retries == 2
    for a, b in zip(clean, got):
        for k in a:
            assert np.array_equal(a[k], b[k])
    # budget exhausted -> the error surfaces on next()
    pf2 = Prefetcher(FlakyStream(at="1").wrap(mk()), depth=2, retries=0)
    with pytest.raises(OSError, match="injected"):
        list(itertools.islice(iter(pf2), 5))
    pf2.close()


def test_prefetch_next_after_close_raises(tok):
    """Regression: next() on an iterator that outlived close() must raise
    a clear RuntimeError, not block forever on the drained queue."""
    loader = ShardedTextLoader(
        resolve_shards(CORPUS), tok, batch_size=4, seq_len=32, seed=5
    )
    pf = Prefetcher(loader, depth=2)
    it = iter(pf)
    first = next(it)
    pf.close()
    with pytest.raises(RuntimeError, match="closed"):
        next(it)
    # a FRESH __iter__ re-arms the producer and continues from the cursor
    nxt = next(iter(pf))
    assert not np.array_equal(first["tokens"], nxt["tokens"])
    pf.close()


# --------------------------------------------------------------- serving


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


@pytest.fixture(scope="module")
def serve_setup():
    cfg = configs.reduced_for_smoke("minimind_moe_16e", vocab_size=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_deadline_eviction_and_queue_expiry(serve_setup):
    from repro.serving.engine import ContinuousBatchingEngine

    cfg, model, params = serve_setup
    clk = FakeClock()
    eng = ContinuousBatchingEngine(
        model, params, n_slots=2, chunk_size=8, max_seq_len=64,
        default_deadline=2.5, clock=clk,
    )
    reqs = [eng.submit(list(range(1, 6)), 20, ignore_eos=True) for _ in range(4)]
    assert all(r is not None for r in reqs)
    done = []
    for _ in range(20):
        done += eng.step()
        clk.t += 1.0
        if not eng.scheduler.has_work:
            break
    assert not eng.scheduler.has_work  # never wedges
    assert len(done) == 4  # every request reported exactly once
    reasons = {r.req_id: r.finish_reason for r in done}
    # queued pair never admitted before t=2.5 -> 'expired'; the admitted
    # pair needs 20 decode steps it will never get -> evicted 'deadline'
    assert sorted(reasons.values()) == ["deadline", "deadline", "expired", "expired"]
    assert eng.n_deadline_missed == 4
    for r in done:
        assert r.phase == "done" and r.t_done is not None


def test_queue_timeout_drops_stale_waiters(serve_setup):
    from repro.serving.engine import ContinuousBatchingEngine

    cfg, model, params = serve_setup
    clk = FakeClock()
    eng = ContinuousBatchingEngine(
        model, params, n_slots=1, chunk_size=8, max_seq_len=64,
        queue_timeout=1.5, clock=clk,
    )
    first = eng.submit([1, 2, 3], 30, ignore_eos=True)  # hogs the only slot
    waiter = eng.submit([4, 5, 6], 4, ignore_eos=True)
    done = []
    for _ in range(6):
        done += eng.step()
        clk.t += 1.0
    assert waiter.finish_reason == "timeout"
    assert eng.n_shed == 1
    assert first.phase != "done" or first.finish_reason not in ("timeout",)


def test_shed_on_full_drops_oldest_first(serve_setup):
    from repro.serving.engine import ContinuousBatchingEngine

    cfg, model, params = serve_setup
    clk = FakeClock()
    eng = ContinuousBatchingEngine(
        model, params, n_slots=1, chunk_size=8, max_seq_len=64,
        max_waiting=2, shed_on_full=True, clock=clk,
    )
    reqs = [eng.submit([1, 2, 3], 4, ignore_eos=True) for _ in range(4)]
    assert all(r is not None for r in reqs)  # shed_on_full never refuses
    done = []
    while eng.scheduler.has_work:
        done += eng.step()
        clk.t += 0.1
    shed = [r for r in done if r.finish_reason == "shed"]
    # no step interleaved the 4 submits: the queue (cap 2) sheds its two
    # oldest waiters, oldest first
    assert [r.req_id for r in shed] == [reqs[0].req_id, reqs[1].req_id]
    assert eng.n_shed == 2 and len(done) == 4
    survivors = {r.finish_reason for r in done if r.finish_reason != "shed"}
    assert survivors == {"max_new_tokens"}


# ---------------------------------------------------------------- router


def test_router_dual_watchdog_resets_poisoned_state():
    cfg = RouterConfig(
        n_experts=8, top_k=2, strategy="bip", sync="global",
        forecast=True, guard_duals=True,
    )
    st = init_router_state(cfg)
    logits = jnp.asarray(np.random.RandomState(0).randn(32, 8), jnp.float32)
    healthy = route(logits, st, cfg)

    for poison in (
        {"q": jnp.full((8,), jnp.nan)},
        {"q": jnp.full((8,), 1e6)},   # runaway magnitude
        {"q_err": jnp.full((8,), jnp.inf)},  # coupled forecaster state
    ):
        bad = dict(st)
        bad.update({k: v.astype(cfg.router_dtype) for k, v in poison.items()})
        out = route(logits, bad, cfg)
        for k, v in out.state.items():
            assert np.all(np.isfinite(np.asarray(v))), k
        # reset-to-safe-init == the fresh-layer trajectory, bit for bit
        assert np.array_equal(np.asarray(out.state["q"]),
                              np.asarray(healthy.state["q"]))

    # transparent on healthy carries: watchdog off == watchdog on
    cfg_off = RouterConfig(
        n_experts=8, top_k=2, strategy="bip", sync="global", forecast=True,
    )
    ref = route(logits, st, cfg_off)
    for k in ref.state:
        assert np.array_equal(np.asarray(ref.state[k]),
                              np.asarray(healthy.state[k])), k
    with pytest.raises(ValueError, match="dual_abs_limit"):
        RouterConfig(n_experts=8, top_k=2, dual_abs_limit=0.0)
