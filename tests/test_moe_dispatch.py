"""Parity suite: sort-based ragged dispatch vs the one-hot/cumsum oracle.

The hot path (core.router.make_dispatch_plan + DispatchPlan.pack/combine)
must reproduce the historical `_dispatch_plan` semantics bit-for-bit:
capacity overflow order (earlier tokens win, slot-major within a token),
token_mask exclusion (padding never occupies capacity), and identical
packed buffers / combined outputs on every moe_ffn path. The Pallas
grouped-FFN custom_vjp must match einsum autodiff to fp32 tolerance.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.core import route
from repro.core.router import make_dispatch_plan
from repro.models import moe


def _random_idx(n, m, k, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        np.stack([rng.choice(m, size=k, replace=False) for _ in range(n)]),
        jnp.int32,
    )


# ------------------------------------------------------------ plan parity


@given(
    n=st.integers(4, 300),
    m=st.sampled_from([2, 4, 8, 16, 64]),
    k=st.integers(1, 4),
    cap=st.integers(1, 64),
    masked=st.sampled_from([False, True]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_plan_bit_matches_reference(n, m, k, cap, masked, seed):
    k = min(k, m)
    idx = _random_idx(n, m, k, seed)
    mask = (
        jnp.asarray(np.random.default_rng(seed + 1).random(n) < 0.6)
        if masked
        else None
    )
    pos_ref, keep_ref = moe._dispatch_plan(idx, m, cap, mask)
    plan = make_dispatch_plan(idx, m, cap, mask)
    keep_ref, pos_ref = np.asarray(keep_ref), np.asarray(pos_ref)
    keep, pos = np.asarray(plan.keep), np.asarray(plan.pos)
    np.testing.assert_array_equal(keep, keep_ref)
    # positions only matter (and are only defined) for kept slots
    np.testing.assert_array_equal(pos[keep], pos_ref[keep_ref])
    # segment counts == one-hot totals over unmasked rows
    sel = np.asarray(idx)[np.asarray(mask)] if masked else np.asarray(idx)
    counts_ref = np.bincount(sel.reshape(-1), minlength=m)
    np.testing.assert_array_equal(np.asarray(plan.counts), counts_ref)


@given(
    n=st.integers(8, 200),
    m=st.sampled_from([4, 8, 16]),
    k=st.integers(1, 4),
    cap=st.integers(2, 48),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_pack_combine_match_scatter_gather_reference(n, m, k, cap, seed):
    """Packed buffers and combined outputs must equal the seed formulation
    (repeat + scatter-add pack, clamped-index gather combine) bitwise."""
    k = min(k, m)
    d = 16
    idx = _random_idx(n, m, k, seed)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    w = jnp.asarray(rng.random((n, k)), jnp.float32)

    pos, keep = moe._dispatch_plan(idx, m, cap)
    e_flat = idx.reshape(-1)
    pos_flat, keep_flat = pos.reshape(-1), keep.reshape(-1)
    src = jnp.repeat(x, k, axis=0) * keep_flat[:, None]
    buf_ref = jnp.zeros((m, cap, d), x.dtype)
    buf_ref = buf_ref.at[e_flat, jnp.where(keep_flat, pos_flat, 0)].add(
        jnp.where(keep_flat[:, None], src, 0.0)
    )

    plan = make_dispatch_plan(idx, m, cap)
    buf = plan.pack(x)
    np.testing.assert_array_equal(np.asarray(buf), np.asarray(buf_ref))

    y = jnp.asarray(rng.standard_normal((m, cap, d)), jnp.float32)
    gathered = y[e_flat, jnp.where(keep_flat, pos_flat, 0)]
    contrib = jnp.where(keep_flat[:, None], gathered * w.reshape(-1, 1), 0.0)
    out_ref = contrib.reshape(n, k, d).sum(axis=1)
    np.testing.assert_array_equal(
        np.asarray(plan.combine(y, w)), np.asarray(out_ref)
    )


def test_token_mask_padding_never_occupies_capacity():
    """A padded batch must pack the very same buffers as the real rows
    alone — masked rows neither claim capacity nor displace real tokens."""
    n, m, k, cap, d = 64, 8, 2, 9, 12
    idx = _random_idx(n, m, k, 0)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    mask = jnp.asarray(rng.random(n) < 0.5)

    plan_pad = make_dispatch_plan(idx, m, cap, mask)
    buf_pad = plan_pad.pack(x)

    sel = np.asarray(mask)
    plan_real = make_dispatch_plan(idx[sel], m, cap)
    buf_real = plan_real.pack(x[sel])
    np.testing.assert_array_equal(np.asarray(buf_pad), np.asarray(buf_real))
    np.testing.assert_array_equal(
        np.asarray(plan_pad.counts), np.asarray(plan_real.counts)
    )
    # masked rows never kept
    assert not np.asarray(plan_pad.keep)[~sel].any()


def test_plan_sharded_pack_covers_all_experts():
    """Packing expert shards with a (traced) offset must tile the full
    buffer: concat of per-shard packs == the global pack."""
    n, m, k, cap, d = 80, 8, 2, 11, 8
    idx = _random_idx(n, m, k, 3)
    x = jnp.asarray(np.random.default_rng(3).standard_normal((n, d)), jnp.float32)
    plan = make_dispatch_plan(idx, m, cap)
    whole = plan.pack(x)
    for m_loc in (2, 4):
        shards = [
            plan.pack(x, expert_offset=off, n_local=m_loc)
            for off in range(0, m, m_loc)
        ]
        np.testing.assert_array_equal(
            np.asarray(jnp.concatenate(shards, axis=0)), np.asarray(whole)
        )
        # combine restricted to each shard sums back to the full combine
        w = jnp.ones((n, k), jnp.float32)
        parts = [
            plan.combine(whole[off : off + m_loc], w, expert_offset=off)
            for off in range(0, m, m_loc)
        ]
        np.testing.assert_allclose(
            np.asarray(sum(parts)), np.asarray(plan.combine(whole, w)), atol=1e-6
        )


# ------------------------------------------------- moe_ffn path parity


def _old_local_reference(params, x, router_state, cfg, token_mask=None):
    """The seed moe_ffn_local: one-hot plan, repeat+scatter pack, gather
    combine, einsum FFN. Frozen here as the parity oracle."""
    n, d = x.shape
    m = cfg.routing.n_experts
    cap = moe.expert_capacity(n, cfg)
    rcfg = moe.router_config(cfg)
    logits = jnp.einsum("nd,dm->nm", x.astype(jnp.float32), params["w_router"])
    out = route(logits, router_state, rcfg, token_mask=token_mask)
    pos, keep = moe._dispatch_plan(out.expert_index, m, cap, token_mask)
    e_flat = out.expert_index.reshape(-1)
    pos_flat, keep_flat = pos.reshape(-1), keep.reshape(-1)
    src = jnp.repeat(x, cfg.routing.top_k, axis=0) * keep_flat[:, None]
    buf = jnp.zeros((m, cap, d), x.dtype)
    buf = buf.at[e_flat, jnp.where(keep_flat, pos_flat, 0)].add(
        jnp.where(keep_flat[:, None], src, 0.0)
    )
    dt = cfg.compute_dtype
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(dt))
    y = jnp.einsum("ecf,efd->ecd", act(g) * u, params["w_down"].astype(dt))
    gathered = y[e_flat, jnp.where(keep_flat, pos_flat, 0)]
    w_flat = out.combine_weights.reshape(-1, 1).astype(y.dtype)
    contrib = jnp.where(keep_flat[:, None], gathered * w_flat, 0.0)
    return contrib.reshape(n, cfg.routing.top_k, d).sum(axis=1), out.state


@pytest.mark.parametrize("masked", [False, True])
def test_moe_ffn_local_matches_seed_reference(masked):
    cfg = configs.reduced_for_smoke("minimind_moe_16e", vocab_size=128)
    rng = np.random.default_rng(1)
    n = 96
    params = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((n, cfg.d_model)), jnp.float32)
    state = {"q": jnp.zeros((cfg.routing.n_experts,), jnp.float32)}
    mask = jnp.asarray(rng.random(n) < 0.6) if masked else None
    y_new, st_new, _, _ = moe.moe_ffn_local(params, x, state, cfg, token_mask=mask)
    y_ref, st_ref = _old_local_reference(params, x, state, cfg, token_mask=mask)
    np.testing.assert_array_equal(np.asarray(y_new), np.asarray(y_ref))
    np.testing.assert_array_equal(np.asarray(st_new["q"]), np.asarray(st_ref["q"]))


def test_moe_ffn_ep_paths_match_local():
    """All three expert-parallel paths must reproduce the (new, sort-based)
    local path on a forced 8-device host — forward values and the psum'd
    load metrics. strategy='topk' + capacity_factor=4 + f32 compute for the
    same reasons as tests/test_distributed.py: it isolates the sharded
    dispatch/combine math from per-shard BIP duals and capacity rounding."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.configs.base import ModelConfig, RoutingSpec
from repro.core.types import init_router_state
from repro.models import moe

cfg = ModelConfig(n_layers=2, d_model=64, d_ff=128, compute_dtype=jnp.float32,
                  routing=RoutingSpec(n_experts=8, top_k=2, strategy="topk",
                                      capacity_factor=4.0),
                  moe_d_ff=96)
params = moe.init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (256, 64))
state = init_router_state(moe.router_config(cfg))

y0, s0, _, m0 = moe.moe_ffn_local(params, x, state, cfg)

mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
for fn in [moe.moe_ffn_ep, moe.moe_ffn_ep2d, moe.moe_ffn_ep2ds]:
    with mesh:
        y1, s1, _, m1 = jax.jit(
            lambda p, xv: fn(p, xv, state, cfg, mesh,
                             data_axes=("data",), model_axis="model")
        )(params, xs)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(jax.device_get(y1)),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(m0["load"]), np.asarray(jax.device_get(m1["load"])),
                               atol=1e-5)
print("OK")
"""
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])


# ---------------------------------------------- Pallas FFN on the hot path


def test_use_kernel_matches_einsum_same_routing():
    """With routing frozen to topk (so use_kernel flips only the FFN impl),
    the Pallas grouped FFN must match the einsum path — values and grads."""
    cfg = configs.reduced_for_smoke("minimind_moe_16e", vocab_size=128)
    cfg = dataclasses.replace(
        cfg, routing=dataclasses.replace(cfg.routing, strategy="topk")
    )
    cfg_k = dataclasses.replace(
        cfg, routing=dataclasses.replace(cfg.routing, use_kernel=True)
    )
    rng = np.random.default_rng(2)
    n = 96
    params = moe.init_moe(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(rng.standard_normal((n, cfg.d_model)), jnp.float32)
    state = {"q": jnp.zeros((cfg.routing.n_experts,), jnp.float32)}

    def loss(p, c):
        y, *_ = moe.moe_ffn_local(p, x, state, c)
        return jnp.sum(y**2)

    np.testing.assert_allclose(
        float(loss(params, cfg)), float(loss(params, cfg_k)), rtol=1e-5
    )
    g0 = jax.grad(lambda p: loss(p, cfg))(params)
    g1 = jax.grad(lambda p: loss(p, cfg_k))(params)
    for key in params:
        np.testing.assert_allclose(
            np.asarray(g0[key]), np.asarray(g1[key]), atol=2e-4, rtol=2e-4
        )


def test_train_step_through_pallas_ffn_grads_match():
    """Acceptance: a minimind-moe-16e training step with use_kernel=True runs
    through the Pallas grouped FFN (interpret mode here) and its grads match
    the einsum FFN at identical (kernel-ADMM) routing to fp32 tolerance."""
    from repro.data import make_batches
    from repro.kernels import ops as kernel_ops
    from repro.kernels import ref as kernel_ref
    from repro.models import build_model

    cfg = configs.reduced_for_smoke("minimind_moe_16e", vocab_size=128)
    cfg = dataclasses.replace(
        cfg, routing=dataclasses.replace(cfg.routing, use_kernel=True)
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    states = model.init_router_states()
    batch = next(iter(make_batches(cfg, 2, 32, 1, seed=0)))

    def grads():
        (loss, _), g = jax.value_and_grad(model.loss_fn, has_aux=True)(
            params, batch, states
        )
        return float(loss), g

    loss_k, g_k = grads()
    assert np.isfinite(loss_k)

    # same routing (the ADMM kernel still runs), einsum in place of the
    # Pallas FFN pair: grads must agree to fp32 tolerance
    orig = kernel_ops.expert_ffn
    kernel_ops.expert_ffn = lambda x, wg, wu, wd, **kw: kernel_ref.expert_ffn_ref(
        x, wg, wu, wd
    )
    try:
        loss_e, g_e = grads()
    finally:
        kernel_ops.expert_ffn = orig
    np.testing.assert_allclose(loss_k, loss_e, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g_k), jax.tree.leaves(g_e)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=2e-4, rtol=2e-4
        )


def test_serving_engine_use_kernel_override():
    """The engine's use_kernel override serves end-to-end through the Pallas
    FFN + masked dispatch plan and still produces the full token budget."""
    from repro.models import build_model
    from repro.serving import ContinuousBatchingEngine

    cfg = configs.reduced_for_smoke("minimind_moe_16e", vocab_size=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ContinuousBatchingEngine(
        model, params, n_slots=2, chunk_size=8, max_seq_len=64, use_kernel=True
    )
    assert eng.model.cfg.routing.use_kernel
    rng = np.random.default_rng(0)
    reqs = [
        eng.submit(rng.integers(0, 128, (5,)), 4, ignore_eos=True)
        for _ in range(3)
    ]
    assert all(r is not None for r in reqs)
    done = eng.run()
    assert len(done) == 3 and all(len(r.output) == 4 for r in done)
