"""Shared subprocess runner for multi-device tests.

XLA locks the host device count per process, so every test that needs a
forced N-device CPU "mesh" runs its body in a fresh subprocess with
XLA_FLAGS set before jax imports. One copy of the runner + prelude lives
here; test_distributed.py, test_train_sharded.py, and the sharded arch
smokes all use it.
"""
from __future__ import annotations

import os
import subprocess
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# imports shared by every forced-device script; jax must come after the env
PRELUDE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np, dataclasses
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
"""


def run_code(code: str, timeout: int = 900) -> str:
    """Run `code` in a subprocess from the repo root; assert success."""
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        cwd=REPO_ROOT,
        timeout=timeout,
    )
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    return out.stdout
