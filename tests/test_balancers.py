"""Balancer-registry refactor suite.

The pluggable-balancer API (core/balancers.py) must be a pure refactor for
the four paper strategies: `route()` through the registry produces
BITWISE-identical RouterOutput fields and state trajectories to the frozen
pre-refactor implementation (tests/_legacy_router.py) — including masked
serving rows, guard_duals + forecast state, local_shards vmapping, and
sync='global' on a forced 4x2 host mesh. On top of that: smokes for the
registry additions (phi / lpr / expert_choice), checkpoint-resume
bit-exactness for lpr's 2-D prototype leaves, registry error messages, and
the expert-choice serving/decode rejection.
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, "tests")
from _forced_devices import PRELUDE, run_code
from _legacy_router import legacy_route

from repro.core import (
    RouterConfig,
    get_balancer,
    init_router_state,
    registered_balancers,
    route,
)

LEGACY = ("topk", "aux_loss", "lossfree", "bip")
N, M, K = 64, 16, 4


def _logits_stream(seed, steps, n=N, m=M):
    rng = np.random.default_rng(seed)
    # mild expert-popularity skew so balancing methods have work to do
    skew = np.linspace(1.0, -1.0, m)[None, :]
    return [
        jnp.asarray(rng.standard_normal((n, m)) + skew, jnp.float32)
        for _ in range(steps)
    ]


def _assert_trajectory_parity(cfg, steps=5, token_mask=None, local_shards=1):
    st_new = init_router_state(cfg)
    st_old = dict(st_new)
    seed = sum(ord(c) for c in cfg.strategy)
    for t, logits in enumerate(_logits_stream(seed, steps)):
        o_new = route(
            logits, st_new, cfg, token_mask=token_mask, local_shards=local_shards
        )
        o_old = legacy_route(
            logits, st_old, cfg, token_mask=token_mask, local_shards=local_shards
        )
        np.testing.assert_array_equal(
            np.asarray(o_new.combine_weights), np.asarray(o_old.combine_weights)
        )
        np.testing.assert_array_equal(
            np.asarray(o_new.expert_index), np.asarray(o_old.expert_index)
        )
        np.testing.assert_array_equal(
            np.asarray(o_new.aux_loss), np.asarray(o_old.aux_loss)
        )
        assert set(o_new.state) == set(o_old.state)
        for key in o_new.state:
            np.testing.assert_array_equal(
                np.asarray(o_new.state[key]),
                np.asarray(o_old.state[key]),
                err_msg=f"strategy={cfg.strategy} step={t} state[{key!r}]",
            )
        st_new, st_old = o_new.state, o_old.state


@pytest.mark.parametrize("strategy", LEGACY)
def test_registry_parity_plain(strategy):
    _assert_trajectory_parity(RouterConfig(n_experts=M, top_k=K, strategy=strategy))


@pytest.mark.parametrize("strategy", LEGACY)
def test_registry_parity_masked_serving_rows(strategy):
    mask = jnp.asarray(np.random.default_rng(7).random(N) > 0.4)
    _assert_trajectory_parity(
        RouterConfig(n_experts=M, top_k=K, strategy=strategy), token_mask=mask
    )


@pytest.mark.parametrize("strategy", LEGACY)
def test_registry_parity_guard_duals(strategy):
    _assert_trajectory_parity(
        RouterConfig(n_experts=M, top_k=K, strategy=strategy, guard_duals=True)
    )


@pytest.mark.parametrize("strategy", LEGACY)
def test_registry_parity_global_singledevice(strategy):
    # sync='global' with no mesh: the threshold/bisection solver for bip,
    # degenerate (empty-axis) psums for lossfree
    _assert_trajectory_parity(
        RouterConfig(n_experts=M, top_k=K, strategy=strategy, sync="global")
    )


def test_registry_parity_bip_forecast_guard():
    _assert_trajectory_parity(
        RouterConfig(
            n_experts=M, top_k=K, strategy="bip",
            sync="global", forecast=True, guard_duals=True,
        ),
        steps=6,
    )


def test_registry_parity_bip_no_warm_start_and_local_shards():
    _assert_trajectory_parity(
        RouterConfig(n_experts=M, top_k=K, strategy="bip", bip_warm_start=False)
    )
    _assert_trajectory_parity(
        RouterConfig(n_experts=M, top_k=K, strategy="bip"), local_shards=4
    )


def test_registry_parity_norm_topk_sigmoid():
    _assert_trajectory_parity(
        RouterConfig(
            n_experts=M, top_k=K, strategy="bip",
            norm_topk_prob=True, score_fn="sigmoid",
        )
    )


def test_registry_parity_global_mesh_4x2():
    """Bitwise parity of route() vs the frozen legacy router under
    shard_map on a forced 4x2 mesh, sync='global' (psum'd dual stats /
    selection histograms over the data axis), 3-step state trajectories."""
    run_code(
        PRELUDE
        + r"""
sys.path.insert(0, "tests")
from repro.core import RouterConfig, init_router_state, route
from repro.models.moe import _shard_map
from _legacy_router import legacy_route

n, m, k = 64, 16, 4
mesh = Mesh(np.array(jax.devices()[:8]).reshape(4, 2), ("data", "model"))
for strategy in ("topk", "aux_loss", "lossfree", "bip", "bip_forecast"):
    forecast = strategy == "bip_forecast"
    cfg = RouterConfig(
        n_experts=m, top_k=k,
        strategy="bip" if forecast else strategy,
        sync="global", data_axes=("data",),
        forecast=forecast, guard_duals=True,
    )

    def pair(logits, st_new, st_old):
        o_new = route(logits, st_new, cfg)
        o_old = legacy_route(logits, st_old, cfg)
        return (
            (o_new.combine_weights, o_new.expert_index, o_new.aux_loss,
             o_new.state),
            (o_old.combine_weights, o_old.expert_index, o_old.aux_loss,
             o_old.state),
        )

    st = init_router_state(cfg)
    state_spec = jax.tree.map(lambda _: P(), st)
    fn = jax.jit(_shard_map(
        pair, mesh=mesh,
        in_specs=(P("data", None), state_spec, state_spec),
        out_specs=((P("data", None), P("data", None), P(), state_spec),) * 2,
        check_vma=False,
    ))
    st_new, st_old = st, dict(st)
    rng = np.random.default_rng(3)
    for t in range(3):
        logits = jnp.asarray(rng.standard_normal((n, m)), jnp.float32)
        (w_n, i_n, a_n, st_new), (w_o, i_o, a_o, st_old) = fn(
            logits, st_new, st_old
        )
        for a, b in ((w_n, w_o), (i_n, i_o), (a_n, a_o)):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (strategy, t)
        for key in st_new:
            assert np.array_equal(
                np.asarray(st_new[key]), np.asarray(st_old[key])
            ), (strategy, t, key)
print("mesh parity ok")
"""
    )


# ------------------------------------------------------------ new methods


def test_registry_lists_all_methods():
    assert set(registered_balancers()) >= {
        "topk", "aux_loss", "lossfree", "bip", "phi", "lpr", "expert_choice"
    }


@pytest.mark.parametrize("strategy", ["phi", "lpr", "expert_choice"])
def test_new_method_smoke(strategy):
    cfg = RouterConfig(n_experts=M, top_k=K, strategy=strategy)
    st = init_router_state(cfg)
    for logits in _logits_stream(11, 6):
        out = route(logits, st, cfg)
        st = out.state
        assert np.isfinite(np.asarray(out.combine_weights)).all()
        assert np.isfinite(float(out.metrics["max_vio"]))
        idx = np.asarray(out.expert_index)
        if strategy == "expert_choice":
            # sentinel slots allowed (uncovered tokens), never beyond m
            assert idx.max() <= M and float(out.metrics["max_vio"]) <= 0.25
            assert {"coverage_full", "coverage_zero"} <= set(out.metrics)
        else:
            assert idx.max() < M
    if strategy == "phi":
        # recentred log-correction: mean(phi) == 0 up to float error
        assert abs(float(np.asarray(st["q"]).mean())) < 1e-6
    if strategy == "lpr":
        assert st["proto"].shape == (M, M)


def test_phi_balances_skewed_stream_better_than_topk():
    vios = {}
    for strategy in ("topk", "phi"):
        cfg = RouterConfig(n_experts=M, top_k=K, strategy=strategy, phi_lr=0.05)
        st = init_router_state(cfg)
        last = None
        for logits in _logits_stream(5, 20):
            out = route(logits, st, cfg)
            st, last = out.state, float(out.metrics["max_vio"])
        vios[strategy] = last
    assert vios["phi"] < vios["topk"]


def test_lpr_stack_state_tiles_2d_leaves():
    import dataclasses

    import repro.configs as configs
    from repro.models.stack import init_stack_router_states

    cfg = configs.reduced_for_smoke("minimind_moe_16e")
    cfg = dataclasses.replace(
        cfg, routing=dataclasses.replace(cfg.routing, strategy="lpr")
    )
    states = init_stack_router_states(cfg)
    moe_states = [s for s in states if s is not None]
    assert moe_states, "minimind config must have MoE positions"
    m = cfg.routing.n_experts
    for st in moe_states:
        reps = st["q"].shape[0]
        assert st["q"].shape == (reps, m)
        assert st["proto"].shape == (reps, m, m)
        # every layer starts at the identity prototype
        np.testing.assert_array_equal(
            np.asarray(st["proto"]), np.stack([np.eye(m)] * reps)
        )


def test_lpr_checkpoint_resume_bit_exact(tmp_path):
    """The (m, m) prototype leaf round-trips the npz checkpoint store and a
    resumed trajectory is bitwise-identical to the uninterrupted one."""
    from repro.checkpoint.store import CheckpointManager

    cfg = RouterConfig(n_experts=M, top_k=K, strategy="lpr")
    stream = _logits_stream(23, 6)

    st = init_router_state(cfg)
    uninterrupted = []
    for logits in stream:
        out = route(logits, st, cfg)
        st = out.state
        uninterrupted.append(st)

    store = CheckpointManager(str(tmp_path))
    st = init_router_state(cfg)
    for logits in stream[:3]:
        st = route(logits, st, cfg).state
    store.save(3, st)
    _, restored = store.restore(3)
    for key in st:
        np.testing.assert_array_equal(np.asarray(st[key]), restored[key])
    st = jax.tree.map(jnp.asarray, restored)
    for t, logits in enumerate(stream[3:]):
        st = route(logits, st, cfg).state
        for key in st:
            np.testing.assert_array_equal(
                np.asarray(st[key]),
                np.asarray(uninterrupted[3 + t][key]),
                err_msg=f"resume step {t} state[{key!r}]",
            )


# ----------------------------------------------------- API contract edges


def test_unknown_strategy_error_lists_registered():
    with pytest.raises(ValueError, match="registered:.*bip.*lpr"):
        RouterConfig(n_experts=M, top_k=K, strategy="nope")
    with pytest.raises(ValueError, match="unknown routing strategy"):
        get_balancer("also-nope")


def test_balance_sweep_methods_flag_resolves_registry():
    sys.path.insert(0, ".")
    from benchmarks.balance_sweep import MATRIX_METHODS, _resolve_methods

    assert _resolve_methods(None, ("bip",)) == ("bip",)
    assert _resolve_methods("phi, lpr", ("bip",)) == ("phi", "lpr")
    assert set(MATRIX_METHODS) == set(registered_balancers())
    with pytest.raises(ValueError, match="registered:"):
        _resolve_methods("bip,bogus", ("bip",))


def test_expert_choice_rejects_serving_mask():
    cfg = RouterConfig(n_experts=M, top_k=K, strategy="expert_choice")
    mask = jnp.ones((N,), bool)
    with pytest.raises(NotImplementedError, match="training-only"):
        route(jnp.zeros((N, M)), init_router_state(cfg), cfg, token_mask=mask)


def test_expert_choice_rejects_serving_engine():
    import dataclasses

    import repro.configs as configs
    from repro.models import build_model
    from repro.serving.engine import ContinuousBatchingEngine

    cfg = configs.reduced_for_smoke("minimind_moe_16e")
    cfg = dataclasses.replace(
        cfg, routing=dataclasses.replace(cfg.routing, strategy="expert_choice")
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError, match="training-only"):
        ContinuousBatchingEngine(model, params, n_slots=2, chunk_size=8)


def test_unsupported_combo_warns_once():
    import repro.core.balancers as balancers_mod

    balancers_mod._warned.discard("kernel-unused-lossfree")
    cfg = RouterConfig(n_experts=M, top_k=K, strategy="lossfree", use_kernel=True)
    st = init_router_state(cfg)
    logits = _logits_stream(1, 1)[0]
    with pytest.warns(UserWarning, match="use_kernel.*ignored"):
        route(logits, st, cfg)
    # second call: warn-once
    import warnings as _w

    with _w.catch_warnings():
        _w.simplefilter("error")
        route(logits, st, cfg)


def test_routing_spec_single_validation_path():
    from repro.configs.base import RoutingSpec

    with pytest.raises(ValueError, match="registered:"):
        RoutingSpec(n_experts=8, top_k=2, strategy="bogus")
    # dense default (0 experts) stays inert — no validation crash
    RoutingSpec()
    spec = RoutingSpec(n_experts=8, top_k=2, strategy="lpr", lpr_blend=0.3)
    rcfg = spec.to_router_config(data_axes=("data",))
    assert rcfg.strategy == "lpr"
    assert rcfg.lpr_blend == 0.3
    assert rcfg.data_axes == ("data",)
