"""Streaming data pipeline: tokenizer, packing, loader, prefetch, resume.

Covers the DESIGN.md §Data invariants:
  * tokenizer: lossless byte-level roundtrip, save/load stability
  * packing: no token loss in 'pack' mode (every stream token is a label
    exactly once), EOS boundaries, pad/nocross label masking, segment ids
  * loader: deterministic per (shards, seed); rank striding partitions the
    corpus exactly; mid-shard cursor checkpoint/restore is bit-exact
  * prefetcher: transparent (same batches), resumable, drains cleanly on
    early stop
  * train_loop: real-pipeline resume reproduces the uninterrupted loss /
    MaxVio trajectory bit-exactly (async checkpointing on), O(1) synthetic
    resume, segment-masked attention equals per-document attention
"""
from __future__ import annotations

import itertools
import json
import os

import numpy as np
import pytest

from repro.data.loader import BatchStream, ShardedTextLoader, resolve_shards
from repro.data.packing import SequencePacker, examples_to_batch
from repro.data.prefetch import Prefetcher
from repro.data.tokenizer import ByteBPETokenizer, iter_corpus_texts

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures", "corpus")


@pytest.fixture(scope="module")
def shards():
    return resolve_shards(FIXTURE)


@pytest.fixture(scope="module")
def tok(shards):
    return ByteBPETokenizer.train(iter_corpus_texts(shards), vocab_size=512)


# ------------------------------------------------------------- tokenizer


def test_tokenizer_roundtrip_and_serialization(shards, tok, tmp_path):
    texts = list(iter_corpus_texts(shards))
    assert len(texts) == 180
    for t in texts[:40] + ["", "  spaces  ", "ünïcode — 测试 🙂"]:
        ids = tok.encode(t)
        assert all(0 <= i < tok.vocab_size for i in ids)
        assert tok.decode(ids) == t
    # compression: merges actually fire on in-domain text
    raw = sum(len(t.encode("utf-8")) for t in texts)
    enc = sum(len(tok.encode(t)) for t in texts)
    assert enc < 0.8 * raw
    path = str(tmp_path / "tok.json")
    tok.save(path)
    tok2 = ByteBPETokenizer.load(path)
    assert tok2.vocab_size == tok.vocab_size and tok2.eos_id == tok.eos_id
    assert tok2.encode(texts[0]) == tok.encode(texts[0])


# --------------------------------------------------------------- packing


def _docs(rng, n, lo=3, hi=40):
    return [list(rng.integers(0, 500, size=rng.integers(lo, hi))) for _ in range(n)]


def test_pack_no_token_loss_and_eos_boundaries():
    rng = np.random.default_rng(0)
    docs = _docs(rng, 23)
    L, EOS = 16, 511
    p = SequencePacker(L, EOS, "pack")
    exs = [e for d in docs for e in p.add_document(d)] + p.flush()
    # label multiset == stream (minus its first token): windows overlap by
    # exactly 1, so every stream token is predicted exactly once
    stream = [t for d in docs for t in list(d) + [EOS]]
    labels = np.concatenate([e["window"][1:][e["valid"]] for e in exs])
    assert labels.tolist() == stream[1 : 1 + len(labels)]
    assert len(stream) - len(labels) <= L + 1  # only the tail can pad/drop
    # every document boundary is an EOS in some window
    assert sum(int((e["window"] == EOS).sum()) for e in exs) >= len(docs) - 1


def test_pack_nocross_segments_and_boundary_masking():
    rng = np.random.default_rng(1)
    docs = _docs(rng, 8, lo=4, hi=12)
    L, EOS = 10, 511
    p = SequencePacker(L, EOS, "pack_nocross")
    exs = [e for d in docs for e in p.add_document(d)] + p.flush()
    for e in exs:
        seg = e["segments"]
        assert np.all(np.diff(seg[seg >= 0]) >= 0)  # monotone within window
        # labels crossing a boundary are masked, within-doc labels are not
        crosses = seg[1:] != seg[:-1]
        assert not np.any(e["valid"] & crosses)
    batch = examples_to_batch(exs[:4])
    assert "segments" in batch and batch["segments"].shape == batch["tokens"].shape
    assert np.all(batch["labels"][batch["labels"] >= 0] < 512)


def test_pad_mode_one_doc_per_row():
    EOS = 99
    p = SequencePacker(8, EOS, "pad")
    short = p.add_document([1, 2, 3])[0]
    assert short["window"].tolist() == [1, 2, 3, EOS, EOS, EOS, EOS, EOS, EOS]
    assert short["valid"].tolist() == [True, True, True] + [False] * 5
    long = p.add_document(list(range(1, 20)))[0]
    assert long["window"].tolist() == list(range(1, 10))  # truncated
    assert bool(long["valid"].all())


def test_packer_state_roundtrip():
    rng = np.random.default_rng(2)
    p1 = SequencePacker(12, 511, "pack_nocross")
    p1.add_document(list(rng.integers(0, 500, 30)))
    p2 = SequencePacker(12, 511, "pack_nocross")
    p2.load_state_dict(json.loads(json.dumps(p1.state_dict())))
    d = list(rng.integers(0, 500, 25))
    for a, b in zip(p1.add_document(list(d)), p2.add_document(list(d))):
        assert np.array_equal(a["window"], b["window"])
        assert np.array_equal(a["segments"], b["segments"])


# ---------------------------------------------------------------- loader


def test_loader_deterministic(shards, tok):
    mk = lambda: ShardedTextLoader(shards, tok, batch_size=4, seq_len=32, seed=5)
    for a, b in itertools.islice(zip(iter(mk()), iter(mk())), 8):
        for k in a:
            assert np.array_equal(a[k], b[k])


def test_loader_rank_striding_partitions_corpus(shards, tok):
    def rank_docs(rank, world):
        l = ShardedTextLoader(
            shards, tok, batch_size=1, seq_len=8, rank=rank, world_size=world,
            epochs=1, seed=0,
        )
        docs = []
        while (d := l._next_rank_doc()) is not None:
            docs.append(tuple(d))
        return docs

    all_docs = rank_docs(0, 1)
    assert len(all_docs) == 180
    for world in (2, 3):
        parts = [rank_docs(r, world) for r in range(world)]
        assert sorted(itertools.chain(*parts)) == sorted(all_docs)
        sizes = [len(p) for p in parts]
        assert max(sizes) - min(sizes) <= 1  # even split
        flat = set(itertools.chain(*(map(tuple, p) for p in parts)))
        # disjoint up to duplicate documents in the corpus
        assert len(flat) == len(set(map(tuple, all_docs)))


@pytest.mark.parametrize("mode", ["pack", "pack_nocross"])
def test_loader_cursor_resume_mid_shard_bit_exact(shards, tok, mode):
    mk = lambda seed: ShardedTextLoader(
        shards, tok, batch_size=4, seq_len=32, pack_mode=mode,
        shuffle_buffer=16, seed=seed,
    )
    l1 = mk(9)
    it1 = iter(l1)
    for _ in range(5):
        next(it1)
    snap = json.loads(json.dumps(l1.state_dict()))  # sidecar JSON roundtrip
    assert 0 < snap["file_idx"] or snap["byte_offset"] > 0  # genuinely mid-shard
    ref = [next(it1) for _ in range(7)]
    l2 = mk(12345)  # ctor seed must not matter after restore
    l2.load_state_dict(snap)
    for r, x in zip(ref, iter(l2)):
        for k in r:
            assert np.array_equal(r[k], x[k])


def test_loader_cursor_size_o1_in_shuffle_buffer(shards, tok):
    """The offset-replay cursor must not serialize buffer contents: its
    JSON size must be flat in `shuffle_buffer` (the replay anchor stores
    RNG + counters, not documents)."""
    def cursor_bytes(buf):
        l = ShardedTextLoader(
            shards, tok, batch_size=4, seq_len=32, shuffle_buffer=buf, seed=7
        )
        it = iter(l)
        for _ in range(3):
            next(it)
        return len(json.dumps(l.state_dict()))

    small, big = cursor_bytes(4), cursor_bytes(4096)
    assert big < 2 * small, (small, big)


def test_loader_epochs_reshuffle(shards, tok):
    l = ShardedTextLoader(shards, tok, batch_size=4, seq_len=64, seed=0)
    first = [next(iter(l)) for _ in range(1)][0]
    n_epoch0 = None
    it = iter(l)
    for _ in range(200):
        next(it)
        if l._epoch >= 1 and n_epoch0 is None:
            n_epoch0 = l._batches_emitted
            break
    assert l._epoch >= 1  # looped into a second epoch
    assert first["tokens"].shape == (4, 64)


# ------------------------------------------------------------- prefetcher


def test_prefetcher_transparent_and_resumable(shards, tok):
    mk = lambda: ShardedTextLoader(shards, tok, batch_size=4, seq_len=32, seed=3)
    raw = list(itertools.islice(iter(mk()), 10))
    pf = Prefetcher(mk(), depth=2)
    got = list(itertools.islice(iter(pf), 10))
    pf.close()
    for a, b in zip(raw, got):
        for k in a:
            assert np.array_equal(np.asarray(a[k]), np.asarray(b[k]))
    # resume from the prefetcher's cursor: it must reflect CONSUMED batches
    # only, not the producer's read-ahead
    pf1 = Prefetcher(mk(), depth=2)
    it = iter(pf1)
    for _ in range(4):
        next(it)
    snap = json.loads(json.dumps(pf1.state_dict()))
    pf1.close()
    l2 = mk()
    l2.load_state_dict(snap)
    nxt = next(iter(l2))
    for k in nxt:
        assert np.array_equal(np.asarray(raw[4][k]), np.asarray(nxt[k]))


def test_prefetcher_drains_cleanly_on_early_stop(shards, tok):
    import threading

    before = threading.active_count()
    pf = Prefetcher(
        ShardedTextLoader(shards, tok, batch_size=4, seq_len=32, seed=0), depth=2
    )
    for i, _ in enumerate(iter(pf)):
        if i == 2:
            break  # early stop mid-stream
    pf.close()
    assert pf._thread is None
    assert threading.active_count() == before
    # double-close is a no-op
    pf.close()


def test_prefetcher_propagates_producer_errors():
    class Boom:
        def __iter__(self):
            yield {"tokens": np.zeros((1, 4), np.int32)}
            raise RuntimeError("shard corrupted")

        def state_dict(self):
            return {}

        def load_state_dict(self, s):
            pass

    pf = Prefetcher(Boom(), depth=2, device_put=False)
    it = iter(pf)
    next(it)
    with pytest.raises(RuntimeError, match="shard corrupted"):
        next(it)


# ------------------------------------------------- end-to-end train/resume


def _tiny_model():
    import repro.configs as configs
    from repro.models import build_model

    cfg = configs.reduced_for_smoke(
        "minimind_moe_16e", n_layers=2, d_model=64, d_ff=128, moe_d_ff=64
    )
    return cfg, build_model(cfg)


def test_train_resume_real_pipeline_bit_exact(shards, tok, tmp_path):
    import jax

    from repro.training import train_loop

    cfg, model = _tiny_model()
    mk = lambda: Prefetcher(
        ShardedTextLoader(shards, tok, batch_size=4, seq_len=32,
                          pack_mode="pack", seed=0),
        depth=2,
    )
    _, ref = train_loop(model, mk(), key=jax.random.PRNGKey(0), total_steps=6)
    d = str(tmp_path / "ck")
    train_loop(model, mk(), key=jax.random.PRNGKey(0), total_steps=3,
               ckpt_dir=d, ckpt_every=3)
    assert os.path.exists(os.path.join(d, "step_3.data.json"))
    st, log = train_loop(model, mk(), key=jax.random.PRNGKey(0), total_steps=6,
                         ckpt_dir=d, ckpt_every=100, resume=True)
    assert log.losses == ref.losses[3:]  # bit-exact continuation
    assert [v.tolist() for v in log.max_vio_steps] == [
        v.tolist() for v in ref.max_vio_steps[3:]
    ]


def test_train_resume_synthetic_stream_o1(tmp_path):
    import jax

    from repro.data.synthetic import SyntheticBatchStream, make_batches
    from repro.training import train_loop

    cfg, model = _tiny_model()
    mk = lambda: SyntheticBatchStream(cfg, 4, 32, 6, seed=0)
    # stream == generator batches
    for a, b in zip(iter(mk()), make_batches(cfg, 4, 32, 6, seed=0)):
        for k in a:
            assert np.array_equal(np.asarray(a[k]), np.asarray(b[k]))
    _, ref = train_loop(model, mk(), key=jax.random.PRNGKey(1), total_steps=6)
    d = str(tmp_path / "ck")
    train_loop(model, mk(), key=jax.random.PRNGKey(1), total_steps=3,
               ckpt_dir=d, ckpt_every=3)
    s = mk()
    _, log = train_loop(model, s, key=jax.random.PRNGKey(1), total_steps=6,
                        ckpt_dir=d, ckpt_every=100, resume=True)
    assert log.losses == ref.losses[3:]
    # O(1): the stream was seeked, not replayed from 0
    assert s.state_dict()["step"] == 6


def test_async_checkpoint_matches_blocking(shards, tok, tmp_path):
    import jax

    from repro.checkpoint import CheckpointManager
    from repro.training import train_loop

    cfg, model = _tiny_model()
    mk = lambda: ShardedTextLoader(shards, tok, batch_size=4, seq_len=32, seed=1)
    da, db = str(tmp_path / "async"), str(tmp_path / "block")
    train_loop(model, mk(), key=jax.random.PRNGKey(2), total_steps=4,
               ckpt_dir=da, ckpt_every=2, async_ckpt=True)
    train_loop(model, mk(), key=jax.random.PRNGKey(2), total_steps=4,
               ckpt_dir=db, ckpt_every=2, async_ckpt=False)
    sa, ta = CheckpointManager(da).restore_train_state()
    sb, tb = CheckpointManager(db).restore_train_state()
    assert sa == sb == 4
    for a, b in zip(jax.tree.leaves(ta.params), jax.tree.leaves(tb.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert CheckpointManager(da).restore_data_state() == CheckpointManager(
        db
    ).restore_data_state()


def test_segment_mask_equals_per_document_attention():
    """'pack_nocross' attention isolates documents (dense trunk: MoE expert
    capacity is contested across the whole batch, so routers couple tokens
    across documents by design — attention is what segments must cut)."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    import repro.configs as configs
    from repro.configs.base import RoutingSpec
    from repro.models import build_model

    cfg, _ = _tiny_model()
    cfg = dataclasses.replace(cfg, family="dense", routing=RoutingSpec())
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    rs = model.init_router_states()
    S = 24
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(3), (1, S), 0, cfg.vocab_size))
    cut = 10
    seg = np.zeros((1, S), np.int32)
    seg[:, cut:] = 1
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks),
             "segments": jnp.asarray(seg)}
    logits, *_ = model.forward(params, batch, rs)
    # each document alone (positions restart per doc in the packed batch's
    # RoPE? no — packed positions are absolute; mimic by slicing positions
    # is not possible via public API, so compare against a batch where the
    # second document is replaced: logits of doc0 must not change)
    toks2 = toks.copy()
    toks2[:, cut:] = (toks2[:, cut:] + 7) % cfg.vocab_size
    batch2 = {"tokens": jnp.asarray(toks2), "labels": jnp.asarray(toks2),
              "segments": jnp.asarray(seg)}
    logits2, *_ = model.forward(params, batch2, rs)
    np.testing.assert_allclose(
        np.asarray(logits[0, :cut]), np.asarray(logits2[0, :cut]), rtol=0, atol=0
    )
    # and WITHOUT segments, changing doc1 does leak into... nothing before
    # the cut (causality) — but changing doc0 leaks into doc1 only when
    # segments are absent
    toks3 = toks.copy()
    toks3[:, :cut] = (toks3[:, :cut] + 7) % cfg.vocab_size
    b_seg = {"tokens": jnp.asarray(toks3), "labels": jnp.asarray(toks3),
             "segments": jnp.asarray(seg)}
    b_noseg = {"tokens": jnp.asarray(toks3), "labels": jnp.asarray(toks3)}
    l_seg, *_ = model.forward(params, b_seg, rs)
    l_noseg, *_ = model.forward(params, b_noseg, rs)
    ref_tail, *_ = model.forward(params, batch, rs)
    # with segments: doc1 logits identical to the original batch's doc1
    np.testing.assert_array_equal(
        np.asarray(l_seg[0, cut:]), np.asarray(ref_tail[0, cut:])
    )
    # without segments: doc0's change must reach doc1 (causal attention)
    assert not np.array_equal(np.asarray(l_noseg[0, cut:]), np.asarray(ref_tail[0, cut:]))


def test_segments_refused_on_ssm_family():
    """The SSM recurrence leaks across packed documents — model.forward
    must refuse segments rather than silently train on the leak."""
    import jax
    import jax.numpy as jnp

    import repro.configs as configs
    from repro.models import build_model

    cfg = configs.reduced_for_smoke("mamba2_130m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.zeros((1, 8), jnp.int32)
    batch = {"tokens": toks, "labels": toks, "segments": jnp.zeros((1, 8), jnp.int32)}
    with pytest.raises(ValueError, match="pack_nocross"):
        model.forward(params, batch, model.init_router_states())


def test_launcher_data_cli(tmp_path):
    """launch.train --data end to end, incl. tokenizer train+save."""
    from repro.launch.train import main

    out = str(tmp_path / "s.json")
    rc = main([
        "--arch", "minimind-moe-16e", "--reduced", "--steps", "2",
        "--batch", "2", "--seq-len", "32", "--data", FIXTURE,
        "--tokenizer", str(tmp_path / "tok.json"), "--log-every", "0",
        "--out-json", out,
    ])
    assert rc == 0
    with open(out) as f:
        summary = json.load(f)
    assert summary["data"] == FIXTURE and summary["final_loss"] is not None
    assert os.path.exists(str(tmp_path / "tok.json"))
