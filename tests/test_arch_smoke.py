"""Per-architecture smoke tests (assignment deliverable f).

Every assigned arch instantiates a REDUCED variant of the same family
(2 scan periods of layers, d_model<=128, <=4 experts) and runs one forward +
one train-grad step + one decode step on CPU, asserting output shapes and
no NaNs.

The `*_sharded_*` cases additionally run `train_loop(mesh=4x2)` for the
non-minimind families (hybrid mamba zamba2, iRoPE-MoE llama4) in a
subprocess with 8 forced host devices (shared runner in
tests/_forced_devices.py); the harness accepts `mesh=` for every family
but only minimind's MoE paths were parity-tested before these.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _forced_devices import PRELUDE, run_code as _run_sharded
from repro import configs
from repro.models import build_model

ARCHS = configs.ARCH_IDS

_SHARDED_PRELUDE = PRELUDE + r"""
from repro import configs
from repro.data import make_batches
from repro.distributed import make_mesh_ctx
from repro.models import build_model
from repro.training import train_loop
"""


def _batch(cfg, rng, batch=2, seq=32):
    b = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32
        ),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32
        ),
    }
    if cfg.family == "vlm":
        b["patches"] = jnp.asarray(
            rng.standard_normal((batch, cfg.frontend_tokens, cfg.frontend_dim)),
            jnp.float32,
        )
    if cfg.family == "encdec":
        b["frames"] = jnp.asarray(
            rng.standard_normal((batch, cfg.enc_seq_len, cfg.frontend_dim)),
            jnp.float32,
        )
    return b


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_grad(arch, rng):
    cfg = configs.reduced_for_smoke(arch)
    assert cfg.d_model <= 512 and cfg.n_layers <= 8
    if cfg.is_moe:
        assert cfg.routing.n_experts <= 4
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    states = model.init_router_states()
    batch = _batch(cfg, rng)

    logits, new_states, aux, mets = jax.jit(model.forward)(params, batch, states)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: NaN/inf logits"

    (loss, (new_states, mets)), grads = jax.jit(
        jax.value_and_grad(model.loss_fn, has_aux=True)
    )(params, batch, states)
    assert np.isfinite(float(loss)), f"{arch}: NaN loss"
    finite = jax.tree.map(lambda g: bool(np.isfinite(np.asarray(g)).all()), grads)
    assert all(jax.tree.leaves(finite)), f"{arch}: non-finite grads"
    # gradient must reach the embedding at minimum
    assert float(jnp.abs(grads["embed"]["tok"]).sum()) > 0.0
    if cfg.is_moe:
        assert mets["max_vio_per_layer"].shape[0] == sum(
            1 for _, f in cfg.layer_kinds() if f == "moe"
        )


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch, rng):
    cfg = configs.reduced_for_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    states = model.init_router_states()
    batch = _batch(cfg, rng, batch=2, seq=1)
    cache = model.init_cache(params, batch, seq_len=64)
    step = jax.jit(model.decode_step)
    tok = batch["tokens"]
    for _ in range(3):
        logits, cache, states = step(params, tok, cache, states)
        assert logits.shape == (2, 1, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all(), f"{arch}: NaN decode logits"
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)


def test_decode_matches_forward_dense():
    """Greedy decode logits must match teacher-forced forward logits
    (validates cache correctness end-to-end) for a dense arch."""
    cfg = configs.reduced_for_smoke("stablelm_1_6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    states = model.init_router_states()
    rng = np.random.default_rng(1)
    seq = 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, seq)), jnp.int32)
    fwd_logits, *_ = model.forward(params, {"tokens": tokens}, states)

    cache = model.init_cache(params, {"tokens": tokens[:, :1]}, seq_len=32)
    outs = []
    st = states
    for t in range(seq):
        lg, cache, st = model.decode_step(params, tokens[:, t : t + 1], cache, st)
        outs.append(lg)
    dec_logits = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(fwd_logits), np.asarray(dec_logits), atol=2e-2, rtol=2e-2
    )


def test_decode_matches_forward_gemma2_pattern():
    """Same check for the local/global alternating + softcap family."""
    cfg = configs.reduced_for_smoke("gemma2_27b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    states = model.init_router_states()
    rng = np.random.default_rng(2)
    seq = 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, seq)), jnp.int32)
    fwd_logits, *_ = model.forward(params, {"tokens": tokens}, states)
    cache = model.init_cache(params, {"tokens": tokens[:, :1]}, seq_len=32)
    outs = []
    st = states
    for t in range(seq):
        lg, cache, st = model.decode_step(params, tokens[:, t : t + 1], cache, st)
        outs.append(lg)
    dec_logits = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(fwd_logits), np.asarray(dec_logits), atol=2e-2, rtol=2e-2
    )


def test_sharded_train_smoke_zamba2():
    """Reduced zamba2 (hybrid mamba + weight-shared attn block) through
    train_loop on a 4x2 host mesh: finite losses, shapes preserved, and the
    sharded losses track the single-device run (no MoE, so the only
    cross-decomposition difference is f32 reassociation)."""
    _run_sharded(_SHARDED_PRELUDE + r"""
cfg = configs.reduced_for_smoke("zamba2_7b", vocab_size=256)
steps = 2
kw = dict(lr=1e-3, warmup_steps=1, total_steps=steps)
_, log0 = train_loop(build_model(cfg), make_batches(cfg, 8, 32, steps, seed=0), **kw)
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
_, log1 = train_loop(build_model(cfg, make_mesh_ctx(mesh)),
                     make_batches(cfg, 8, 32, steps, seed=0), mesh=mesh, **kw)
assert len(log1.losses) == steps
assert all(np.isfinite(l) for l in log1.losses), log1.losses
for a, b in zip(log0.losses, log1.losses):
    assert abs(a - b) / abs(a) < 2e-2, (log0.losses, log1.losses)
print("OK", log1.losses[-1])
""")


def test_sharded_train_smoke_llama4_global_sync():
    """Reduced llama4 (iRoPE 3:1 local/global attention, MoE k=1) through
    train_loop on a 4x2 host mesh under sync='global': the global-dual path
    must hold on a second MoE family (different attn pattern, top_k=1, and
    a reduced 4-expert table), with per-layer MaxVio within marginal-tie
    quanta of the single-device run."""
    _run_sharded(_SHARDED_PRELUDE + r"""
cfg = configs.reduced_for_smoke(
    "llama4_scout_17b_a16e",
    routing=dataclasses.replace(
        configs.reduced_for_smoke("llama4_scout_17b_a16e").routing,
        sync="global", capacity_factor=8.0),
    vocab_size=256)
steps = 2
kw = dict(lr=1e-3, warmup_steps=1, total_steps=steps)
_, log0 = train_loop(build_model(cfg), make_batches(cfg, 8, 32, steps, seed=0), **kw)
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
_, log1 = train_loop(build_model(cfg, make_mesh_ctx(mesh)),
                     make_batches(cfg, 8, 32, steps, seed=0), mesh=mesh, **kw)
assert all(np.isfinite(l) for l in log1.losses), log1.losses
v0, v1 = np.stack(log0.max_vio_steps), np.stack(log1.max_vio_steps)
assert v0.shape == v1.shape
quantum = 1.0 / (8 * 32 * cfg.routing.top_k / cfg.routing.n_experts)
assert np.abs(v0 - v1).max() <= 3 * quantum + 1e-5, (v0.tolist(), v1.tolist())
for a, b in zip(log0.losses, log1.losses):
    assert abs(a - b) / abs(a) < 2e-2, (log0.losses, log1.losses)
print("OK", log1.losses[-1])
""")


def test_full_configs_exact_dims():
    """The FULL configs must carry the exact assigned dimensions."""
    spec = {
        "zamba2_7b": dict(n_layers=81, d_model=3584, n_heads=32, d_ff=14336, vocab_size=32000),
        "paligemma_3b": dict(n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_ff=16384, vocab_size=257216),
        "llama4_scout_17b_a16e": dict(n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, vocab_size=202048),
        "deepseek_coder_33b": dict(n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=19200, vocab_size=32256),
        "phi4_mini_3_8b": dict(n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, d_ff=8192, vocab_size=200064),
        "mamba2_130m": dict(n_layers=24, d_model=768, vocab_size=50280),
        "seamless_m4t_large_v2": dict(n_layers=24, d_model=1024, n_heads=16, d_ff=8192, vocab_size=256206),
        "gemma2_27b": dict(n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, d_ff=36864, vocab_size=256000),
        "arctic_480b": dict(n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864, vocab_size=32000),
        "stablelm_1_6b": dict(n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=5632, vocab_size=100352),
    }
    for arch, dims in spec.items():
        cfg = configs.get(arch)
        for k, v in dims.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    assert configs.get("zamba2_7b").ssm.d_state == 64
    assert configs.get("mamba2_130m").ssm.d_state == 128
    assert configs.get("llama4_scout_17b_a16e").routing.n_experts == 16
    assert configs.get("llama4_scout_17b_a16e").routing.top_k == 1
    assert configs.get("arctic_480b").routing.n_experts == 128
    assert configs.get("arctic_480b").routing.top_k == 2
    assert configs.get("arctic_480b").dense_residual
    assert configs.get("minimind_moe_16e").routing.n_experts == 16
    assert configs.get("minimind_moe_64e").routing.n_experts == 64
