"""Packed multi-request prefill (DESIGN.md §Serving).

Three contracts pinned here:

1. Parity: a packed chunk — several requests' segments sharing one row,
   plus resident decode/stream rows — produces bit-identical logits and
   cache rows to prefilling each request sequentially. NEG_INF masking
   gives segment-foreign weights that are exactly zero, so packing is
   exact, not approximately close.
2. Refusal: packed operands on a stack with ssm/hybrid layers raise
   (cross-segment state bleeds through recurrences; only attention kinds
   can mask it out).
3. Padding hygiene: `attention_chunk` documents that padded output
   columns are garbage the CALLER must mask. The engine is that caller —
   the regression test poisons every padded column (tokens and, on the
   packed path, positions) right before the jit'd step and asserts the
   generated streams are bit-identical to the clean engine. Any leak of
   a padded column into attended KV state or sampled logits would diverge
   the streams.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.serving import ContinuousBatchingEngine

VOCAB = 128


def _seq_prefill(model, params, prompt, slot, n_slots, chunk, cache, st):
    """Reference: prefill one prompt alone in its slot (legacy layout)."""
    toks = jnp.zeros((n_slots, chunk), jnp.int32)
    toks = toks.at[slot, : prompt.shape[0]].set(prompt)
    lengths = jnp.zeros((n_slots,), jnp.int32).at[slot].set(prompt.shape[0])
    lg, cache, st, _ = model.prefill_chunk(params, toks, cache, st, lengths)
    return lg[slot, prompt.shape[0] - 1], cache, st


@pytest.mark.parametrize("arch", ["stablelm_1_6b", "gemma2_27b"])
def test_packed_prefill_matches_sequential(arch):
    """Packed chunk == sequential per-request prefill, bit for bit, for
    both the logits at each segment's last token and the written cache
    rows — on an all-global stack and on a ring(local)+global stack."""
    cfg = configs.reduced_for_smoke(arch, vocab_size=VOCAB)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    n_slots, c, seq_len = 4, 8, 32

    p0 = jnp.asarray(rng.integers(0, VOCAB, (5,)), jnp.int32)  # resident
    p1 = jnp.asarray(rng.integers(0, VOCAB, (3,)), jnp.int32)
    p2 = jnp.asarray(rng.integers(0, VOCAB, (4,)), jnp.int32)

    # --- sequential reference: each prompt alone, then a decode on slot 0
    st = model.init_router_states()
    cache = model.init_slot_cache(params, n_slots, seq_len)
    lg0, cache, st = _seq_prefill(model, params, p0, 0, n_slots, c, cache, st)
    lg1, cache, st = _seq_prefill(model, params, p1, 1, n_slots, c, cache, st)
    lg2, cache, st = _seq_prefill(model, params, p2, 2, n_slots, c, cache, st)
    tok0 = jnp.argmax(lg0).astype(jnp.int32)
    toks = jnp.zeros((n_slots, c), jnp.int32).at[0, 0].set(tok0)
    lengths = jnp.zeros((n_slots,), jnp.int32).at[0].set(1)
    lg_dec, cache_ref, st_ref, _ = model.prefill_chunk(
        params, toks, cache, st, lengths
    )
    ref_dec = lg_dec[0, 0]

    # --- packed: resident decode in row 0, p1+p2 as segments 1,2 of row 1
    st = model.init_router_states()
    cache = model.init_slot_cache(params, n_slots, seq_len)
    lg0b, cache, st = _seq_prefill(model, params, p0, 0, n_slots, c, cache, st)
    assert jnp.array_equal(lg0b, lg0)

    toks = jnp.zeros((n_slots, c), jnp.int32)
    positions = jnp.zeros((n_slots, c), jnp.int32)
    segments = jnp.full((n_slots, c), -1, jnp.int32)
    write_slots = jnp.full((n_slots, c), -1, jnp.int32)
    cache_rows = jnp.arange(n_slots, dtype=jnp.int32)
    toks = toks.at[0, 0].set(tok0)
    positions = positions.at[0, 0].set(p0.shape[0])
    segments = segments.at[0, 0].set(0)
    write_slots = write_slots.at[0, 0].set(0)
    col = 0
    for seg, (prompt, slot) in enumerate([(p1, 1), (p2, 2)], start=1):
        n = prompt.shape[0]
        toks = toks.at[1, col : col + n].set(prompt)
        positions = positions.at[1, col : col + n].set(jnp.arange(n))
        segments = segments.at[1, col : col + n].set(seg)
        write_slots = write_slots.at[1, col : col + n].set(slot)
        col += n

    lg_packed, cache_got, _, _ = model.prefill_chunk(
        params, toks, cache, st,
        positions=positions, segments=segments,
        write_slots=write_slots, cache_rows=cache_rows,
    )
    assert jnp.array_equal(lg_packed[0, 0], ref_dec)
    assert jnp.array_equal(lg_packed[1, p1.shape[0] - 1], lg1)
    assert jnp.array_equal(lg_packed[1, col - 1], lg2)
    # every cache row the step touched must match the sequential reference
    for a, b in zip(jax.tree.leaves(cache_ref), jax.tree.leaves(cache_got)):
        assert np.array_equal(np.asarray(a)[:, :3], np.asarray(b)[:, :3])


@pytest.mark.parametrize("arch", ["mamba2_130m", "zamba2_7b"])
def test_packed_prefill_rejects_stateful_stacks(arch):
    """ssm/hybrid layers carry cross-token recurrent state that segment
    masks cannot isolate; packed operands must be refused loudly."""
    cfg = configs.reduced_for_smoke(arch, vocab_size=VOCAB)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_slots, c = 2, 8
    cache = model.init_slot_cache(params, n_slots, 32)
    st = model.init_router_states()
    z2 = jnp.zeros((n_slots, c), jnp.int32)
    with pytest.raises(ValueError, match="attention-only"):
        model.prefill_chunk(
            params, z2, cache, st,
            positions=z2, segments=z2, write_slots=z2,
            cache_rows=jnp.arange(n_slots, dtype=jnp.int32),
        )


def _run_stream(eng, prompts, gen=6):
    reqs = []
    for p in prompts:
        r = eng.submit(p, gen, ignore_eos=True)
        while r is None:
            eng.step()
            r = eng.submit(p, gen, ignore_eos=True)
        reqs.append(r)
    steps = 0
    while eng.scheduler.has_work:
        eng.step()
        steps += 1
    return [r.output for r in reqs], steps


def test_engine_packed_spreading_reduces_steps():
    """End to end: a long prompt next to idle rows finishes its prefill in
    fewer steps through the packed path, with outputs identical to the
    legacy one-row-per-slot schedule."""
    cfg = configs.reduced_for_smoke("stablelm_1_6b", vocab_size=VOCAB)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, VOCAB, (23,)).tolist(),  # 3 chunks of 8
        rng.integers(0, VOCAB, (5,)).tolist(),
        rng.integers(0, VOCAB, (3,)).tolist(),
    ]

    eng = ContinuousBatchingEngine(
        model, params, n_slots=6, chunk_size=8, max_seq_len=64
    )
    assert eng._can_spread
    out_packed, steps_packed = _run_stream(eng, prompts)

    ref = ContinuousBatchingEngine(
        model, params, n_slots=6, chunk_size=8, max_seq_len=64
    )
    ref._can_spread = False  # force the legacy schedule
    out_legacy, steps_legacy = _run_stream(ref, prompts)

    assert out_packed == out_legacy
    assert steps_packed < steps_legacy


def _poison_padding(eng):
    """Wrap both jit'd step programs to overwrite every padded column with
    garbage immediately before the device call."""
    orig_leg = eng._serve_step
    orig_pack = eng._serve_step_packed

    def leg(params, cache, states, tokens, lengths, rng):
        pad = jnp.arange(tokens.shape[1])[None, :] >= lengths[:, None]
        return orig_leg(
            params, cache, states,
            jnp.where(pad, VOCAB - 1, tokens), lengths, rng,
        )

    def pack(params, cache, states, tokens, positions, segments,
             write_slots, cache_rows, gather_rows, gather_cols, rng):
        pad = segments < 0
        return orig_pack(
            params, cache, states,
            jnp.where(pad, VOCAB - 1, tokens),
            jnp.where(pad, 7, positions),
            segments, write_slots, cache_rows, gather_rows, gather_cols, rng,
        )

    eng._serve_step = leg
    eng._serve_step_packed = pack


@pytest.mark.parametrize("arch", ["stablelm_1_6b", "minimind_moe_16e"])
def test_engine_masks_padded_columns(arch):
    """The attention_chunk contract — padded output columns are garbage the
    caller must mask — held at the engine level, on both step programs.

    Garbage in padded columns (tokens AND packed-path positions) must
    never reach a sampled token or attended KV state: if it did, the
    poisoned engine's generated streams would diverge from the clean
    engine's somewhere over a mixed prefill/decode schedule that exercises
    partial chunks, packed segments, and spread rows."""
    cfg = configs.reduced_for_smoke(arch, vocab_size=VOCAB)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompts = [
        rng.integers(0, VOCAB, (int(n),)).tolist() for n in (19, 5, 3, 11)
    ]

    outs = []
    for poison in (False, True):
        eng = ContinuousBatchingEngine(
            model, params, n_slots=4, chunk_size=8, max_seq_len=64
        )
        if poison:
            _poison_padding(eng)
        out, _ = _run_stream(eng, prompts)
        outs.append(out)
    assert outs[0] == outs[1]
