"""Substrate integration: training loop learns, checkpoints roundtrip,
serving generates, optimizer behaves."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# where hypothesis is absent, tests/conftest.py installs a deterministic
# single-sample stub before this import runs
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.checkpoint import load_pytree, save_pytree, CheckpointManager
from repro.data import SyntheticLMDataset, make_batches
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init, adamw_update, global_norm
from repro.optim.schedules import linear_warmup_cosine
from repro.serving import greedy_generate
from repro.training import train_loop
from repro.training.loop import evaluate_ppl


def test_train_loop_learns_dense():
    cfg = configs.reduced_for_smoke("stablelm_1_6b", vocab_size=256)
    model = build_model(cfg)
    batches = list(make_batches(cfg, batch_size=8, seq_len=64, n_batches=40, seed=0))
    state, log = train_loop(model, batches, lr=1e-3, warmup_steps=5, total_steps=40)
    assert np.mean(log.losses[:5]) - np.mean(log.losses[-5:]) > 0.5, log.losses[-5:]


def test_train_loop_learns_moe_with_bip():
    cfg = configs.reduced_for_smoke("minimind_moe_16e", vocab_size=256)
    model = build_model(cfg)
    batches = list(make_batches(cfg, batch_size=8, seq_len=64, n_batches=40, seed=1))
    state, log = train_loop(model, batches, lr=1e-3, warmup_steps=5, total_steps=40)
    assert np.mean(log.losses[:5]) - np.mean(log.losses[-5:]) > 0.5
    s = log.summary()
    # the paper's claim: balance from the first step, on every batch
    assert s["SupMaxVio"] < 1.0, s
    assert s["AvgMaxVio"] < 0.5, s
    assert len(s["AvgMaxVio_per_layer"]) == cfg.n_layers  # all layers MoE


def test_synthetic_data_is_learnable_and_skewed():
    ds = SyntheticLMDataset(vocab_size=128, seq_len=64, seed=0)
    b = next(iter(ds.batches(16, 1)))
    toks = np.asarray(b["tokens"]).reshape(-1)
    counts = np.bincount(toks, minlength=128)
    # zipf skew: top token much more frequent than median
    assert counts.max() > 8 * max(np.median(counts), 1)
    # determinism
    b2 = next(iter(ds.batches(16, 1)))
    np.testing.assert_array_equal(np.asarray(b["tokens"]), np.asarray(b2["tokens"]))


def test_checkpoint_roundtrip_exact():
    cfg = configs.reduced_for_smoke("minimind_moe_16e")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    states = model.init_router_states()
    tree = {"params": params, "router": states, "misc": (jnp.arange(3), None)}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.npz")
        save_pytree(path, tree)
        back = load_pytree(path)
    flat_a, tdef_a = jax.tree.flatten(tree)
    flat_b, tdef_b = jax.tree.flatten(back)
    assert tdef_a == tdef_b, (tdef_a, tdef_b)
    for a, b in zip(flat_a, flat_b):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_manager_gc():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in [10, 20, 30]:
            mgr.save(s, {"x": jnp.ones((2,))})
        files = sorted(os.listdir(d))
        # each kept step = npz + its integrity-manifest sidecar
        assert [f for f in files if f.endswith(".npz")] == [
            "step_20.npz",
            "step_30.npz",
        ]
        assert files == [
            "step_20.manifest.json",
            "step_20.npz",
            "step_30.manifest.json",
            "step_30.npz",
        ]
        step, tree = mgr.restore()
        assert step == 30 and np.all(np.asarray(tree["x"]) == 1.0)


def test_checkpoint_bf16_roundtrip():
    tree = {"w": jnp.arange(8, dtype=jnp.bfloat16) * 0.5}
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "x.npz")
        save_pytree(p, tree)
        back = load_pytree(p)
    assert back["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(tree["w"]), np.asarray(back["w"]))


def test_serving_generates_all_families():
    for arch in ["stablelm_1_6b", "minimind_moe_16e", "mamba2_130m", "zamba2_7b"]:
        cfg = configs.reduced_for_smoke(arch, vocab_size=128)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        prompts = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
        toks = greedy_generate(model, params, prompts, n_steps=4, max_seq_len=32)
        assert toks.shape == (2, 4)
        assert np.all(np.asarray(toks) >= 0) and np.all(np.asarray(toks) < 128)


def test_trained_model_beats_untrained_on_test_split():
    cfg = configs.reduced_for_smoke("stablelm_1_6b", vocab_size=256)
    model = build_model(cfg)
    train = list(make_batches(cfg, 8, 64, 50, seed=0, split="train"))
    test = list(make_batches(cfg, 8, 64, 4, seed=0, split="test"))
    state, _ = train_loop(model, train, lr=1e-3, warmup_steps=5, total_steps=50)
    trained_ppl = evaluate_ppl(model, state, test)
    from repro.training.loop import init_train_state
    from repro.optim.adamw import from_model_config
    fresh = init_train_state(model, jax.random.PRNGKey(9), from_model_config(cfg))
    fresh_ppl = evaluate_ppl(model, fresh, test)
    assert trained_ppl < 0.6 * fresh_ppl, (trained_ppl, fresh_ppl)


# ------------------------------------------------------- optimizer props


@given(seed=st.integers(0, 10_000), lr=st.floats(1e-5, 1e-2))
@settings(max_examples=15, deadline=None)
def test_adamw_decreases_quadratic(seed, lr):
    """Property: AdamW steps decrease a convex quadratic."""
    rng = np.random.default_rng(seed)
    target = jnp.asarray(rng.standard_normal((8,)), jnp.float32)
    params = {"w": jnp.zeros((8,))}
    cfg = AdamWConfig(weight_decay=0.0, clip_norm=0.0)
    opt = adamw_init(params, cfg)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, params, jnp.asarray(lr), cfg)
    assert float(loss(params)) < l0


def test_adamw_clip_norm_bounds_update():
    params = {"w": jnp.zeros((4,))}
    cfg = AdamWConfig(clip_norm=1.0, weight_decay=0.0)
    opt = adamw_init(params, cfg)
    huge = {"w": jnp.full((4,), 1e6)}
    _, _, info = adamw_update(huge, opt, params, jnp.asarray(1e-3), cfg)
    assert float(info["grad_norm"]) > 1e5  # reported pre-clip


def test_lr_schedule_shape():
    f = linear_warmup_cosine(1.0, 10, 100)
    assert float(f(jnp.asarray(0.0))) == 0.0
    assert abs(float(f(jnp.asarray(10.0))) - 1.0) < 1e-6
    assert float(f(jnp.asarray(50.0))) < 1.0
    assert float(f(jnp.asarray(100.0))) >= 0.1 - 1e-6
